"""Benchmark harness utilities: CSV emission per the repo convention."""

from __future__ import annotations

import time


class Csv:
    """Collects ``name,us_per_call,derived`` rows (one per measurement)."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append((name, us_per_call, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0)
