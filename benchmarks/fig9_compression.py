"""Fig. 9 — Compression ratio vs collection size + per-model ratio CDF.

NeurStore vs ZSTD / ZFP-like / ELF on growing model collections. 9(b):
per-model ratios with base-tensor cost amortized over referencing tensors
(paper §6.3.2); we report CDF quantiles."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.baselines.compressors import ALL_COMPRESSORS
from repro.core import StorageEngine

from .common import Csv
from .workload import model_collection, collection_bytes


def run(csv: Csv):
    for n_fam, tag in ((2, "small"), (4, "medium"), (6, "large")):
        collection = model_collection(n_families=n_fam, n_variants=4,
                                      n_unrelated=max(1, n_fam // 2))
        orig = collection_bytes(collection)
        # Per-tensor compressors.
        for cname in ("zstd", "zfp", "elf"):
            comp = ALL_COMPRESSORS[cname]
            total = sum(len(comp.compress(t)) for _, ts in collection
                        for t in ts.values())
            csv.add(f"fig9a/{tag}/{cname}", 0.0,
                    f"bytes={total} ratio={orig/total:.2f}")
        # NeurStore.
        with tempfile.TemporaryDirectory() as root:
            eng = StorageEngine(root)
            for nm, ts in collection:
                eng.save_model(nm, {}, ts)
            s = eng.storage_bytes()
            csv.add(f"fig9a/{tag}/neurstore", 0.0,
                    f"bytes={s['total']} ratio={orig/s['total']:.2f}")
            if tag == "large":
                per_model = []
                for nm, ts in collection:
                    raw = sum(t.size * 4 for t in ts.values())
                    per_model.append(raw / eng.per_model_bytes(nm))
                q = np.percentile(per_model, [10, 50, 90])
                frac_14 = float(np.mean(np.asarray(per_model) > 1.4))
                csv.add("fig9b/cdf/neurstore", 0.0,
                        f"p10={q[0]:.2f} p50={q[1]:.2f} p90={q[2]:.2f} "
                        f"frac>1.4x={frac_14:.2f}")
