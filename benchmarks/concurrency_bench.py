"""Concurrent-read benchmark: snapshot readers vs the global-lock baseline.

Measures the concurrency subsystem (buffer pool + snapshot-isolated loads):

* **serialized baseline** — N reader threads materializing models behind
  ONE global mutex, each load bypassing the buffer pool
  (``shared_cache=False``: private page bytes, private payload decode) —
  the pre-concurrency read path, where every read re-reads and re-decodes
  under exclusion;
* **concurrent** — the same N readers on the snapshot path (short capture
  critical section, then lock-free materialization over pooled frames and
  shared decoded payloads) while ONE writer thread replaces/deletes models
  and vacuums in a loop — the ISSUE 4 scenario;
* per-read **p50/p99 latency** and **aggregate throughput** for both, plus
  the writer's op count and the engine's pool/snapshot counters.

The acceptance bar (checked against the full-scale run recorded in
``BENCH_concurrency.json``): ≥2x aggregate read throughput with 4 reader
threads vs the serialized baseline on CPU. The CI gate
(``benchmarks/perf_gate.py``) enforces the coarse invariant
``concurrent >= serialized`` on the noisy shared runners.

Run: ``PYTHONPATH=src python benchmarks/concurrency_bench.py [--readers 4]``;
``--smoke`` runs the small CI scale. Or via the runner:
``PYTHONPATH=src python -m benchmarks.run concurrency [--smoke]``.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

import numpy as np

from repro.core.engine import StorageEngine

# Bumped whenever the JSON layout changes (parsed by benchmarks/perf_gate.py).
SCHEMA_VERSION = 2


def _models(n: int, dim: int, rng: np.random.Generator) -> list[tuple]:
    """Dissimilar models (each owns its bases) with matmul-sized tensors so
    materialization is numpy-dominated — the serving-shaped workload."""
    side = int(dim ** 0.5)
    out = []
    for i in range(n):
        tensors = {
            "w0": rng.normal(0, 5.0, (side, side)).astype(np.float32),
            "w1": rng.normal(0, 5.0, (side, side)).astype(np.float32),
            "b": rng.normal(0, 5.0, (side,)).astype(np.float32),
        }
        out.append((f"m{i}", {"layer": i}, tensors))
    return out


def _percentile(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def _run_phase(engine, specs, n_readers: int, duration_s: float,
               serialized: bool, write_interval_s: float):
    """One measured phase: N reader threads + one pacing writer thread.

    ``serialized`` models the pre-concurrency engine: EVERY operation —
    reads (which also bypass the buffer pool and re-decode privately,
    exactly the old load path) and writes alike — funnels through one
    global mutex. The concurrent mode runs the same workload through the
    snapshot path with no mutex. The writer replaces one model per tick
    and vacuums periodically, so both phases pay the same write load and
    the comparison isolates the read-path concurrency.
    """
    names = [n for n, _, _ in specs]
    mutex = threading.Lock()  # the global-lock stand-in (serialized mode)
    stop = threading.Event()
    lat: list[list[float]] = [[] for _ in range(n_readers)]
    writer_ops = {"saves": 0, "deletes": 0, "replaces": 0, "vacuums": 0}

    def reader(slot: int):
        rng = np.random.default_rng(slot)
        my = lat[slot]
        while not stop.is_set():
            name = names[int(rng.integers(len(names)))]
            t0 = time.perf_counter()
            try:
                if serialized:
                    with mutex:
                        engine.load_model(name, shared_cache=False).materialize()
                else:
                    engine.load_model(name).materialize()
            except KeyError:
                continue  # raced the writer mid-replace: not a read
            my.append(time.perf_counter() - t0)

    def write_op(fn):
        if serialized:
            with mutex:
                return fn()
        return fn()

    def writer():
        # A serving-shaped write mix: steady ingest/delete churn of small
        # models (short commits), an occasional replace of a model the
        # readers are hitting (exercises invalidation + snapshot
        # isolation), periodic vacuum (exercises copy-on-write GC).
        k = 0
        wrng = np.random.default_rng(99)
        while not stop.wait(write_interval_s):
            small = {
                "w": wrng.normal(0, 5.0, (96, 96)).astype(np.float32),
                "b": wrng.normal(0, 5.0, (96,)).astype(np.float32),
            }
            write_op(lambda: engine.save_model(f"ingest{k}", {}, small))
            writer_ops["saves"] += 1
            if k >= 4:
                write_op(lambda: engine.delete_model(f"ingest{k - 4}"))
                writer_ops["deletes"] += 1
            if k % 6 == 5:
                name, arch, tensors = specs[k % len(specs)]
                fresh = {kk: wrng.normal(0, 5.0, vv.shape).astype(np.float32)
                         for kk, vv in tensors.items()}
                write_op(lambda: engine.replace_model(name, arch, fresh))
                writer_ops["replaces"] += 1
            if k % 8 == 7:
                write_op(lambda: engine.vacuum(min_dead_fraction=0.25))
                writer_ops["vacuums"] += 1
            k += 1
        # Leave the store as the next phase expects it: no ingest leftovers.
        for name in list(engine.list_models()):
            if name.startswith("ingest"):
                write_op(lambda name=name: engine.delete_model(name))

    threads = [threading.Thread(target=reader, args=(s,))
               for s in range(n_readers)]
    wt = threading.Thread(target=writer)
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    wt.start()
    time.sleep(duration_s)
    # Wall stops at the stop signal: the thread-drain tail (a reader or
    # the writer finishing its in-flight op) must not dilute throughput.
    wall = time.perf_counter() - t_start
    stop.set()
    for t in threads:
        t.join()
    wt.join()
    all_lat = [x for slot in lat for x in slot]
    return {
        "reads": len(all_lat),
        "wall_s": wall,
        "reads_per_s": len(all_lat) / wall,
        "p50_ms": _percentile(all_lat, 50) * 1e3,
        "p99_ms": _percentile(all_lat, 99) * 1e3,
        "writer_ops": dict(writer_ops),
    }


def run_bench(n_models: int = 8, dim: int = 262144, n_readers: int = 4,
              duration_s: float = 6.0, write_interval_s: float = 0.15,
              reps: int = 2, smoke: bool = False) -> dict:
    rng = np.random.default_rng(0)
    specs = _models(n_models, dim, rng)

    def phase(serialized: bool):
        # Fresh store per phase: both modes start from the identical
        # just-ingested state, so neither inherits the other's index
        # growth or page churn.
        with tempfile.TemporaryDirectory() as root:
            engine = StorageEngine(root)
            engine.save_models(specs)
            res = _run_phase(engine, specs, n_readers, duration_s,
                             serialized=serialized,
                             write_interval_s=write_interval_s)
            res["engine_stats"] = {
                "epoch": engine.stats()["epoch"],
                "buffer_pool": engine.stats()["buffer_pool"],
            }
        return res

    # Best-of-N per mode: scheduler noise on shared runners stalls a whole
    # phase (one descheduled writer wedges everything behind it); the best
    # rep reflects what each read path can actually sustain.
    ser_reps = [phase(True) for _ in range(reps)]
    con_reps = [phase(False) for _ in range(reps)]
    serialized = max(ser_reps, key=lambda r: r["reads_per_s"])
    concurrent = max(con_reps, key=lambda r: r["reads_per_s"])
    stats = concurrent.pop("engine_stats")
    serialized.pop("engine_stats", None)

    speedup = (concurrent["reads_per_s"] / serialized["reads_per_s"]
               if serialized["reads_per_s"] else float("inf"))
    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "smoke" if smoke else "full",
        "config": {
            "n_models": n_models,
            "dim": dim,
            "n_readers": n_readers,
            "duration_s": duration_s,
            "write_interval_s": write_interval_s,
            "reps": reps,
        },
        "concurrent_read": {
            "serialized": serialized,
            "concurrent": concurrent,
            "speedup_vs_serialized": speedup,
            "all_reps": {
                "serialized_reads_per_s": [r["reads_per_s"] for r in ser_reps],
                "concurrent_reads_per_s": [r["reads_per_s"] for r in con_reps],
            },
        },
        "engine_stats": stats,
    }


def run(csv, smoke: bool = False):
    """Runner entry point (quick scale, CSV convention)."""
    res = run_bench(n_models=4, dim=65536, n_readers=4,
                    duration_s=1.0 if smoke else 2.0, smoke=smoke)
    cr = res["concurrent_read"]
    csv.add("concurrency/serialized_read",
            cr["serialized"]["p50_ms"] * 1e3,
            f"reads_per_s={cr['serialized']['reads_per_s']:.0f}")
    csv.add("concurrency/concurrent_read",
            cr["concurrent"]["p50_ms"] * 1e3,
            f"reads_per_s={cr['concurrent']['reads_per_s']:.0f},"
            f"speedup={cr['speedup_vs_serialized']:.2f}x")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", type=int, default=8)
    ap.add_argument("--dim", type=int, default=262144,
                    help="flattened elements per weight tensor (512x512)")
    ap.add_argument("--readers", type=int, default=4)
    ap.add_argument("--duration", type=float, default=6.0,
                    help="seconds per read phase")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI scale: 4 models, dim 65536, 3s phases")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_concurrency.json"))
    args = ap.parse_args()
    if args.smoke:
        # Dim 65536 (256x256 tensors) keeps each read's numpy chunks large
        # enough that the GIL is released for most of the work — smaller
        # smoke scales sit in a convoy regime where 5 threads on 2 cores
        # thrash on sub-ms ops and the measurement turns bimodal.
        args.models, args.dim, args.duration = 4, 65536, 3.0
    res = run_bench(n_models=args.models, dim=args.dim,
                    n_readers=args.readers, duration_s=args.duration,
                    smoke=args.smoke)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    cr = res["concurrent_read"]
    s, c = cr["serialized"], cr["concurrent"]
    print(f"serialized ({args.readers} readers, global lock): "
          f"{s['reads_per_s']:.1f} reads/s  "
          f"p50={s['p50_ms']:.1f}ms p99={s['p99_ms']:.1f}ms")
    print(f"concurrent ({args.readers} readers + writer):     "
          f"{c['reads_per_s']:.1f} reads/s  "
          f"p50={c['p50_ms']:.1f}ms p99={c['p99_ms']:.1f}ms")
    print(f"speedup: {cr['speedup_vs_serialized']:.2f}x "
          f"(writer serialized/concurrent: "
          f"{s['writer_ops']} / {c['writer_ops']})")
    print(f"pool: {res['engine_stats']['buffer_pool']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
