"""Table 2 — CPU and I/O statistics for saving/loading one representative
model (the paper uses google/vit-base): wall/user/sys time, CPU
utilization, bytes written/read, resident memory of the loaded form."""

from __future__ import annotations

import os
import tempfile
import time

from repro.baselines import BlobStore, FileStore
from repro.core import StorageEngine

from .common import Csv
from .workload import transformer_tensors, finetune


def _du(path):
    total = 0
    for dirpath, _, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(dirpath, f))
    return total


def run(csv: Csv):
    base = transformer_tensors(d=256, layers=8, ff=1024, vocab=2048, seed=0)
    model = finetune(base, seed=1)
    with tempfile.TemporaryDirectory() as root:
        stores = {
            "neurstore": StorageEngine(root + "/ns"),
            "postgresml": BlobStore(root + "/pg"),
            "elf*": FileStore(root + "/elf"),
        }
        for sname, store in stores.items():
            store.save_model("warm", {}, base)  # warm the index/store
            c0 = os.times()
            w0 = time.perf_counter()
            d0 = _du(root)
            store.save_model("probe", {}, model)
            wall = time.perf_counter() - w0
            c1 = os.times()
            wrote = _du(root) - d0
            cpu = (c1.user - c0.user) + (c1.system - c0.system)
            csv.add(f"table2/save/{sname}", wall * 1e6,
                    f"user_s={c1.user-c0.user:.3f} sys_s={c1.system-c0.system:.3f} "
                    f"cpu_util={cpu/max(wall,1e-9):.2f} bytes_written={wrote}")
            c0 = os.times()
            w0 = time.perf_counter()
            lm = store.load_model("probe")
            tensors = lm.materialize()
            wall = time.perf_counter() - w0
            c1 = os.times()
            resident = sum(t.nbytes for t in tensors.values())
            cpu = (c1.user - c0.user) + (c1.system - c0.system)
            csv.add(f"table2/load/{sname}", wall * 1e6,
                    f"user_s={c1.user-c0.user:.3f} sys_s={c1.system-c0.system:.3f} "
                    f"cpu_util={cpu/max(wall,1e-9):.2f} resident_bytes={resident}")
        # NeurStore compression-aware resident footprint: quantized forms
        # only (paper: 165 MB vs 330 MB).
        lm = stores["neurstore"].load_model("probe")
        quantized = lm.compressed_params()
        resident_q = sum(v["base_codes"].nbytes + v["qdelta"].nbytes // 8
                         for v in quantized.values())
        csv.add("table2/load/neurstore_compressed", 0.0,
                f"resident_bytes={resident_q}")
