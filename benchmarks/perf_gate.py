"""CI perf gate: parse bench JSON artifacts, fail on ingest regressions.

The contract (docs/ingestion.md "CI perf-gate contract"):

* every bench JSON must carry a ``schema_version`` this gate understands
  (currently 2; pre-versioned files are rejected with a clear message
  rather than silently passing);
* ``BENCH_hnsw.json``: batched ingest must not be slower than the
  sequential insert loop measured in the same run —
  ``insert_batch.speedup_vs_single >= 1.0``. This is a coarse gate on
  purpose: CI runners are noisy, but a batched path that loses to
  single-insert is a real regression at any noise level (the full-scale
  acceptance bar is 3x, checked on dev machines / in BENCH_hnsw.json);
* ``BENCH_lifecycle.json``: ``batch_save.reconstruction_parity`` must be
  true, and the one-transaction batch save must not be drastically slower
  than the per-model loop (``speedup_vs_sequential >= 0.8`` — fsync timing
  on shared runners jitters, so only a clear loss fails). Its
  ``accounting`` section (schema >= 3, ISSUE 10) gates the always-on
  space ledger: accounting-on save throughput must hold
  ``on_vs_off_ratio >= 0.95`` of accounting-off, and the reported
  store-wide ``compression_ratio`` must be < 1.0 (the store actually
  compresses — the paper's headline claim);
* ``BENCH_concurrency.json``: snapshot-isolated concurrent readers must
  not lose to the global-lock serialized baseline measured in the same
  run — ``concurrent_read.speedup_vs_serialized >= 1.0``. Coarse on
  purpose (shared-runner core counts vary); the full acceptance bar is
  2x with 4 readers, checked on dev machines / in BENCH_concurrency.json.

* ``BENCH_serving.json``: the HTTP front door must hold
  ``read_vs_embedded_ratio >= 0.5`` at 4 clients with zero 5xx responses
  and a finite p99 under writer churn (ISSUE 8 acceptance bar; the smoke
  artifact is gated with the same invariants). Its ``obs`` section gates
  the always-on observability layer (ISSUE 9): obs-on served QPS must
  hold ``on_vs_off_ratio >= 0.95`` of obs-off, and the mid-churn
  ``/v1/metrics`` scrape must have parsed cleanly (``scrape_ok``).

Usage: ``python benchmarks/perf_gate.py BENCH_hnsw.json [BENCH_lifecycle.json]
[BENCH_concurrency.json] [BENCH_serving.json]``. Exits non-zero with a
one-line reason per violated check.
"""

from __future__ import annotations

import json
import sys

KNOWN_SCHEMAS = {2, 3}  # serving bumped to 3 when the obs section landed
MIN_BATCH_INGEST_SPEEDUP = 1.0
MIN_BATCH_SAVE_SPEEDUP = 0.8
MIN_CONCURRENT_READ_SPEEDUP = 1.0
MIN_CHECKSUM_RATIO = 0.9
MIN_COMPRESSED_THROUGHPUT = 0.8
MAX_COMPRESSED_BYTES_RATIO = 1.0  # strict: compressed must move FEWER bytes
MIN_SERVED_READ_RATIO = 0.5  # served QPS vs embedded, 4 clients (ISSUE 8)
MIN_OBS_ON_RATIO = 0.95  # obs-on served QPS vs obs-off (ISSUE 9)
MIN_ACCOUNTING_ON_RATIO = 0.95  # accounting-on save vs off (ISSUE 10)
MAX_COMPRESSION_RATIO = 1.0  # strict: the store must actually compress


def check_file(path: str) -> list[str]:
    with open(path) as f:
        res = json.load(f)
    errors: list[str] = []
    schema = res.get("schema_version")
    if schema not in KNOWN_SCHEMAS:
        return [f"{path}: missing/unknown schema_version {schema!r} "
                f"(gate understands {sorted(KNOWN_SCHEMAS)})"]
    if "insert_batch" in res:
        speedup = res["insert_batch"]["speedup_vs_single"]
        if speedup < MIN_BATCH_INGEST_SPEEDUP:
            errors.append(
                f"{path}: batched ingest regressed below single-insert "
                f"(speedup_vs_single={speedup:.2f} < "
                f"{MIN_BATCH_INGEST_SPEEDUP})")
        else:
            print(f"{path}: insert_batch {speedup:.2f}x vs single-insert ok")
    elif "insert" in res:
        errors.append(f"{path}: no insert_batch section — batched ingest "
                      "was not measured")
    if "batch_save" in res:
        bs = res["batch_save"]
        parity_ok = bool(bs.get("reconstruction_parity", False))
        if not parity_ok:
            errors.append(f"{path}: batch_save reconstruction parity FAILED")
        speedup = bs["speedup_vs_sequential"]
        if speedup < MIN_BATCH_SAVE_SPEEDUP:
            errors.append(
                f"{path}: save_models slower than per-model saves "
                f"(speedup_vs_sequential={speedup:.2f} < "
                f"{MIN_BATCH_SAVE_SPEEDUP})")
        elif parity_ok:
            print(f"{path}: save_models {speedup:.2f}x vs sequential ok "
                  f"(parity=True)")
    elif "delete" in res:
        errors.append(f"{path}: no batch_save section — batched save was "
                      "not measured")
    if "concurrent_read" in res:
        cr = res["concurrent_read"]
        speedup = cr["speedup_vs_serialized"]
        if speedup < MIN_CONCURRENT_READ_SPEEDUP:
            errors.append(
                f"{path}: concurrent readers lost to the global-lock "
                f"baseline (speedup_vs_serialized={speedup:.2f} < "
                f"{MIN_CONCURRENT_READ_SPEEDUP})")
        else:
            print(f"{path}: concurrent read {speedup:.2f}x vs serialized ok "
                  f"({cr['concurrent']['reads_per_s']:.0f} reads/s, "
                  f"p99={cr['concurrent']['p99_ms']:.0f}ms)")
    elif "engine_stats" in res:
        errors.append(f"{path}: no concurrent_read section — concurrency "
                      "was not measured")
    if "checksum_overhead" in res:
        co = res["checksum_overhead"]
        for which in ("save", "load"):
            ratio = co[f"{which}_ratio"]
            if ratio < MIN_CHECKSUM_RATIO:
                errors.append(
                    f"{path}: checksummed {which} throughput fell below "
                    f"{MIN_CHECKSUM_RATIO:.0%} of checksum-off "
                    f"({which}_ratio={ratio:.3f})")
            else:
                print(f"{path}: {which} with checksums {ratio:.3f}x of "
                      "checksum-off ok")
    elif "durability" in path:
        errors.append(f"{path}: no checksum_overhead section — the "
                      "integrity tax was not measured")
    if "compressed_serve" in res:
        for name, ph in res["compressed_serve"]["phases"].items():
            bytes_ratio = ph["bytes_ratio"]
            tp_ratio = ph["throughput_ratio"]
            if bytes_ratio >= MAX_COMPRESSED_BYTES_RATIO:
                errors.append(
                    f"{path}: [{name}] compressed serving moved as many "
                    f"weight bytes as materialize-then-serve "
                    f"(bytes_ratio={bytes_ratio:.3f} >= "
                    f"{MAX_COMPRESSED_BYTES_RATIO})")
            if tp_ratio < MIN_COMPRESSED_THROUGHPUT:
                errors.append(
                    f"{path}: [{name}] compressed serving throughput fell "
                    f"below {MIN_COMPRESSED_THROUGHPUT:.0%} of materialized "
                    f"(throughput_ratio={tp_ratio:.3f})")
            if not ph["int4"]["tokens_match"]:
                errors.append(
                    f"{path}: [{name}] int4 compressed decode diverged "
                    "from the materialized decode at the same precision")
            if (bytes_ratio < MAX_COMPRESSED_BYTES_RATIO
                    and tp_ratio >= MIN_COMPRESSED_THROUGHPUT
                    and ph["int4"]["tokens_match"]):
                print(f"{path}: [{name}] compressed serve "
                      f"{tp_ratio:.2f}x throughput, {bytes_ratio:.2f}x bytes "
                      f"(int4 {ph['int4']['bytes_ratio_vs_materialized']:.2f}x"
                      ", parity ok)")
    elif "compressed" in path:
        errors.append(f"{path}: no compressed_serve section — "
                      "compressed-domain serving was not measured")
    if "serving" in res:
        sv = res["serving"]
        ratio = sv["read_vs_embedded_ratio"]
        served = sv["served"]
        if ratio < MIN_SERVED_READ_RATIO:
            errors.append(
                f"{path}: served read QPS fell below "
                f"{MIN_SERVED_READ_RATIO}x embedded "
                f"(read_vs_embedded_ratio={ratio:.3f})")
        if served.get("errors_5xx", 0) != 0:
            errors.append(
                f"{path}: server returned {served['errors_5xx']} 5xx "
                "responses under writer churn (must be 0)")
        if not sv.get("p99_finite", False):
            errors.append(
                f"{path}: served p99 latency is not finite under writer "
                "churn (reads starved or hung)")
        if served.get("read_errors", 0) != 0:
            errors.append(
                f"{path}: {served['read_errors']} served reads raised "
                "client-side (must be 0)")
        if not errors:
            print(f"{path}: served {served['qps']:.0f} qps "
                  f"({ratio:.2f}x embedded, p99={served['p99_ms']:.0f}ms, "
                  f"5xx=0) ok")
    elif "serving" in path:
        errors.append(f"{path}: no serving section — the HTTP front door "
                      "was not measured")
    if "obs" in res:
        ob = res["obs"]
        oratio = ob["on_vs_off_ratio"]
        obs_errors = []
        if oratio < MIN_OBS_ON_RATIO:
            obs_errors.append(
                f"{path}: observability overhead too high — obs-on served "
                f"QPS fell below {MIN_OBS_ON_RATIO}x obs-off "
                f"(on_vs_off_ratio={oratio:.3f})")
        if not ob.get("scrape_ok", False):
            obs_errors.append(
                f"{path}: /v1/metrics scrape failed or was malformed "
                f"under load ({ob.get('on', {}).get('scrape_error', '?')})")
        if ob.get("on", {}).get("errors_5xx", 0) != 0:
            obs_errors.append(
                f"{path}: {ob['on']['errors_5xx']} 5xx responses in the "
                "obs-on phase (must be 0)")
        if not obs_errors:
            inc = ob.get("counter_inc", {})
            print(f"{path}: obs-on {oratio:.3f}x obs-off ok "
                  f"(counter inc {inc.get('enabled_ns', 0):.0f}ns, "
                  f"scrape {ob.get('scrape_families', 0)} families)")
        errors.extend(obs_errors)
    elif "serving" in path and res.get("schema_version", 0) >= 3:
        errors.append(f"{path}: no obs section — the observability "
                      "overhead was not measured")
    if "accounting" in res:
        ac = res["accounting"]
        aratio = ac["on_vs_off_ratio"]
        cratio = ac.get("compression_ratio")
        acct_errors = []
        if aratio < MIN_ACCOUNTING_ON_RATIO:
            acct_errors.append(
                f"{path}: space-accounting overhead too high — "
                f"accounting-on save throughput fell below "
                f"{MIN_ACCOUNTING_ON_RATIO}x accounting-off "
                f"(on_vs_off_ratio={aratio:.3f})")
        if cratio is None or cratio >= MAX_COMPRESSION_RATIO:
            acct_errors.append(
                f"{path}: store did not compress — reported "
                f"compression_ratio={cratio!r} must be < "
                f"{MAX_COMPRESSION_RATIO}")
        if not acct_errors:
            print(f"{path}: accounting-on {aratio:.3f}x off ok, "
                  f"compression ratio {cratio:.3f} "
                  f"({ac.get('physical_bytes', '?')} physical / "
                  f"{ac.get('logical_bytes', '?')} logical bytes)")
        errors.extend(acct_errors)
    elif "lifecycle" in path and res.get("schema_version", 0) >= 3:
        errors.append(f"{path}: no accounting section — the space ledger "
                      "was not measured")
    return errors


def main() -> None:
    paths = sys.argv[1:]
    if not paths:
        sys.exit("usage: perf_gate.py BENCH_hnsw.json [BENCH_lifecycle.json]")
    errors: list[str] = []
    for path in paths:
        errors.extend(check_file(path))
    for err in errors:
        print(f"PERF GATE: {err}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print("perf gate: all checks passed")


if __name__ == "__main__":
    main()
