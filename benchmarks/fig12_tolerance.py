"""Fig. 12 — Precision tolerance (p) sweep: accuracy change vs storage.

Real trained MLPs on a synthetic tabular task (Avazu analogue): compress at
increasing p, measure |Δaccuracy| and compressed bytes. Expect the paper's
shape: flat near zero until a task-dependent cliff."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core import StorageEngine

from .common import Csv
from .workload import (
    make_tabular_task,
    mlp_accuracy,
    mlp_to_tensors,
    tensors_to_mlp,
    train_mlp,
)


def run(csv: Csv):
    x, y = make_tabular_task(seed=0)
    xtr, ytr, xte, yte = x[:3072], y[:3072], x[3072:], y[3072:]
    models = [train_mlp(xtr, ytr, seed=s) for s in range(3)]
    base_accs = [mlp_accuracy(ws, bs, xte, yte) for ws, bs in models]
    for p in (2.0 ** -24, 1e-5, 1e-3, 1e-2, 5e-2):
        deltas, bytes_total, orig_total = [], 0, 0
        with tempfile.TemporaryDirectory() as root:
            eng = StorageEngine(root, tolerance=p)
            for i, (ws, bs) in enumerate(models):
                t = mlp_to_tensors(ws, bs)
                rep = eng.save_model(f"m{i}", {}, t)
                orig_total += rep.original_bytes
                back = eng.load_model(f"m{i}").materialize()
                ws2, bs2 = tensors_to_mlp(back)
                acc = mlp_accuracy(ws2, bs2, xte, yte)
                deltas.append(abs(acc - base_accs[i]))
            bytes_total = eng.storage_bytes()["total"]
        csv.add(f"fig12/p{p:.0e}", 0.0,
                f"acc_change={np.mean(deltas)*100:.3f}% "
                f"bytes={bytes_total} ratio={orig_total/bytes_total:.2f}")
