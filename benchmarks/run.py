"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (repo convention).

``--smoke`` is passed through to modules whose ``run`` accepts it (the
trajectory benchmarks: hnsw, lifecycle) — the CI bench-smoke job uses the
same flag on the standalone scripts, which additionally write their
``BENCH_*.json`` files with a ``schema_version`` field so the perf gate
(``benchmarks/perf_gate.py``) can parse them stably.
"""

from __future__ import annotations

import inspect
import sys


def main() -> None:
    from .common import Csv
    from . import (fig7_e2e, fig8_throughput, fig9_compression, fig10_tau,
                   fig11_flexible, fig12_tolerance, fig13_accuracy,
                   table2_stats, pipeline_bench, hnsw_bench, lifecycle_bench,
                   concurrency_bench, durability_bench, compressed_serve_bench,
                   serving_bench)

    args = [a for a in sys.argv[1:] if a != "--smoke"]
    smoke = "--smoke" in sys.argv[1:]
    only = args[0] if args else None
    modules = {
        "fig7": fig7_e2e, "fig8": fig8_throughput, "fig9": fig9_compression,
        "fig10": fig10_tau, "fig11": fig11_flexible, "fig12": fig12_tolerance,
        "fig13": fig13_accuracy, "table2": table2_stats,
        "pipeline": pipeline_bench, "hnsw": hnsw_bench,
        "lifecycle": lifecycle_bench, "concurrency": concurrency_bench,
        "durability": durability_bench,
        "compressed_serve": compressed_serve_bench,
        "serving": serving_bench,
    }
    csv = Csv()
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if only and name != only:
            continue
        if smoke and "smoke" in inspect.signature(mod.run).parameters:
            mod.run(csv, smoke=True)
        else:
            mod.run(csv)
        csv.emit()
        csv.rows.clear()


if __name__ == "__main__":
    main()
