"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (repo convention).
"""

from __future__ import annotations

import sys


def main() -> None:
    from .common import Csv
    from . import (fig7_e2e, fig8_throughput, fig9_compression, fig10_tau,
                   fig11_flexible, fig12_tolerance, fig13_accuracy,
                   table2_stats, pipeline_bench, hnsw_bench, lifecycle_bench)

    only = sys.argv[1] if len(sys.argv) > 1 else None
    modules = {
        "fig7": fig7_e2e, "fig8": fig8_throughput, "fig9": fig9_compression,
        "fig10": fig10_tau, "fig11": fig11_flexible, "fig12": fig12_tolerance,
        "fig13": fig13_accuracy, "table2": table2_stats,
        "pipeline": pipeline_bench, "hnsw": hnsw_bench,
        "lifecycle": lifecycle_bench,
    }
    csv = Csv()
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if only and name != only:
            continue
        mod.run(csv)
        csv.emit()
        csv.rows.clear()


if __name__ == "__main__":
    main()
