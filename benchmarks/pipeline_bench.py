"""§4.3.3 pipelining — 3-stage load/dequant/compute overlap vs sequential."""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import PipelineLoader, StorageEngine

from .common import Csv
from .workload import transformer_tensors


def run(csv: Csv):
    model = transformer_tensors(d=256, layers=8, ff=1024, vocab=2048)
    with tempfile.TemporaryDirectory() as root:
        eng = StorageEngine(root)
        eng.save_model("m", {}, model)

        def consume(name, tensor):  # stand-in matmul per tensor
            if tensor.ndim == 2:
                np.dot(np.ones((8, tensor.shape[0]), np.float32), tensor)

        # Sequential: load+dequant then compute.
        t0 = time.perf_counter()
        lm = eng.load_model("m")
        for name in lm.tensor_names():
            consume(name, lm.tensor(name))
        seq_s = time.perf_counter() - t0
        # Pipelined.
        lm = eng.load_model("m")
        stats = PipelineLoader(lm).run(consume)
        csv.add("pipeline/sequential", seq_s * 1e6, "")
        csv.add("pipeline/pipelined", stats["wall"] * 1e6,
                f"io_s={stats['io']:.3f} dequant_s={stats['dequant']:.3f} "
                f"compute_s={stats['compute']:.3f} "
                f"speedup={seq_s/stats['wall']:.2f}x")
