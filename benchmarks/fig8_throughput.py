"""Fig. 8 — Save/load throughput under concurrent clients + total storage.

Threads (1..8) issue save then load requests against NeurStore /
PostgresML-blob / ELF*-file stores; report queries-per-minute and the
resulting storage bytes (Fig. 8c)."""

from __future__ import annotations

import tempfile
import threading
import time

from repro.baselines import BlobStore, FileStore
from repro.core import StorageEngine

from .common import Csv
from .workload import model_collection, collection_bytes


def _run_clients(n_clients, jobs):
    """Run callables from ``jobs`` split across n threads; return seconds."""
    chunks = [jobs[i::n_clients] for i in range(n_clients)]
    t0 = time.perf_counter()
    threads = [threading.Thread(target=lambda c=c: [j() for j in c])
               for c in chunks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def run(csv: Csv):
    collection = model_collection(n_families=3, n_variants=3, n_unrelated=2)
    orig = collection_bytes(collection)
    for n_clients in (1, 4, 8):
        with tempfile.TemporaryDirectory() as root:
            stores = {
                "neurstore": StorageEngine(root + "/ns"),
                "postgresml": BlobStore(root + "/pg"),
                "elf*": FileStore(root + "/elf"),
            }
            for sname, store in stores.items():
                saves = [lambda nm=nm, t=t: store.save_model(nm, {}, t)
                         for nm, t in collection]
                dt = _run_clients(n_clients, saves)
                qpm = len(collection) / dt * 60
                csv.add(f"fig8a/write/{sname}/clients{n_clients}",
                        dt * 1e6 / len(collection), f"qpm={qpm:.1f}")
                loads = [lambda nm=nm: store.load_model(nm).materialize()
                         for nm, _ in collection]
                dt = _run_clients(n_clients, loads)
                qpm = len(collection) / dt * 60
                csv.add(f"fig8b/read/{sname}/clients{n_clients}",
                        dt * 1e6 / len(collection), f"qpm={qpm:.1f}")
                if n_clients == 1:
                    s = store.storage_bytes()
                    csv.add(f"fig8c/storage/{sname}", 0.0,
                            f"bytes={s['total']} ratio={orig/s['total']:.2f}")
