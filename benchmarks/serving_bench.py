"""Serving benchmark: the HTTP front door vs embedded access (ISSUE 8).

Two phases over an identical just-ingested store, both under writer
churn (one writer replacing models while N readers loop):

* **embedded** — N reader threads calling ``engine.load_model().
  materialize()`` in-process: the ceiling the network path is judged
  against;
* **served** — the same N readers as ``StoreClient`` instances against a
  ``ModelStoreServer`` on the same machine, each read a full streamed
  download (decode + CRC + materialize); the writer churns through the
  client too, so the upload path, admission checks and quota gate are
  all on the clock.

Reported per phase: aggregate QPS and per-read p50/p99 latency; the
served phase also reports the server's 5xx count and the admission
policy's shed count. The acceptance bar (full-scale run recorded in
``BENCH_serving.json``): served read QPS ≥ 0.5x embedded at 4 clients,
zero 5xx, finite p99. The CI gate (``benchmarks/perf_gate.py``)
enforces the same invariants on the smoke artifact.

A third **obs** section prices the observability layer (ISSUE 9), which
is on by default and must be ~free: the served phase is re-run with
metrics+tracing enabled vs disabled (``on_vs_off_ratio`` gated ≥ 0.95),
a microbench times one counter increment in each mode, and the obs-on
server's ``/v1/metrics`` is scraped mid-churn and pushed through the
strict Prometheus parser (``scrape_ok`` gated true — malformed
exposition fails the bench, not just the consumer).

Run: ``PYTHONPATH=src python benchmarks/serving_bench.py [--clients 4]``;
``--smoke`` runs the small CI scale. Or via the runner:
``PYTHONPATH=src python -m benchmarks.run serving [--smoke]``.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import tempfile
import threading
import time
import urllib.request

import numpy as np

from repro.core.engine import StorageEngine
from repro.obs.metrics import (
    default_registry,
    parse_prometheus_text,
    set_enabled,
)
from repro.server import AdmissionPolicy, ModelStoreServer, StoreClient
from repro.store import SaveRequest
from repro.store.errors import AdmissionRejectedError

# Bumped whenever the JSON layout changes (parsed by benchmarks/perf_gate.py).
SCHEMA_VERSION = 3

TENANT = "bench"


def _models(n: int, dim: int, rng: np.random.Generator) -> list[tuple]:
    """Dissimilar models with matmul-sized tensors (serving-shaped reads)."""
    side = int(dim ** 0.5)
    out = []
    for i in range(n):
        tensors = {
            "w0": rng.normal(0, 5.0, (side, side)).astype(np.float32),
            "w1": rng.normal(0, 5.0, (side, side)).astype(np.float32),
            "b": rng.normal(0, 5.0, (side,)).astype(np.float32),
        }
        out.append((f"m{i}", {"layer": i}, tensors))
    return out


def _percentile(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def _churn_tensors(rng: np.random.Generator, dim: int) -> dict:
    side = int(dim ** 0.5)
    return {
        "w0": rng.normal(0, 5.0, (side, side)).astype(np.float32),
        "w1": rng.normal(0, 5.0, (side, side)).astype(np.float32),
        "b": rng.normal(0, 5.0, (side,)).astype(np.float32),
    }


def _run_phase(read_fn, write_fn, names: list[str], n_clients: int,
               duration_s: float, write_interval_s: float) -> dict:
    """N reader loops + one pacing writer; returns QPS + latency stats."""
    stop = threading.Event()
    lat: list[list[float]] = [[] for _ in range(n_clients)]
    counters = {"writes": 0, "rejected": 0, "read_errors": 0}

    def reader(slot: int):
        rng = np.random.default_rng(slot)
        my = lat[slot]
        while not stop.is_set():
            name = names[int(rng.integers(len(names)))]
            t0 = time.perf_counter()
            try:
                read_fn(slot, name)
            except KeyError:
                continue  # raced a replace mid-commit
            except Exception:  # noqa: BLE001 — counted, gate catches nonzero
                counters["read_errors"] += 1
                continue
            my.append(time.perf_counter() - t0)

    def writer():
        wrng = np.random.default_rng(99)
        k = 0
        while not stop.wait(write_interval_s):
            name = names[k % len(names)]
            try:
                write_fn(name, wrng)
                counters["writes"] += 1
            except AdmissionRejectedError:
                counters["rejected"] += 1
            k += 1

    threads = [threading.Thread(target=reader, args=(s,))
               for s in range(n_clients)]
    wt = threading.Thread(target=writer)
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    wt.start()
    time.sleep(duration_s)
    wall = time.perf_counter() - t_start
    stop.set()
    for t in threads:
        t.join()
    wt.join()
    all_lat = [x for slot in lat for x in slot]
    return {
        "reads": len(all_lat),
        "wall_s": wall,
        "qps": len(all_lat) / wall,
        "p50_ms": _percentile(all_lat, 50) * 1e3,
        "p99_ms": _percentile(all_lat, 99) * 1e3,
        **counters,
    }


def run_bench(n_models: int = 8, dim: int = 262144, n_clients: int = 4,
              duration_s: float = 6.0, write_interval_s: float = 0.25,
              reps: int = 2, smoke: bool = False) -> dict:
    rng = np.random.default_rng(0)
    specs = _models(n_models, dim, rng)
    names = [n for n, _, _ in specs]

    def embedded_phase() -> dict:
        with tempfile.TemporaryDirectory() as root:
            engine = StorageEngine(root)
            engine.save_models(specs)

            def read(_slot, name):
                engine.load_model(name).materialize()

            def write(name, wrng):
                arch = {"layer": name}
                engine.replace_model(name, arch, _churn_tensors(wrng, dim))

            res = _run_phase(read, write, names, n_clients, duration_s,
                             write_interval_s)
            engine.close()
            return res

    def served_phase(obs_enabled: bool = True,
                     scrape: bool = False) -> dict:
        set_enabled(obs_enabled)
        try:
            with tempfile.TemporaryDirectory() as root:
                engine = StorageEngine(root)
                engine.save_models(
                    [(f"{TENANT}/{n}", a, t) for n, a, t in specs])
                server = ModelStoreServer(
                    engine, admission=AdmissionPolicy()).start()
                clients = [StoreClient(server.host, server.port,
                                       tenant=TENANT)
                           for _ in range(n_clients)]
                writer_client = StoreClient(server.host, server.port,
                                            tenant=TENANT)

                def read(slot, name):
                    clients[slot].load(name).materialize()

                def write(name, wrng):
                    writer_client.replace(SaveRequest(
                        name, _churn_tensors(wrng, dim),
                        architecture={"layer": name}))

                scrape_info = {}
                if scrape:
                    # Scrape mid-churn on a side thread so the exposition
                    # is rendered under the same concurrent mutation the
                    # gate cares about, not from a quiesced registry.
                    def scraper():
                        time.sleep(duration_s / 2)
                        url = (f"http://{server.host}:{server.port}"
                               "/v1/metrics")
                        try:
                            with urllib.request.urlopen(url) as resp:
                                fams = parse_prometheus_text(
                                    resp.read().decode("utf-8"))
                            scrape_info["scrape_ok"] = True
                            scrape_info["scrape_families"] = len(fams)
                        except Exception as exc:  # noqa: BLE001 — gated
                            scrape_info["scrape_ok"] = False
                            scrape_info["scrape_error"] = repr(exc)
                    st = threading.Thread(target=scraper)
                    st.start()
                res = _run_phase(read, write, names, n_clients, duration_s,
                                 write_interval_s)
                if scrape:
                    st.join()
                    res.update(scrape_info)
                res["errors_5xx"] = server.server_stats()["errors_5xx"]
                res["rejected_429"] = server.admission.stats()["rejected"]
                server.stop()
                engine.close()
                return res
        finally:
            set_enabled(True)

    # Best-of-N per mode (same rationale as concurrency_bench: one
    # descheduled thread on a shared runner wedges a whole phase).
    emb_reps = [embedded_phase() for _ in range(reps)]
    srv_reps = [served_phase() for _ in range(reps)]
    embedded = max(emb_reps, key=lambda r: r["qps"])
    served = max(srv_reps, key=lambda r: r["qps"])
    ratio = served["qps"] / embedded["qps"] if embedded["qps"] else 0.0

    # Observability overhead: same served workload, obs on (with a
    # mid-churn /v1/metrics scrape) vs off. Interleaved on/off reps so a
    # runner slowdown mid-bench penalizes both modes equally.
    on_reps, off_reps = [], []
    for _ in range(reps):
        on_reps.append(served_phase(obs_enabled=True, scrape=True))
        off_reps.append(served_phase(obs_enabled=False))
    obs_on = max(on_reps, key=lambda r: r["qps"])
    obs_off = max(off_reps, key=lambda r: r["qps"])
    obs_ratio = obs_on["qps"] / obs_off["qps"] if obs_off["qps"] else 0.0

    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "smoke" if smoke else "full",
        "config": {
            "n_models": n_models,
            "dim": dim,
            "n_clients": n_clients,
            "duration_s": duration_s,
            "write_interval_s": write_interval_s,
            "reps": reps,
        },
        "serving": {
            "embedded": embedded,
            "served": served,
            "read_vs_embedded_ratio": ratio,
            "p99_finite": math.isfinite(served["p99_ms"]),
            "all_reps": {
                "embedded_qps": [r["qps"] for r in emb_reps],
                "served_qps": [r["qps"] for r in srv_reps],
            },
        },
        "obs": {
            "on": obs_on,
            "off": obs_off,
            "on_vs_off_ratio": obs_ratio,
            "scrape_ok": bool(obs_on.get("scrape_ok", False)),
            "scrape_families": obs_on.get("scrape_families", 0),
            "counter_inc": _counter_microbench(),
            "all_reps": {
                "on_qps": [r["qps"] for r in on_reps],
                "off_qps": [r["qps"] for r in off_reps],
            },
        },
    }


def _counter_microbench(n: int = 200_000) -> dict:
    """ns per counter increment, metrics enabled vs disabled.

    Prices the primitive every hot-path instrumentation site pays; the
    disabled number is what ``set_enabled(False)`` buys back.
    """
    c = default_registry().counter(
        "neurstore_bench_counter_total",
        "Bench-only counter (the microbench's scratch cell).")
    out = {}
    for mode, enabled in (("enabled", True), ("disabled", False)):
        set_enabled(enabled)
        t0 = time.perf_counter()
        for _ in range(n):
            c.inc()
        out[f"{mode}_ns"] = (time.perf_counter() - t0) / n * 1e9
    set_enabled(True)
    return out


def run(csv, smoke: bool = False):
    """Runner entry point (quick scale, CSV convention)."""
    res = run_bench(n_models=4, dim=65536, n_clients=4,
                    duration_s=1.0 if smoke else 2.0, reps=1, smoke=smoke)
    sv = res["serving"]
    csv.add("serving/embedded_read", sv["embedded"]["p50_ms"] * 1e3,
            f"qps={sv['embedded']['qps']:.0f}")
    csv.add("serving/served_read", sv["served"]["p50_ms"] * 1e3,
            f"qps={sv['served']['qps']:.0f},"
            f"ratio={sv['read_vs_embedded_ratio']:.2f}x")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", type=int, default=8)
    ap.add_argument("--dim", type=int, default=262144,
                    help="flattened elements per weight tensor (512x512)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--duration", type=float, default=6.0,
                    help="seconds per phase")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI scale: 4 models, dim 65536, 3s phases")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serving.json"))
    args = ap.parse_args()
    if args.smoke:
        # Same scale floor as concurrency_bench: 256x256 tensors keep each
        # read numpy-dominated so the HTTP hop is measured against real
        # materialization work, not sub-ms cache hits.
        args.models, args.dim, args.duration = 4, 65536, 3.0
    res = run_bench(n_models=args.models, dim=args.dim,
                    n_clients=args.clients, duration_s=args.duration,
                    smoke=args.smoke)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    sv = res["serving"]
    e, s = sv["embedded"], sv["served"]
    print(f"embedded ({args.clients} threads + writer): {e['qps']:.1f} qps  "
          f"p50={e['p50_ms']:.1f}ms p99={e['p99_ms']:.1f}ms")
    print(f"served   ({args.clients} clients + writer): {s['qps']:.1f} qps  "
          f"p50={s['p50_ms']:.1f}ms p99={s['p99_ms']:.1f}ms  "
          f"5xx={s['errors_5xx']} shed={s['rejected_429']}")
    print(f"served/embedded: {sv['read_vs_embedded_ratio']:.2f}x")
    ob = res["obs"]
    print(f"obs on/off: {ob['on']['qps']:.1f}/{ob['off']['qps']:.1f} qps "
          f"({ob['on_vs_off_ratio']:.3f}x)  counter inc "
          f"{ob['counter_inc']['enabled_ns']:.0f}ns on / "
          f"{ob['counter_inc']['disabled_ns']:.0f}ns off  "
          f"scrape_ok={ob['scrape_ok']} "
          f"({ob['scrape_families']} families)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
