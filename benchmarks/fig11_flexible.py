"""Fig. 11 — Flexible model loading: full vs 8-bit throughput, bits saved,
payload bytes actually read (partial bit-plane I/O)."""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import StorageEngine
from repro.core.pages import read_record

from .common import Csv
from .workload import model_collection


def run(csv: Csv):
    collection = model_collection(n_families=3, n_variants=4, n_unrelated=1)
    with tempfile.TemporaryDirectory() as root:
        eng = StorageEngine(root)
        for nm, ts in collection:
            eng.save_model(nm, {}, ts)
        names = [nm for nm, _ in collection]
        for mode, bits in (("full", None), ("flex8", 8)):
            t0 = time.perf_counter()
            for nm in names:
                eng.load_model(nm, bits=bits).materialize()
            dt = time.perf_counter() - t0
            csv.add(f"fig11a/load/{mode}", dt * 1e6 / len(names),
                    f"models_per_min={len(names)/dt*60:.1f}")
        # Bits saved per tensor + flexible-vs-full deviation.
        saved, diffs, payload_full, payload_flex = [], [], 0, 0
        for nm in names:
            lm_full = eng.load_model(nm)
            lm_flex = eng.load_model(nm, bits=8)
            for tname in lm_full.tensor_names():
                rec = lm_full.record(tname)
                saved.append(max(rec.meta.nbit - 8, 0))
                payload_full += rec.payload_nbytes
                payload_flex += lm_flex.record(tname).payload_nbytes
                d = np.abs(lm_full.tensor(tname) - lm_flex.tensor(tname))
                diffs.append(float(d.mean()))
        csv.add("fig11b/bits_saved", 0.0,
                f"mean={np.mean(saved):.1f} zero_frac={np.mean(np.array(saved)==0):.2f}")
        csv.add("fig11b/precision", 0.0,
                f"mean_abs_diff={np.mean(diffs):.2e}")
        csv.add("fig11b/payload", 0.0,
                f"full={payload_full} flex8={payload_flex} "
                f"io_saved={1-payload_flex/payload_full:.2f}")
