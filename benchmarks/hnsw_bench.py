"""Tensor-index hot-path benchmark: vectorized HNSW vs the frozen seed.

Measures, at the acceptance scale (1000 vertices, dim 4096 by default):

* **insert throughput** — seed (`repro.core.hnsw_ref.SeedHNSWIndex`,
  per-insert concatenate + set visited + dense distance) vs the rebuilt
  `repro.core.hnsw.HNSWIndex` (amortized arrays + bitset + decomposed L2);
* **batched ingest** — `HNSWIndex.insert_batch` (one quantization sweep,
  shared entry descent, batch-wide distance matrix through the kernel
  dispatch seam) vs the sequential insert loop — the ISSUE 3 tentpole
  number; the CI perf gate fails when `insert_batch.speedup_vs_single`
  drops below 1.0 (see `benchmarks/perf_gate.py`);
* **k-NN search latency** over a fixed query batch, seed vs new;
* **batched distance primitive** — one query against every resident vertex:
  the seed's dense dequantize-and-einsum vs `HNSWIndex.batch_distances`
  (float32 gemv + cached per-vertex norms);
* **save_model / load_model wall time** through the grouped, dirty-aware
  engine pipeline, with the index-cache stats (hits/misses/evictions/
  dirty flushes) that the dirty-flag tracking exposes.

Writes ``BENCH_hnsw.json`` at the repo root (``schema_version`` documents
the layout the CI gate parses; bump it on breaking changes) and prints the
usual ``name,us_per_call,derived`` CSV rows.

Run: ``PYTHONPATH=src python benchmarks/hnsw_bench.py [--n 1000] [--dim 4096]``;
``--smoke`` runs the small CI scale (<1 min). Or via the runner:
``PYTHONPATH=src python -m benchmarks.run hnsw [--smoke]`` (quick scale).
"""

from __future__ import annotations

import argparse
import json
import math
import os
import tempfile
import time

import numpy as np

from repro.core.engine import StorageEngine
from repro.core.hnsw import HNSWIndex
from repro.core.hnsw_ref import SeedHNSWIndex, quantized_l2_batch_dense

# Bumped whenever the JSON layout changes: the CI perf gate
# (benchmarks/perf_gate.py) refuses files it does not understand.
SCHEMA_VERSION = 2


def _bench_index(cls, data: np.ndarray, queries: np.ndarray, ef: int = 32):
    dim = data.shape[1]
    idx = cls(dim, seed=0)
    t0 = time.perf_counter()
    for row in data:
        idx.insert(row)
    insert_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for q in queries:
        idx.search(q, k=5, ef=ef)
    search_s = time.perf_counter() - t0
    return idx, insert_s, search_s


def _bench_batch_distance(new_idx: HNSWIndex, seed_idx: SeedHNSWIndex,
                          queries: np.ndarray, reps: int = 3):
    n = len(seed_idx)
    t0 = time.perf_counter()
    for _ in range(reps):
        for q in queries:
            quantized_l2_batch_dense(
                q, seed_idx._codes, seed_idx._scales, seed_idx._zps, seed_idx._mids
            )
    dense_s = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        for q in queries:
            new_idx.batch_distances(q)
    deco_s = (time.perf_counter() - t0) / reps
    # Sanity: same distances (decomposed vs dense oracle).
    q = queries[0]
    np.testing.assert_allclose(
        new_idx.batch_distances(q)[:n],
        quantized_l2_batch_dense(q, seed_idx._codes, seed_idx._scales,
                                 seed_idx._zps, seed_idx._mids),
        rtol=1e-6,
    )
    return dense_s, deco_s


def _bench_engine(dim: int, rng: np.random.Generator):
    """save/load wall time on a base model + fine-tunes + one outlier."""
    base = {
        f"layer{i}/w": rng.normal(0, 0.02, dim).astype(np.float32)
        for i in range(4)
    }
    base["head/w"] = rng.normal(0, 0.02, dim // 4).astype(np.float32)
    out = {"save_s": [], "load_s": []}
    with tempfile.TemporaryDirectory() as root:
        eng = StorageEngine(root)
        r = eng.save_model("base", {}, base)
        out["save_s"].append(r.seconds)
        for i in range(3):
            ft = {k: v + rng.normal(0, 1e-5, v.shape).astype(np.float32)
                  for k, v in base.items()}
            out["save_s"].append(eng.save_model(f"ft{i}", {}, ft).seconds)
        other = {k: rng.normal(0, 5.0, v.shape).astype(np.float32)
                 for k, v in base.items()}
        out["save_s"].append(eng.save_model("other", {}, other).seconds)
        for name in ("base", "ft0", "other"):
            t0 = time.perf_counter()
            eng.load_model(name).materialize()
            out["load_s"].append(time.perf_counter() - t0)
        out["cache_stats"] = eng.index_cache.stats()
    return out


def _bench_insert_batch(data: np.ndarray, single_insert_s: float,
                        reps: int = 1):
    """Batched ingest vs the sequential insert loop (same data, same seed).

    ``reps > 1`` (smoke mode) keeps the fastest of several fresh builds —
    shared CI runners jitter by multiples at sub-second scales, and the
    gate needs the steady-state number, not a scheduling hiccup.
    """
    n, dim = data.shape
    batch_s = math.inf
    for _ in range(max(reps, 1)):
        idx = HNSWIndex(dim, seed=0)
        t0 = time.perf_counter()
        idx.insert_batch(data)
        batch_s = min(batch_s, time.perf_counter() - t0)
    # Distance parity vs the seed oracle on the batch-built index (the
    # acceptance bar travels with the number it certifies).
    q = data[0] + 1.0
    np.testing.assert_allclose(
        idx.batch_distances(q),
        quantized_l2_batch_dense(
            q, idx._codes[:n], idx._scales[:n], idx._zps[:n], idx._mids[:n]
        ),
        rtol=1e-6,
    )
    return {
        "seconds": batch_s,
        "vertices_per_s": n / batch_s,
        "single_insert_s": single_insert_s,
        "speedup_vs_single": single_insert_s / batch_s,
    }


def run_bench(n: int = 1000, dim: int = 4096, n_queries: int = 50,
              seed: int = 0, smoke: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    data = rng.normal(0, 1, (n, dim))
    queries = rng.normal(0, 1, (n_queries, dim))

    new_idx, new_ins, new_sea = _bench_index(HNSWIndex, data, queries)
    seed_idx, seed_ins, seed_sea = _bench_index(SeedHNSWIndex, data, queries)
    insert_batch = _bench_insert_batch(data, new_ins,
                                       reps=3 if smoke else 1)
    dense_s, deco_s = _bench_batch_distance(
        new_idx, seed_idx, queries[: min(8, n_queries)]
    )
    engine = _bench_engine(dim, rng)

    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "smoke" if smoke else "full",
        "config": {"n": n, "dim": dim, "n_queries": n_queries, "seed": seed},
        "insert_batch": insert_batch,
        "insert": {
            "seed_s": seed_ins,
            "new_s": new_ins,
            "seed_vertices_per_s": n / seed_ins,
            "new_vertices_per_s": n / new_ins,
            "speedup": seed_ins / new_ins,
        },
        "knn_search": {
            "seed_s": seed_sea,
            "new_s": new_sea,
            "seed_qps": n_queries / seed_sea,
            "new_qps": n_queries / new_sea,
            "speedup": seed_sea / new_sea,
        },
        "batch_distance": {
            "dense_s_per_query": dense_s / min(8, n_queries),
            "decomposed_s_per_query": deco_s / min(8, n_queries),
            "speedup": dense_s / deco_s,
        },
        "save_load": engine,
    }


def run(csv, smoke: bool = False):
    """Runner entry point (quick scale, CSV convention)."""
    res = run_bench(n=200, dim=512 if smoke else 1024, n_queries=20,
                    smoke=smoke)
    ins = res["insert"]
    ib = res["insert_batch"]
    sea = res["knn_search"]
    bd = res["batch_distance"]
    csv.add("hnsw/insert", ins["new_s"] / res["config"]["n"] * 1e6,
            f"speedup_vs_seed={ins['speedup']:.2f}x")
    csv.add("hnsw/insert_batch", ib["seconds"] / res["config"]["n"] * 1e6,
            f"speedup_vs_single={ib['speedup_vs_single']:.2f}x")
    csv.add("hnsw/knn_search", sea["new_s"] / res["config"]["n_queries"] * 1e6,
            f"speedup_vs_seed={sea['speedup']:.2f}x")
    csv.add("hnsw/batch_distance", bd["decomposed_s_per_query"] * 1e6,
            f"speedup_vs_seed={bd['speedup']:.2f}x")
    csv.add("hnsw/save_model", float(np.mean(res["save_load"]["save_s"])) * 1e6,
            f"dirty_flushes={res['save_load']['cache_stats']['dirty_flushes']}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI scale (<1 min): 200 vertices, dim 512")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_hnsw.json"))
    args = ap.parse_args()
    if args.smoke:
        args.n, args.dim, args.queries = 200, 512, 10
    res = run_bench(n=args.n, dim=args.dim, n_queries=args.queries,
                    smoke=args.smoke)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    ins, sea, bd = res["insert"], res["knn_search"], res["batch_distance"]
    ib = res["insert_batch"]
    print(f"insert:        {ins['seed_s']:.2f}s -> {ins['new_s']:.2f}s "
          f"({ins['speedup']:.2f}x, {ins['new_vertices_per_s']:.0f} v/s)")
    print(f"insert_batch:  {ib['single_insert_s']:.2f}s -> "
          f"{ib['seconds']:.2f}s ({ib['speedup_vs_single']:.2f}x vs single, "
          f"{ib['vertices_per_s']:.0f} v/s)")
    print(f"knn search:    {sea['seed_s']:.2f}s -> {sea['new_s']:.2f}s "
          f"({sea['speedup']:.2f}x)")
    print(f"batch dist:    {bd['dense_s_per_query']*1e3:.2f}ms -> "
          f"{bd['decomposed_s_per_query']*1e3:.2f}ms ({bd['speedup']:.2f}x)")
    print(f"save wall (s): {[round(s, 4) for s in res['save_load']['save_s']]}")
    print(f"load wall (s): {[round(s, 4) for s in res['save_load']['load_s']]}")
    print(f"cache stats:   {res['save_load']['cache_stats']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
