"""Shared benchmark workloads: synthetic model collections with controlled
lineage (paper §6.1.1 analogue) and small *trained* models for accuracy
benchmarks (Figs. 12/13 analogue).

The paper's 800-HuggingFace-model corpus is offline-unavailable; we
synthesize collections that reproduce its structure: families of fine-tuned
variants around shared pretrained bases (deltas of controllable magnitude,
fine-tuning restricted to a subset of layers) plus unrelated models.
"""

from __future__ import annotations

import numpy as np

RNG = np.random.default_rng(2025)


def mlp_tensors(widths=(64, 256, 256, 8), seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    t = {}
    for i, (a, b) in enumerate(zip(widths[:-1], widths[1:])):
        t[f"layer{i}/w"] = rng.normal(0, scale, (a, b)).astype(np.float32)
        t[f"layer{i}/b"] = np.zeros(b, np.float32)
    return t


def transformer_tensors(d=128, layers=4, ff=512, vocab=512, seed=0):
    rng = np.random.default_rng(seed)
    t = {"embed": rng.normal(0, 0.02, (vocab, d)).astype(np.float32)}
    for i in range(layers):
        for nm, shape in [("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)),
                          ("wo", (d, d)), ("w1", (d, ff)), ("w2", (ff, d)),
                          ("ln1", (d,)), ("ln2", (d,))]:
            init = (np.ones(shape) if nm.startswith("ln")
                    else rng.normal(0, d ** -0.5, shape))
            t[f"l{i}/{nm}"] = init.astype(np.float32)
    t["head"] = rng.normal(0, d ** -0.5, (d, vocab)).astype(np.float32)
    return t


def finetune(tensors, seed, sigma=5e-4, layer_fraction=0.5):
    """Perturb a subset of layers (fine-tuning often touches few layers)."""
    rng = np.random.default_rng(seed)
    names = sorted({k.split("/")[0] for k in tensors})
    touched = set(rng.choice(names, max(1, int(len(names) * layer_fraction)),
                             replace=False))
    out = {}
    for k, v in tensors.items():
        if k.split("/")[0] in touched:
            out[k] = (v + rng.normal(0, sigma, v.shape)).astype(np.float32)
        else:
            out[k] = v
    return out


def model_collection(n_families=4, n_variants=4, n_unrelated=4,
                     kind="mixed", sigma=5e-4):
    """[(name, tensors)] — families of fine-tunes + unrelated models."""
    out = []
    makers = {"mlp": mlp_tensors, "transformer": transformer_tensors}
    kinds = (["mlp", "transformer"] if kind == "mixed" else [kind])
    for f in range(n_families):
        mk = makers[kinds[f % len(kinds)]]
        base = mk(seed=100 + f)
        out.append((f"fam{f}/base", base))
        for v in range(n_variants):
            out.append((f"fam{f}/ft{v}",
                        finetune(base, seed=1000 + f * 50 + v, sigma=sigma)))
    for u in range(n_unrelated):
        mk = makers[kinds[u % len(kinds)]]
        out.append((f"solo{u}", mk(seed=9000 + u)))
    return out


def collection_bytes(collection) -> int:
    return sum(sum(v.size * 4 for v in t.values()) for _, t in collection)


# ------------------------------------------------------------ trained models
def make_tabular_task(seed=0, n=4096, d=64, classes=8):
    """Avazu-like synthetic CTR/classification task with a planted MLP."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    w_true = rng.normal(0, 1, (d, classes))
    y = (x @ w_true + 0.5 * rng.normal(0, 1, (n, classes))).argmax(-1)
    return x, y.astype(np.int32)


def train_mlp(x, y, widths=(64, 128, 8), steps=300, seed=0, lr=0.05):
    """Tiny numpy MLP trained with softmax CE — a *real* trained model for
    the accuracy-vs-tolerance benchmarks."""
    rng = np.random.default_rng(seed)
    ws = [rng.normal(0, a ** -0.5, (a, b)).astype(np.float32)
          for a, b in zip(widths[:-1], widths[1:])]
    bs = [np.zeros(b, np.float32) for b in widths[1:]]

    def fwd(params, xb):
        ws_, bs_ = params
        h = xb
        acts = [h]
        for i, (w, b) in enumerate(zip(ws_, bs_)):
            h = h @ w + b
            if i < len(ws_) - 1:
                h = np.maximum(h, 0)
            acts.append(h)
        return h, acts

    n = x.shape[0]
    for step in range(steps):
        idx = rng.integers(0, n, 256)
        xb, yb = x[idx], y[idx]
        logits, acts = fwd((ws, bs), xb)
        logits = logits - logits.max(-1, keepdims=True)
        p = np.exp(logits)
        p /= p.sum(-1, keepdims=True)
        g = p
        g[np.arange(len(yb)), yb] -= 1
        g /= len(yb)
        # backprop
        for i in reversed(range(len(ws))):
            a_in = acts[i]
            gw = a_in.T @ g
            gb = g.sum(0)
            if i > 0:
                g = (g @ ws[i].T) * (acts[i] > 0)
            ws[i] -= lr * gw
            bs[i] -= lr * gb
    return ws, bs


def mlp_accuracy(ws, bs, x, y) -> float:
    h = x
    for i, (w, b) in enumerate(zip(ws, bs)):
        h = h @ w + b
        if i < len(ws) - 1:
            h = np.maximum(h, 0)
    return float((h.argmax(-1) == y).mean())


def mlp_to_tensors(ws, bs):
    t = {}
    for i, (w, b) in enumerate(zip(ws, bs)):
        t[f"l{i}/w"] = w
        t[f"l{i}/b"] = b
    return t


def tensors_to_mlp(t):
    n = len(t) // 2
    return ([t[f"l{i}/w"] for i in range(n)], [t[f"l{i}/b"] for i in range(n)])
