"""Model-lifecycle benchmark: save N models → delete half → vacuum.

Measures the catalog/GC path added with the transactional lifecycle
subsystem:

* **delete throughput** — journaled ``delete_model`` wall time (page unlink
  + ref decrement + tombstoning, one transaction each);
* **vacuum** — per-dim sweep + HNSW compaction + page rewrite wall time,
  and the bytes it reclaims (pages freed by the deletes, index bytes freed
  by dropping dead vertices);
* **post-vacuum load parity** — every surviving model must ``materialize()``
  bit-identically to its pre-delete snapshot (the lifecycle parity bar);
* **reopen** — engine restart over the vacuumed store (journal replay is a
  no-op on a clean store, so this times catalog load only);
* **batched ingest** — the same model set saved through ONE
  ``save_models`` transaction (one journal intent, one ``meta.json``
  commit, cross-model dim grouping) vs the per-model ``save_model`` loop —
  the checkpoint-sweep amortization of ISSUE 3;
* **space accounting** — the same ingest with the incremental
  ``SpaceAccountant`` on vs off (pricing the always-on ledger), plus the
  store-wide compression ratio it reports — the paper's Fig. 9 number as
  a continuously-published artifact (ISSUE 10).

Writes ``BENCH_lifecycle.json`` at the repo root (``schema_version``
documents the layout the CI gate parses) and prints the usual
``name,us_per_call,derived`` CSV rows via the runner.

Run: ``PYTHONPATH=src python benchmarks/lifecycle_bench.py [--n 16] [--dim 4096]``;
``--smoke`` runs the small CI scale. Or via the runner:
``PYTHONPATH=src python -m benchmarks.run lifecycle [--smoke]`` (quick scale).
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core.engine import StorageEngine
from repro.core.loader import materialize_many

# Bumped whenever the JSON layout changes (parsed by benchmarks/perf_gate.py).
# 3: added the "accounting" section (compression ratio + ledger overhead).
SCHEMA_VERSION = 3


def _models(n: int, dim: int, rng: np.random.Generator):
    """Half 'keep' (a base + fine-tunes sharing its vertices), half 'drop'
    (dissimilar models that exclusively own their base vertices)."""
    base = {
        "w0": rng.normal(0, 0.02, dim).astype(np.float32),
        "w1": rng.normal(0, 0.02, dim).astype(np.float32),
    }
    keep = {"keep0": base}
    for i in range(1, (n + 1) // 2):
        keep[f"keep{i}"] = {
            k: v + rng.normal(0, 1e-5, v.shape).astype(np.float32)
            for k, v in base.items()
        }
    drop = {
        f"drop{i}": {
            "w0": rng.normal(0, 5.0, dim).astype(np.float32),
            "w1": rng.normal(0, 5.0, dim).astype(np.float32),
        }
        for i in range(n // 2)
    }
    return keep, drop


def _bench_batch_save(models: dict, dim: int, sequential_s: float) -> dict:
    """The same model set through ONE save_models tx, on a fresh store."""
    specs = [(name, {}, tensors) for name, tensors in models.items()]
    with tempfile.TemporaryDirectory() as root:
        eng = StorageEngine(root)
        t0 = time.perf_counter()
        eng.save_models(specs)
        batch_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        handles = eng.load_models([name for name, _ in models.items()])
        outs = materialize_many(handles)
        multi_load_s = time.perf_counter() - t0
        # Reconstruction bound: the quantizer's |err| <= p plus the final
        # float32 cast (up to half an ulp of the tensor's own magnitude).
        p = 2.0 ** -24 * 1.001 + 1e-9
        parity = all(
            bool(np.all(
                np.abs(out[k] - tensors[k])
                <= p + np.spacing(np.abs(tensors[k]))
            ))
            for (name, tensors), out in zip(models.items(), outs)
            for k in tensors
        )
    return {
        "n_models": len(specs),
        "seconds": batch_s,
        "sequential_s": sequential_s,
        "speedup_vs_sequential": sequential_s / batch_s,
        "multi_load_s": multi_load_s,
        "reconstruction_parity": bool(parity),
    }


def _bench_accounting(seed: int = 0, trials: int = 15) -> dict:
    """Price the incremental space ledger: same ingest, accounting on/off.

    Runs at its own fixed scale (8 models, dim 2048) regardless of
    ``--smoke``: the ledger's cost is O(tensors) per save, so the gate
    statistic should not swing with the bench's model size.

    Save wall time is fsync-dominated and jitters by ±30% per pass on a
    shared box — far more than the ledger costs — so pooled per-mode
    aggregates (even medians over many passes) sporadically skew the
    ratio past any reasonable gate. Instead, one accounting-on and one
    accounting-off engine ingest the same models with their *individual
    saves interleaved*: each save runs in both engines back-to-back
    (milliseconds apart, alternating which goes first), so both sides of
    a pair share the disk's mood, and the per-pair off/on ratio isolates
    the ledger cost. The gate ratio is the median over all
    ``trials × n_models`` pair ratios (~120 pairs, ~1s total). The
    compression ratio comes from the accounting-on store — it is the
    number ``GET /v1/accounting`` and ``StoreStats.compression_ratio``
    publish in production.
    """
    rng = np.random.default_rng(seed)
    keep, drop = _models(8, 2048, rng)
    models = {**keep, **drop}

    def median(xs: list[float]) -> float:
        xs = sorted(xs)
        return xs[len(xs) // 2]

    on_stats: dict = {}
    per: dict[bool, list[list[float]]] = {True: [], False: []}
    ratios: list[float] = []
    for trial in range(trials):
        with tempfile.TemporaryDirectory() as root_off, \
                tempfile.TemporaryDirectory() as root_on:
            engs = {False: StorageEngine(root_off, accounting=False),
                    True: StorageEngine(root_on, accounting=True)}
            took: dict[bool, list[float]] = {True: [], False: []}
            for i, (name, tensors) in enumerate(models.items()):
                order = ((False, True) if (trial + i) % 2 == 0
                         else (True, False))
                pair = {}
                for mode in order:
                    pair[mode] = engs[mode].save_model(
                        name, {}, tensors).seconds
                    took[mode].append(pair[mode])
                ratios.append(pair[False] / pair[True])
            for mode in (False, True):
                per[mode].append(sum(took[mode]))
            on_stats = engs[True].stats()["accounting"]

    return {
        "n_models": len(models),
        "on_save_s": median(per[True]),
        "off_save_s": median(per[False]),
        # Throughput ratio, accounting-on vs off (>= 1.0 means free):
        # median over per-save interleaved off/on pair ratios.
        "on_vs_off_ratio": median(ratios),
        "logical_bytes": on_stats["logical_bytes"],
        "physical_bytes": on_stats["physical_bytes"],
        "compression_ratio": on_stats["compression_ratio"],
    }


def run_bench(n: int = 16, dim: int = 4096, seed: int = 0,
              smoke: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    keep, drop = _models(n, dim, rng)
    with tempfile.TemporaryDirectory() as root:
        eng = StorageEngine(root)
        save_s = []
        for name, tensors in {**keep, **drop}.items():
            save_s.append(eng.save_model(name, {}, tensors).seconds)
        before = eng.storage_bytes()
        snapshots = {name: eng.load_model(name).materialize() for name in keep}

        t0 = time.perf_counter()
        for name in drop:
            eng.delete_model(name)
        delete_s = time.perf_counter() - t0
        after_delete = eng.storage_bytes()

        t0 = time.perf_counter()
        report = eng.vacuum(min_dead_fraction=0.0)
        vacuum_s = time.perf_counter() - t0
        after_vacuum = eng.storage_bytes()

        parity = True
        for name, snap in snapshots.items():
            out = eng.load_model(name).materialize()
            parity &= all(np.array_equal(out[k], snap[k]) for k in snap)

        t0 = time.perf_counter()
        eng2 = StorageEngine(root)
        reopen_s = time.perf_counter() - t0
        parity &= sorted(eng2.list_models()) == sorted(keep)

    batch_save = _bench_batch_save({**keep, **drop}, dim, sum(save_s))
    accounting = _bench_accounting(seed=seed)

    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "smoke" if smoke else "full",
        "config": {"n_models": n, "dim": dim, "seed": seed},
        "save_s_total": sum(save_s),
        "batch_save": batch_save,
        "delete": {
            "n": len(drop),
            "seconds": delete_s,
            "per_model_s": delete_s / max(len(drop), 1),
        },
        "vacuum": {
            "seconds": vacuum_s,
            "vertices_dropped": report["vertices_dropped"],
            "pages_rewritten": report["pages_rewritten"],
        },
        "bytes": {
            "before": before,
            "after_delete": after_delete,
            "after_vacuum": after_vacuum,
            "reclaimed_pages": before["pages"] - after_vacuum["pages"],
            "reclaimed_index": before["index"] - after_vacuum["index"],
            "reclaimed_total": before["total"] - after_vacuum["total"],
        },
        "post_vacuum_load_parity": bool(parity),
        "reopen_s": reopen_s,
        "accounting": accounting,
    }


def run(csv, smoke: bool = False):
    """Runner entry point (quick scale, CSV convention)."""
    res = run_bench(n=6 if smoke else 8, dim=512 if smoke else 1024,
                    smoke=smoke)
    d, v, b = res["delete"], res["vacuum"], res["bytes"]
    bs = res["batch_save"]
    csv.add("lifecycle/delete_model", d["per_model_s"] * 1e6,
            f"n={d['n']}")
    csv.add("lifecycle/vacuum", v["seconds"] * 1e6,
            f"dropped={v['vertices_dropped']},pages_rw={v['pages_rewritten']}")
    csv.add("lifecycle/reclaimed_bytes", b["reclaimed_total"],
            f"pages={b['reclaimed_pages']},index={b['reclaimed_index']}")
    csv.add("lifecycle/reopen", res["reopen_s"] * 1e6,
            f"parity={res['post_vacuum_load_parity']}")
    csv.add("lifecycle/save_models", bs["seconds"] / bs["n_models"] * 1e6,
            f"speedup_vs_sequential={bs['speedup_vs_sequential']:.2f}x")
    ac = res["accounting"]
    csv.add("lifecycle/accounting_on_save",
            ac["on_save_s"] / ac["n_models"] * 1e6,
            f"on_vs_off={ac['on_vs_off_ratio']:.3f},"
            f"ratio={ac['compression_ratio']:.3f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI scale (<1 min): 8 models, dim 512")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_lifecycle.json"))
    args = ap.parse_args()
    if args.smoke:
        args.n, args.dim = 8, 512
    res = run_bench(n=args.n, dim=args.dim, smoke=args.smoke)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    b, v = res["bytes"], res["vacuum"]
    bs = res["batch_save"]
    print(f"saved {args.n} models ({res['save_s_total']:.2f}s), "
          f"deleted {res['delete']['n']} ({res['delete']['seconds']:.3f}s)")
    print(f"save_models:  {bs['sequential_s']:.2f}s -> {bs['seconds']:.2f}s "
          f"({bs['speedup_vs_sequential']:.2f}x, one tx; multi-load "
          f"{bs['multi_load_s']:.3f}s, parity "
          f"{bs['reconstruction_parity']})")
    print(f"vacuum: {v['seconds']:.3f}s, dropped {v['vertices_dropped']} "
          f"vertices, rewrote {v['pages_rewritten']} pages")
    print(f"reclaimed: pages {b['reclaimed_pages']}, index "
          f"{b['reclaimed_index']}, total {b['reclaimed_total']} "
          f"({b['before']['total']} -> {b['after_vacuum']['total']})")
    print(f"post-vacuum load parity: {res['post_vacuum_load_parity']}")
    ac = res["accounting"]
    print(f"accounting: on {ac['on_vs_off_ratio']:.3f}x off, "
          f"compression ratio {ac['compression_ratio']:.3f} "
          f"({ac['physical_bytes']} / {ac['logical_bytes']} bytes)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
