"""Durability benchmark: what do end-to-end checksums cost?

Measures the integrity tax on the hot paths — the same workload run with
``checksums=True`` (v3 pages + CRC verification at buffer-pool frame
admission, the default) and ``checksums=False`` (v3 framing with the
crc==0 "not checksummed" sentinel, no verification):

* **save** — models ingested per second (CRC computation rides inside
  ``write_page``);
* **load** — cold materializations per second (per-record CRC verify at
  frame admission; each load reopens a fresh engine so the buffer pool
  never amortizes the check away);
* **scrub** — pages verified per second by the background scrubber's
  increment, reported for sizing ``scrub_models`` (no gate).

Best-of-N reps per mode. The CI gate (``benchmarks/perf_gate.py``)
enforces ``save_ratio`` and ``load_ratio`` (checksum-on ÷ checksum-off
throughput) ≥ 0.9: CRC32 over page bytes must stay noise against the
quantization + fsync work around it.

Run: ``PYTHONPATH=src python benchmarks/durability_bench.py [--smoke]``;
writes ``BENCH_durability.json``. Or ``python -m benchmarks.run durability``.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core.engine import StorageEngine

# Bumped whenever the JSON layout changes (parsed by benchmarks/perf_gate.py).
SCHEMA_VERSION = 2


def _models(n: int, dim: int, rng: np.random.Generator) -> list[tuple]:
    side = int(dim ** 0.5)
    out = []
    for i in range(n):
        tensors = {
            "w": rng.normal(i * 3.0, 1.0, (side, side)).astype(np.float32),
            "b": rng.normal(i * 3.0, 1.0, (side,)).astype(np.float32),
        }
        out.append((f"model_{i}", {"kind": "bench"}, tensors))
    return out


def _phase(specs: list[tuple], checksums: bool) -> dict:
    """One save + cold-load + scrub pass on a fresh store."""
    with tempfile.TemporaryDirectory() as root:
        engine = StorageEngine(root, checksums=checksums)
        t0 = time.perf_counter()
        for name, arch, tensors in specs:
            engine.save_model(name, arch, tensors)
        save_s = time.perf_counter() - t0
        engine.close()

        # Cold loads: a fresh engine per pass so frame admission (where
        # verification runs) is actually exercised, not pool hits.
        engine = StorageEngine(root, checksums=checksums)
        t0 = time.perf_counter()
        for name, _arch, _tensors in specs:
            engine.load_model(name).materialize()
        load_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        srep = engine.scrub(max_models=len(specs))
        scrub_s = time.perf_counter() - t0
        scanned = srep["scanned"]
        engine.close()
    return {
        "save_s": save_s,
        "load_s": load_s,
        "saves_per_s": len(specs) / save_s if save_s else float("inf"),
        "loads_per_s": len(specs) / load_s if load_s else float("inf"),
        "scrub_pages_per_s": scanned / scrub_s if scrub_s else float("inf"),
    }


def run_bench(n_models: int = 16, dim: int = 262144, reps: int = 3,
              smoke: bool = False) -> dict:
    rng = np.random.default_rng(0)
    specs = _models(n_models, dim, rng)

    # One discarded warmup, then interleaved on/off reps: page-cache and
    # allocator drift hits both modes equally instead of biasing whichever
    # mode happens to run first.
    _phase(specs, True)
    on_reps, off_reps = [], []
    for _ in range(reps):
        on_reps.append(_phase(specs, True))
        off_reps.append(_phase(specs, False))
    on = max(on_reps, key=lambda r: r["saves_per_s"])
    off = max(off_reps, key=lambda r: r["saves_per_s"])
    # Ratios compare each metric's best rep: best-of-N is the standard
    # noise-robust estimator on shared runners, and pairing bests avoids
    # punishing one mode for a stall in an unrelated phase of its best rep.
    best = lambda runs, key: max(r[key] for r in runs)  # noqa: E731
    save_ratio = best(on_reps, "saves_per_s") / best(off_reps, "saves_per_s")
    load_ratio = best(on_reps, "loads_per_s") / best(off_reps, "loads_per_s")
    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "smoke" if smoke else "full",
        "config": {"n_models": n_models, "dim": dim, "reps": reps},
        "checksum_overhead": {
            "checksums_on": on,
            "checksums_off": off,
            "save_ratio": save_ratio,
            "load_ratio": load_ratio,
            "all_reps": {
                "on_saves_per_s": [r["saves_per_s"] for r in on_reps],
                "off_saves_per_s": [r["saves_per_s"] for r in off_reps],
                "on_loads_per_s": [r["loads_per_s"] for r in on_reps],
                "off_loads_per_s": [r["loads_per_s"] for r in off_reps],
            },
        },
    }


def run(csv, smoke: bool = False):
    """Runner entry point (quick scale, CSV convention)."""
    res = run_bench(n_models=8, dim=65536, reps=2, smoke=smoke)
    co = res["checksum_overhead"]
    csv.add("durability/save_checksum_on",
            1e6 / co["checksums_on"]["saves_per_s"],
            f"ratio_vs_off={co['save_ratio']:.3f}")
    csv.add("durability/load_checksum_on",
            1e6 / co["checksums_on"]["loads_per_s"],
            f"ratio_vs_off={co['load_ratio']:.3f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", type=int, default=16)
    ap.add_argument("--dim", type=int, default=262144,
                    help="flattened elements per weight tensor (512x512)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI scale: 8 models, dim 65536, 2 reps")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_durability.json"))
    args = ap.parse_args()
    if args.smoke:
        args.models, args.dim, args.reps = 8, 65536, 2
    res = run_bench(n_models=args.models, dim=args.dim, reps=args.reps,
                    smoke=args.smoke)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    co = res["checksum_overhead"]
    print(f"save: {co['checksums_on']['saves_per_s']:.1f}/s with checksums "
          f"({co['save_ratio']:.3f}x of off)")
    print(f"load: {co['checksums_on']['loads_per_s']:.1f}/s with checksums "
          f"({co['load_ratio']:.3f}x of off)")
    print(f"scrub: {co['checksums_on']['scrub_pages_per_s']:.1f} pages/s")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
