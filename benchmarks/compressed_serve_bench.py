"""Compressed-domain serving benchmark: decode off the store vs materialize.

Measures a full serving *session* — ``load_model(bits=...)`` → provider
construction → greedy decode — for the same stored llama3-shaped decoder
through two providers:

* **compressed** — :class:`repro.core.CompressedModel`: every large
  matmul consumes int8 base codes + quantized deltas through the
  ``dequant_matmul_auto`` seam; the float weight is never materialized;
* **materialized** — ``LoadedModel.materialize()`` first, then plain
  float32 gemms (the materialize-then-serve baseline).

Each session opens a **fresh** ``StorageEngine`` on the same on-disk
store, so neither provider inherits the other's decoded buffer-pool
payloads (the warm-pool variant was measured and biases the comparison).
Sessions are interleaved compressed/materialized, best-of-N; jax backend
discovery is triggered once up front so plugin init is not charged to
whichever session runs first.

Two phases: **smoke** (tiny decoder, short decode — the CI scale) and
**full** (512-wide, 4 layers). A full run records both; ``--smoke``
records only the smoke phase. Each phase also runs one ``bits=4``
session pair to report the int4-packed bytes-per-weight (1.5 vs 2.0)
and check compressed/materialized token parity at that precision.

Gates (``benchmarks/perf_gate.py``): per phase, ``bytes_ratio``
(compressed ÷ materialized weight-operand traffic) strictly < 1.0, and
``throughput_ratio`` (compressed ÷ materialized session tokens/s) ≥ 0.8
— on CPU the decomposed gemm folds to a single combined-operand gemm in
steady state, and the compressed session skips the up-front float64
dequantization of every weight, so losing 20 % end-to-end is a real
regression, not runner noise.

Run: ``PYTHONPATH=src python benchmarks/compressed_serve_bench.py
[--smoke]``; writes ``BENCH_compressed_serve.json``. Or
``python -m benchmarks.run compressed_serve``.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import CompressedModel, StorageEngine
from repro.launch.compressed_serve import (
    DecoderSpec,
    MaterializedProvider,
    greedy_decode,
    save_decoder,
)

# Bumped whenever the JSON layout changes (parsed by benchmarks/perf_gate.py).
SCHEMA_VERSION = 2

SMOKE_SPEC = DecoderSpec(d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                         n_layers=2, vocab_size=256)
FULL_SPEC = DecoderSpec(d_model=512, n_heads=8, n_kv_heads=4, d_ff=1024,
                        n_layers=4, vocab_size=2048)
PROMPT = ((1, 7, 42),)


def _session(root: str, spec: DecoderSpec, kind: str, steps: int,
             bits: int = 8) -> dict:
    """One cold serving session: fresh engine, load → provider → decode."""
    prompt = np.asarray(PROMPT)
    engine = StorageEngine(root)
    try:
        t0 = time.perf_counter()
        lm = engine.load_model("decoder", bits=bits)
        provider = (CompressedModel(lm) if kind == "compressed"
                    else MaterializedProvider(lm))
        setup_s = time.perf_counter() - t0
        tokens = greedy_decode(provider, spec, prompt, steps)
        total_s = time.perf_counter() - t0
        counters = dict(provider.counters)
        provider.close()
    finally:
        engine.close()
    return {
        "setup_s": setup_s,
        "decode_s": total_s - setup_s,
        "total_s": total_s,
        "tokens_per_s": steps / total_s if total_s else float("inf"),
        "bytes_moved": counters["bytes_moved"],
        "matmul_calls": counters["matmul_calls"],
        "tokens": tokens,
    }


def _phase(spec: DecoderSpec, steps: int, reps: int) -> dict:
    with tempfile.TemporaryDirectory() as root:
        engine = StorageEngine(root)
        save_decoder(engine, "decoder", spec, seed=0)
        engine.close()

        # Interleaved best-of-N: allocator/page-cache drift hits both
        # providers equally instead of biasing whichever runs first.
        c_reps, m_reps = [], []
        for _ in range(reps):
            c_reps.append(_session(root, spec, "compressed", steps))
            m_reps.append(_session(root, spec, "materialized", steps))
        best_c = max(c_reps, key=lambda r: r["tokens_per_s"])
        best_m = max(m_reps, key=lambda r: r["tokens_per_s"])
        if not all((r["tokens"] == best_m["tokens"]).all() for r in c_reps):
            raise AssertionError("compressed decode diverged from materialized")

        # One bits=4 pair: flexible loading (top-4 delta bit-planes) gives
        # the int4-packed kernel layout — report its traffic + parity.
        c4 = _session(root, spec, "compressed", steps, bits=4)
        m4 = _session(root, spec, "materialized", steps, bits=4)

    phase = {
        "spec": {"d_model": spec.d_model, "n_layers": spec.n_layers,
                 "d_ff": spec.d_ff, "vocab_size": spec.vocab_size},
        "steps": steps,
        "reps": reps,
        "compressed": {k: v for k, v in best_c.items() if k != "tokens"},
        "materialized": {k: v for k, v in best_m.items() if k != "tokens"},
        "int4": {
            "bytes_moved": c4["bytes_moved"],
            "bytes_ratio_vs_materialized": c4["bytes_moved"] / m4["bytes_moved"],
            "tokens_match": bool((c4["tokens"] == m4["tokens"]).all()),
        },
        "bytes_ratio": best_c["bytes_moved"] / best_m["bytes_moved"],
        "throughput_ratio": (best_c["tokens_per_s"] / best_m["tokens_per_s"]),
        "all_reps": {
            "compressed_tokens_per_s": [r["tokens_per_s"] for r in c_reps],
            "materialized_tokens_per_s": [r["tokens_per_s"] for r in m_reps],
        },
    }
    return phase


def run_bench(smoke: bool = False, reps: int = 5,
              smoke_steps: int = 8, full_steps: int = 16) -> dict:
    # Trigger jax plugin discovery before any timed session — the seam's
    # _on_tpu() probe would otherwise charge backend init (~tens of ms)
    # to the first compressed session.
    import jax

    jax.default_backend()

    phases = {"smoke": _phase(SMOKE_SPEC, smoke_steps, reps)}
    if not smoke:
        phases["full"] = _phase(FULL_SPEC, full_steps, reps)
    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "smoke" if smoke else "full",
        "config": {"reps": reps, "smoke_steps": smoke_steps,
                   "full_steps": full_steps, "prompt_len": len(PROMPT[0])},
        "compressed_serve": {"phases": phases},
    }


def run(csv, smoke: bool = False):
    """Runner entry point (quick scale, CSV convention)."""
    res = run_bench(smoke=True, reps=3 if smoke else 5)
    ph = res["compressed_serve"]["phases"]["smoke"]
    csv.add("compressed_serve/tokens_per_s",
            1e6 / ph["compressed"]["tokens_per_s"],
            f"throughput_ratio={ph['throughput_ratio']:.3f}")
    csv.add("compressed_serve/bytes_ratio", ph["bytes_ratio"] * 1e6,
            f"int4_ratio={ph['int4']['bytes_ratio_vs_materialized']:.3f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: smoke phase only, 3 reps")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_compressed_serve.json"))
    args = ap.parse_args()
    if args.smoke:
        args.reps = 3
    res = run_bench(smoke=args.smoke, reps=args.reps)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    for name, ph in res["compressed_serve"]["phases"].items():
        print(f"{name}: compressed {ph['compressed']['tokens_per_s']:.1f} "
              f"tok/s vs materialized {ph['materialized']['tokens_per_s']:.1f} "
              f"(ratio {ph['throughput_ratio']:.3f}); "
              f"bytes ratio {ph['bytes_ratio']:.3f}, "
              f"int4 {ph['int4']['bytes_ratio_vs_materialized']:.3f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
