"""Fig. 13 — Model performance change across compression algorithms.

Trained models on two tasks; compressors at the default tolerance
(p = 2^-24) + NeurStore full and flexible-8bit loading. Paper expectation:
>90% of models show no change for ZFP/ELF/NeurStore-full; flexible loading
adds a small bounded change."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.baselines.compressors import ALL_COMPRESSORS
from repro.core import StorageEngine

from .common import Csv
from .workload import (
    make_tabular_task,
    mlp_accuracy,
    mlp_to_tensors,
    tensors_to_mlp,
    train_mlp,
)


def run(csv: Csv):
    tasks = {
        "tabular": make_tabular_task(seed=0),
        "tabular2": make_tabular_task(seed=7, d=32, classes=4),
    }
    n_models = 4
    for task, (x, y) in tasks.items():
        xtr, ytr, xte, yte = x[:3072], y[:3072], x[3072:], y[3072:]
        widths = (x.shape[1], 128, int(y.max()) + 1)
        models = [train_mlp(xtr, ytr, widths=widths, seed=s)
                  for s in range(n_models)]
        base = [mlp_accuracy(ws, bs, xte, yte) for ws, bs in models]

        def eval_tensors(ts):
            ws, bs = tensors_to_mlp(ts)
            return mlp_accuracy(ws, bs, xte, yte)

        for cname in ("zstd", "zfp", "elf", "ptq8"):
            comp = ALL_COMPRESSORS[cname]
            deltas = []
            for i, (ws, bs) in enumerate(models):
                ts = mlp_to_tensors(ws, bs)
                back = {k: comp.decompress(comp.compress(v), v.shape)
                        for k, v in ts.items()}
                deltas.append(abs(eval_tensors(back) - base[i]))
            csv.add(f"fig13/{task}/{cname}", 0.0,
                    f"mean_change={np.mean(deltas)*100:.4f}% "
                    f"zero_frac={np.mean(np.array(deltas)==0):.2f}")
        with tempfile.TemporaryDirectory() as root:
            eng = StorageEngine(root)
            for i, (ws, bs) in enumerate(models):
                eng.save_model(f"{task}{i}", {}, mlp_to_tensors(ws, bs))
            for mode, bits in (("neurstore_full", None), ("neurstore_flex8", 8)):
                deltas = []
                for i in range(n_models):
                    back = eng.load_model(f"{task}{i}", bits=bits).materialize()
                    deltas.append(abs(eval_tensors(back) - base[i]))
                csv.add(f"fig13/{task}/{mode}", 0.0,
                        f"mean_change={np.mean(deltas)*100:.4f}% "
                        f"zero_frac={np.mean(np.array(deltas)==0):.2f}")
