"""Fig. 7 — End-to-end time breakdown for in-database AI-powered analytics.

Three tasks (tabular MLP ≈ Avazu; sequence transformer ≈ DistilBERT/IMDB;
encoder transformer ≈ ViT/Beans), three stores (NeurStore, PostgresML-blob,
ELF*-file). Per task: save N models → load each → run inference; report
per-stage seconds. NeurStore loading is compression-aware (no full
decompress before use)."""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.baselines import BlobStore, FileStore
from repro.core import StorageEngine

from .common import Csv
from .workload import finetune, mlp_tensors, transformer_tensors


def _mlp_infer(tensors, x):
    # mlp_tensors uses layer{i}/w|b keys.
    n = len(tensors) // 2
    h = x
    for i in range(n):
        h = h @ tensors[f"layer{i}/w"] + tensors[f"layer{i}/b"]
        if i < n - 1:
            h = np.maximum(h, 0)
    return h


def _transformer_infer(tensors, x):
    # One encoder pass with the stored tensors (numpy; stands in for the
    # ONNX runtime in the paper — identical across stores by construction).
    h = x
    for i in range(4):
        q = h @ tensors[f"l{i}/wq"]
        k = h @ tensors[f"l{i}/wk"]
        v = h @ tensors[f"l{i}/wv"]
        s = q @ k.transpose(0, 2, 1) / np.sqrt(q.shape[-1])
        s = np.exp(s - s.max(-1, keepdims=True))
        s /= s.sum(-1, keepdims=True)
        h = h + (s @ v) @ tensors[f"l{i}/wo"]
        ff = np.maximum(h @ tensors[f"l{i}/w1"], 0)
        h = h + ff @ tensors[f"l{i}/w2"]
    return h


TASKS = {
    "tabular": dict(maker=lambda seed: mlp_tensors(seed=seed), n_models=6,
                    infer=_mlp_infer,
                    x=np.random.default_rng(0).normal(0, 1, (256, 64)).astype(np.float32)),
    "sequence": dict(maker=lambda seed: transformer_tensors(seed=seed),
                     n_models=4, infer=_transformer_infer,
                     x=np.random.default_rng(1).normal(0, 1, (8, 32, 128)).astype(np.float32)),
    "image": dict(maker=lambda seed: transformer_tensors(d=128, layers=4, seed=seed),
                  n_models=4, infer=_transformer_infer,
                  x=np.random.default_rng(2).normal(0, 1, (8, 49, 128)).astype(np.float32)),
}


def run(csv: Csv):
    for task, spec in TASKS.items():
        base = spec["maker"](0)
        models = [(f"{task}/m{i}",
                   base if i == 0 else finetune(base, seed=i))
                  for i in range(spec["n_models"])]
        with tempfile.TemporaryDirectory() as root:
            stores = {
                "neurstore": StorageEngine(root + "/ns"),
                "postgresml": BlobStore(root + "/pg"),
                "elf*": FileStore(root + "/elf"),
            }
            for sname, store in stores.items():
                t0 = time.perf_counter()
                for name, tensors in models:
                    store.save_model(name, {"task": task}, tensors)
                t_save = time.perf_counter() - t0
                t0 = time.perf_counter()
                loaded = [store.load_model(name).materialize()
                          for name, _ in models]
                t_load = time.perf_counter() - t0
                t0 = time.perf_counter()
                for tensors in loaded:
                    spec["infer"](tensors, spec["x"])
                t_infer = time.perf_counter() - t0
                total = t_save + t_load + t_infer
                csv.add(f"fig7/{task}/{sname}/save", t_save * 1e6 / len(models),
                        f"total_s={t_save:.3f}")
                csv.add(f"fig7/{task}/{sname}/load", t_load * 1e6 / len(models),
                        f"total_s={t_load:.3f}")
                csv.add(f"fig7/{task}/{sname}/infer", t_infer * 1e6 / len(models),
                        f"e2e_s={total:.3f}")
