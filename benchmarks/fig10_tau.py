"""Fig. 10 — Similarity-threshold (τ) sweep: index vs delta storage split,
compression ratio peak, and compression throughput (1 and 2 threads)."""

from __future__ import annotations

import tempfile
import threading
import time

from repro.core import StorageEngine

from .common import Csv
from .workload import model_collection, collection_bytes


def run(csv: Csv):
    collection = model_collection(n_families=2, n_variants=5, n_unrelated=1,
                                  kind="mlp", sigma=2e-2)
    orig = collection_bytes(collection)
    best = (0, None)
    for tau in (0.01, 0.04, 0.16, 0.64):
        with tempfile.TemporaryDirectory() as root:
            eng = StorageEngine(root, tau=tau)
            t0 = time.perf_counter()
            for nm, ts in collection:
                eng.save_model(nm, {}, ts)
            dt = time.perf_counter() - t0
            s = eng.storage_bytes()
            ratio = orig / s["total"]
            mbs = orig / dt / 1e6
            csv.add(f"fig10a/tau{tau}", dt * 1e6 / len(collection),
                    f"index={s['index']} delta={s['pages']} ratio={ratio:.2f}")
            csv.add(f"fig10b/tau{tau}/threads1", dt * 1e6 / len(collection),
                    f"MBps={mbs:.1f}")
            if ratio > best[0]:
                best = (ratio, tau)
        # two-thread compression (independent engines — thread-level
        # parallelism over the model stream, paper §6.4.1 setup).
        with tempfile.TemporaryDirectory() as root:
            engs = [StorageEngine(root + f"/t{i}", tau=tau) for i in range(2)]
            halves = [collection[0::2], collection[1::2]]
            t0 = time.perf_counter()
            ths = [threading.Thread(
                target=lambda e=e, h=h: [e.save_model(nm, {}, ts) for nm, ts in h])
                for e, h in zip(engs, halves)]
            for t in ths:
                t.start()
            for t in ths:
                t.join()
            dt = time.perf_counter() - t0
            csv.add(f"fig10b/tau{tau}/threads2", dt * 1e6 / len(collection),
                    f"MBps={orig/dt/1e6:.1f}")
    csv.add("fig10/peak", 0.0, f"best_ratio={best[0]:.2f} at_tau={best[1]}")
