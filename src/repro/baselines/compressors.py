"""Baseline compressors the paper evaluates against (§6.1.2).

* ``ZstdCompressor`` — real Zstandard (the paper's ZSTD v1.5.5 baseline).
* ``ZlibCompressor`` — LZ-family; stands in for PostgresML's PGLZ/TOAST.
* ``ElfCompressor`` — ELF [VLDB'24]: erase the exponent field of floats in
  (-1, 1) by remapping to [1, 2) — the mantissa keeps the value exactly
  recoverable given the map flag; exponent bytes then compress away.
  Implemented losslessly: map, then zstd the now-redundant exponent plane.
* ``ZfpLikeCompressor`` — fixed-accuracy float compressor in the spirit of
  ZFP: block-wise (64) common-exponent fixed-point encoding at a given
  absolute error bound.
* ``PTQ8Compressor`` — naive whole-tensor 8-bit PTQ (lossy, no deltas):
  the "quantize the model directly" strawman.

All expose compress(arr) → bytes and decompress(bytes, shape) → arr, plus
``lossless`` / error-bound metadata for the accuracy benchmarks.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

try:
    import zstandard as zstd

    # Fresh (de)compressor per call: the objects are NOT thread-safe and
    # the throughput benchmarks save from concurrent clients.
    def _zstd_c(b: bytes) -> bytes:
        return zstd.ZstdCompressor(level=3).compress(b)

    def _zstd_d(b: bytes) -> bytes:
        return zstd.ZstdDecompressor().decompress(b)
except ImportError:  # pragma: no cover
    _zstd_c = zlib.compress
    _zstd_d = zlib.decompress


class ZstdCompressor:
    name = "zstd"
    lossless = True

    def compress(self, arr: np.ndarray) -> bytes:
        return _zstd_c(np.ascontiguousarray(arr, np.float32).tobytes())

    def decompress(self, data: bytes, shape) -> np.ndarray:
        return np.frombuffer(_zstd_d(data), np.float32).reshape(shape).copy()


class ZlibCompressor:
    """PGLZ stand-in (PostgresML stores TOAST-compressed blobs)."""

    name = "pglz"
    lossless = True

    def compress(self, arr: np.ndarray) -> bytes:
        return zlib.compress(np.ascontiguousarray(arr, np.float32).tobytes(), 6)

    def decompress(self, data: bytes, shape) -> np.ndarray:
        return np.frombuffer(zlib.decompress(data), np.float32).reshape(shape).copy()


class ElfCompressor:
    """ELF: map x ∈ (-1,1) to sign·(|x|+1) ∈ [1,2) — the exponent byte of
    every mapped float becomes a constant pattern, which the entropy stage
    removes. Adding 1.0 rounds the mantissa at ulp(1)=2^-23, so roundtrip
    error ≤ 2^-24 — exactly the tolerance the NeurStore paper adopts
    "consistent with that used in ELF" (§6.1.3)."""

    name = "elf"
    lossless = False
    tolerance = 2.0 ** -24

    def compress(self, arr: np.ndarray) -> bytes:
        x = np.ascontiguousarray(arr, np.float32).ravel()
        mapped_mask = np.abs(x) < 1.0
        y = np.where(mapped_mask, np.sign(x) * (np.abs(x) + 1.0), x).astype(np.float32)
        # Byte-plane split boosts the entropy stage (exponent plane is now
        # near-constant for mapped values).
        planes = y.view(np.uint8).reshape(-1, 4).T.copy()
        flags = np.packbits(mapped_mask)
        payload = _zstd_c(planes.tobytes())
        fl = _zstd_c(flags.tobytes())
        return struct.pack("<QQ", len(payload), x.size) + payload + fl

    def decompress(self, data: bytes, shape) -> np.ndarray:
        plen, n = struct.unpack_from("<QQ", data, 0)
        off = 16
        planes = np.frombuffer(_zstd_d(data[off:off + plen]), np.uint8)
        y = planes.reshape(4, -1).T.copy().view(np.float32).ravel()
        flags = np.unpackbits(
            np.frombuffer(_zstd_d(data[off + plen:]), np.uint8), count=n
        ).astype(bool)
        x = np.where(flags, np.sign(y) * (np.abs(y) - 1.0), y)
        return x.astype(np.float32).reshape(shape)


class ZfpLikeCompressor:
    """Fixed-accuracy mode: per-64-block common exponent + fixed point at
    absolute tolerance ``p`` (captures ZFP's error-bounded behaviour)."""

    name = "zfp"
    lossless = False

    def __init__(self, tolerance: float = 5.96e-8):
        self.tolerance = tolerance

    def compress(self, arr: np.ndarray) -> bytes:
        x = np.ascontiguousarray(arr, np.float64).ravel()
        n = x.size
        pad = (-n) % 64
        xp = np.pad(x, (0, pad)).reshape(-1, 64)
        amax = np.abs(xp).max(axis=1)
        # Bits so that quantization step <= 2*tolerance within each block.
        nbits = np.ceil(np.log2(np.maximum(amax / self.tolerance, 1.0))).astype(np.int64)
        nbits = np.clip(nbits, 0, 30)
        out = bytearray(struct.pack("<QQd", n, xp.shape[0], self.tolerance))
        for blk, b, am in zip(xp, nbits, amax):
            out += struct.pack("<Bd", int(b), float(am))
            if b == 0:
                continue
            scale = am / (2 ** int(b) - 1) if am > 0 else 1.0
            q = np.round(blk / scale).astype(np.int32)
            # pack signed values: zigzag then minimal bytes (1/2/4)
            zz = ((q >> 31) ^ (q << 1)).astype(np.uint32)
            width = 1 if zz.max() < 256 else (2 if zz.max() < 65536 else 4)
            out += struct.pack("<B", width)
            out += zz.astype({1: np.uint8, 2: np.uint16, 4: np.uint32}[width]).tobytes()
        return _zstd_c(bytes(out))

    def decompress(self, data: bytes, shape) -> np.ndarray:
        raw = _zstd_d(data)
        n, nblk, tol = struct.unpack_from("<QQd", raw, 0)
        off = 24
        blocks = []
        for _ in range(nblk):
            b, am = struct.unpack_from("<Bd", raw, off)
            off += 9
            if b == 0:
                blocks.append(np.zeros(64))
                continue
            (width,) = struct.unpack_from("<B", raw, off)
            off += 1
            dt = {1: np.uint8, 2: np.uint16, 4: np.uint32}[width]
            zz = np.frombuffer(raw, dt, 64, off).astype(np.uint32)
            off += 64 * width
            q = (zz >> 1).astype(np.int32) ^ -((zz & 1).astype(np.int32))
            scale = am / (2 ** int(b) - 1) if am > 0 else 1.0
            blocks.append(q * scale)
        x = np.concatenate(blocks)[:n]
        return x.astype(np.float32).reshape(shape)


class PTQ8Compressor:
    """Whole-tensor 8-bit PTQ — the no-delta quantization strawman."""

    name = "ptq8"
    lossless = False

    def compress(self, arr: np.ndarray) -> bytes:
        from ..core.quantize import quantize_linear

        x = np.ascontiguousarray(arr, np.float32)
        q, meta = quantize_linear(x.ravel(), nbit=8)
        head = struct.pack("<ddq", meta.scale, meta.mid, meta.zero_point)
        return head + _zstd_c(q.astype(np.uint8).tobytes())

    def decompress(self, data: bytes, shape) -> np.ndarray:
        from ..core.quantize import QuantMeta, dequantize_linear

        scale, mid, zp = struct.unpack_from("<ddq", data, 0)
        q = np.frombuffer(_zstd_d(data[24:]), np.uint8).astype(np.int64)
        meta = QuantMeta(scale=scale, zero_point=zp, nbit=8, mid=mid)
        return dequantize_linear(q, meta).astype(np.float32).reshape(shape)


ALL_COMPRESSORS = {
    c.name: c for c in [ZstdCompressor(), ZlibCompressor(), ElfCompressor(),
                        ZfpLikeCompressor(), PTQ8Compressor()]
}
