"""Baselines: compression algorithms + model stores the paper compares."""

from .compressors import ALL_COMPRESSORS
from .stores import BlobStore, FileStore

__all__ = ["ALL_COMPRESSORS", "BlobStore", "FileStore"]
