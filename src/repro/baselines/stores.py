"""Baseline model *stores* (system-level comparisons, paper §6.1.2).

* ``BlobStore``  — PostgresML-like: serialize the whole model into one
  zlib(PGLZ)-compressed blob in a "model table" (a directory of blobs +
  a metadata json standing in for the relational table).
* ``FileStore``  — ELF*-like: per-tensor ELF compression into one file per
  model + a metadata record holding the path (ELF is a float-array
  transform, so it applies tensor-wise, not to the serialized container).

Both share the benchmark-facing API of ``StorageEngine``:
``save_model(name, arch, tensors)`` / ``load_model(name).materialize()``.
"""

from __future__ import annotations

import io
import json
import os
import struct
import time
import zlib

import numpy as np

from .compressors import ElfCompressor


class _Loaded:
    def __init__(self, tensors):
        self._tensors = tensors

    def materialize(self):
        return dict(self._tensors)

    def tensor(self, name):
        return self._tensors[name]


class _BaseStore:
    name = "base"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._meta_path = os.path.join(root, "meta.json")
        self._meta = {}
        if os.path.exists(self._meta_path):
            with open(self._meta_path) as f:
                self._meta = json.load(f)

    def _blob_path(self, name: str) -> str:
        return os.path.join(self.root, f"{name.replace('/', '_')}.bin")

    def _encode(self, tensors):  # → bytes
        raise NotImplementedError

    def _decode(self, blob):    # → dict[str, np.ndarray]
        raise NotImplementedError

    def save_model(self, name, architecture, tensors):
        t0 = time.perf_counter()
        blob = self._encode(tensors)
        with open(self._blob_path(name), "wb") as f:
            f.write(blob)
        self._meta[name] = {
            "architecture": architecture,
            "original_bytes": sum(np.asarray(v).size * 4 for v in tensors.values()),
            "blob_bytes": len(blob),
        }
        with open(self._meta_path, "w") as f:
            json.dump(self._meta, f)
        return time.perf_counter() - t0

    def load_model(self, name):
        with open(self._blob_path(name), "rb") as f:
            blob = f.read()
        return _Loaded(self._decode(blob))

    def list_models(self):
        return list(self._meta)

    def storage_bytes(self):
        total = sum(os.path.getsize(os.path.join(self.root, f))
                    for f in os.listdir(self.root) if f.endswith(".bin"))
        return {"pages": total, "index": 0, "total": total}


class BlobStore(_BaseStore):
    """PostgresML-like: one PGLZ(zlib) blob per model (TOAST semantics)."""

    name = "postgresml"

    def _encode(self, tensors):
        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v, np.float32) for k, v in tensors.items()})
        return zlib.compress(buf.getvalue(), 6)

    def _decode(self, blob):
        with np.load(io.BytesIO(zlib.decompress(blob))) as z:
            return {k: z[k] for k in z.files}


class FileStore(_BaseStore):
    """ELF*-like: per-tensor ELF compression, one file per model."""

    name = "elf*"
    _elf = ElfCompressor()

    def _encode(self, tensors):
        out = bytearray(struct.pack("<I", len(tensors)))
        for k, v in tensors.items():
            arr = np.asarray(v, np.float32)
            body = self._elf.compress(arr)
            kb = k.encode()
            out += struct.pack("<H", len(kb)) + kb
            out += struct.pack("<B", arr.ndim)
            out += struct.pack(f"<{arr.ndim}I", *arr.shape)
            out += struct.pack("<Q", len(body)) + body
        return bytes(out)

    def _decode(self, blob):
        (n,) = struct.unpack_from("<I", blob, 0)
        off = 4
        tensors = {}
        for _ in range(n):
            (klen,) = struct.unpack_from("<H", blob, off)
            off += 2
            k = blob[off:off + klen].decode()
            off += klen
            (ndim,) = struct.unpack_from("<B", blob, off)
            off += 1
            shape = struct.unpack_from(f"<{ndim}I", blob, off)
            off += 4 * ndim
            (blen,) = struct.unpack_from("<Q", blob, off)
            off += 8
            tensors[k] = self._elf.decompress(blob[off:off + blen], shape)
            off += blen
        return tensors
