"""Unified error surface: one machine-readable code per failure class.

The same registry backs all three surfaces (the error contract asserted
by ``tests/test_server.py::test_error_contract``):

* the **embedded API** raises the typed exceptions directly;
* the **server** maps an exception to ``{"error": {"code", "message"}}``
  plus the code's canonical HTTP status (:func:`error_payload`);
* **StoreClient** maps the code back to the *same* typed exception class
  (:func:`raise_for_code`), so ``except CorruptPageError`` works
  identically against a local engine and a remote store.

Codes are part of the wire contract (``docs/serving.md``): they are
append-only and never renamed.

=================  ======  ==========================================
code               status  raised as
=================  ======  ==========================================
``not_found``      404     ``KeyError``
``corrupt``        409     ``CorruptPageError``
``read_only``      503     ``ReadOnlyStoreError``
``quota_exceeded`` 413     :class:`QuotaExceededError`
``backpressure``   429     :class:`AdmissionRejectedError`
``kernel_not_ready`` 422   ``KernelNotReady``
``invalid_request`` 400    ``ValueError``
``internal``       500     :class:`RemoteStoreError`
=================  ======  ==========================================
"""

from __future__ import annotations

from ..core.integrity import (
    CorruptIndexError,
    CorruptPageError,
    IntegrityError,
    ReadOnlyStoreError,
)
from ..core.loader import KernelNotReady

__all__ = [
    "AdmissionRejectedError",
    "QuotaExceededError",
    "RemoteStoreError",
    "ERROR_CODES",
    "error_code_for",
    "error_payload",
    "http_status_for",
    "raise_for_code",
]


class QuotaExceededError(RuntimeError):
    """A save would push a tenant past its byte quota (checked at commit)."""


class AdmissionRejectedError(RuntimeError):
    """A write was rejected by the admission policy (pool pressure or
    snapshot-epoch lag). The request is safe to retry after backoff."""


class RemoteStoreError(RuntimeError):
    """The server failed in a way no specific code covers (HTTP 5xx)."""


# code → canonical HTTP status. Append-only: codes are wire contract.
ERROR_CODES: dict[str, int] = {
    "not_found": 404,
    "corrupt": 409,
    "read_only": 503,
    "quota_exceeded": 413,
    "backpressure": 429,
    "kernel_not_ready": 422,
    "invalid_request": 400,
    "internal": 500,
}

# code → exception type the client raises. One entry per code; the
# reverse mapping in error_code_for handles subclass fan-in (every
# IntegrityError subclass → "corrupt" except the two specialized ones).
_RAISERS: dict[str, type] = {
    "not_found": KeyError,
    "corrupt": CorruptPageError,
    "read_only": ReadOnlyStoreError,
    "quota_exceeded": QuotaExceededError,
    "backpressure": AdmissionRejectedError,
    "kernel_not_ready": KernelNotReady,
    "invalid_request": ValueError,
    "internal": RemoteStoreError,
}


def error_code_for(exc: BaseException) -> str:
    """Map an exception to its stable wire code (most-specific first)."""
    if isinstance(exc, ReadOnlyStoreError):
        return "read_only"
    if isinstance(exc, (CorruptPageError, CorruptIndexError, IntegrityError)):
        return "corrupt"
    if isinstance(exc, QuotaExceededError):
        return "quota_exceeded"
    if isinstance(exc, AdmissionRejectedError):
        return "backpressure"
    if isinstance(exc, KernelNotReady):
        return "kernel_not_ready"
    if isinstance(exc, KeyError):
        return "not_found"
    if isinstance(exc, ValueError):
        return "invalid_request"
    return "internal"


def http_status_for(code: str) -> int:
    return ERROR_CODES.get(code, 500)


def error_payload(exc: BaseException) -> tuple[int, dict]:
    """(HTTP status, JSON body) for an exception — the server's error path."""
    code = error_code_for(exc)
    message = str(exc) or type(exc).__name__
    if isinstance(exc, KeyError) and exc.args:
        message = str(exc.args[0])  # KeyError str() wraps in quotes
    return http_status_for(code), {"error": {"code": code, "message": message}}


def raise_for_code(code: str, message: str) -> None:
    """Raise the typed exception registered for ``code`` (client side).

    Unknown codes (a newer server) degrade to :class:`RemoteStoreError`
    with the code embedded, so old clients fail loudly but typed.
    """
    exc_type = _RAISERS.get(code)
    if exc_type is None:
        raise RemoteStoreError(f"[{code}] {message}")
    raise exc_type(message)
