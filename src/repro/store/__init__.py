"""The supported public facade over the NeurStore engine.

``repro.store`` is the import path applications should use::

    from repro.store import NeurStore, SaveRequest

    store = NeurStore.open("/path/to/store")
    store.save(SaveRequest("base", tensors, architecture={"family": "demo"}))
    with store.load("base", bits=8) as handle:
        params = handle.materialize()

Everything here is a thin, *typed* veneer over
:class:`repro.core.engine.StorageEngine` — the same
:class:`~repro.store.api.SaveRequest` / :class:`~repro.store.api.SaveReport`
/ :class:`~repro.store.api.LoadHandle` / :class:`~repro.store.api.StoreStats`
dataclasses are used verbatim by the HTTP server handlers
(``repro.server.app``) and the network client
(``repro.server.client.StoreClient``), so code written against this
facade runs unchanged against a remote store. The canonical knob set
(``tolerance``/``tau`` defaults + per-save overrides, ``bits`` /
``shared_cache`` per load) is documented in :mod:`repro.store.api` and
``docs/serving.md``.

``repro.core.engine`` remains importable for existing code (its
``StorageEngine``/``SaveReport`` are exactly what this facade wraps),
but new surface lands here first.
"""

from __future__ import annotations

from ..core.engine import DEFAULT_TAU, DEFAULT_TOLERANCE, StorageEngine
from .api import LoadHandle, SaveReport, SaveRequest, StoreStats
from .errors import (
    AdmissionRejectedError,
    QuotaExceededError,
    RemoteStoreError,
)

__all__ = [
    "AdmissionRejectedError",
    "DEFAULT_TAU",
    "DEFAULT_TOLERANCE",
    "LoadHandle",
    "NeurStore",
    "QuotaExceededError",
    "RemoteStoreError",
    "SaveReport",
    "SaveRequest",
    "StoreStats",
]


class NeurStore:
    """Typed single-process front door over one on-disk store."""

    def __init__(self, engine: StorageEngine):
        self.engine = engine

    @classmethod
    def open(
        cls,
        path: str,
        *,
        tolerance: float = DEFAULT_TOLERANCE,
        tau: float = DEFAULT_TAU,
        cache_bytes: int = 32 << 30,
        pool_bytes: int = 1 << 30,
        checksums: bool = True,
        auto_maintenance: bool = False,
    ) -> "NeurStore":
        """Open (or create) a store at ``path`` with the documented knobs.

        ``tolerance``/``tau`` become the store-level defaults that
        per-save overrides fall back to; ``cache_bytes`` bounds the HNSW
        index cache, ``pool_bytes`` the tensor-page buffer pool.
        """
        return cls(StorageEngine(
            path, tolerance=tolerance, tau=tau, cache_bytes=cache_bytes,
            pool_bytes=pool_bytes, checksums=checksums,
            auto_maintenance=auto_maintenance,
        ))

    # --------------------------------------------------------------- writes
    def save(self, request: SaveRequest) -> SaveReport:
        return self.engine.save_model(
            request.name, request.architecture, request.tensors,
            tolerance=request.tolerance, tau=request.tau,
        )

    def save_many(self, requests: list[SaveRequest]) -> list[SaveReport]:
        """Commit several models in ONE catalog transaction (batch ingest).

        Per-save knob overrides are not supported on the batch path (the
        batch shares one probe/quantize sweep); all requests must leave
        ``tolerance``/``tau`` unset.
        """
        for r in requests:
            if r.tolerance is not None or r.tau is not None:
                raise ValueError(
                    f"save_many: request {r.name!r} carries per-save knob "
                    "overrides; batch saves use the store defaults")
        return self.engine.save_models(
            [(r.name, r.architecture, r.tensors) for r in requests]
        )

    def replace(self, request: SaveRequest) -> SaveReport:
        """Replace an existing model (KeyError if absent) atomically."""
        return self.engine.replace_model(
            request.name, request.architecture, request.tensors,
            tolerance=request.tolerance, tau=request.tau,
        )

    def delete(self, name: str) -> None:
        self.engine.delete_model(name)

    def vacuum(self, min_dead_fraction: float = 0.0) -> dict:
        return self.engine.vacuum(min_dead_fraction=min_dead_fraction)

    # ---------------------------------------------------------------- reads
    def load(self, name: str, *, bits: int | None = None,
             shared_cache: bool = True) -> LoadHandle:
        lm = self.engine.load_model(name, bits=bits, shared_cache=shared_cache)
        return LoadHandle.from_loaded(name, lm, bits=bits)

    def load_many(self, names: list[str],
                  bits: int | None = None) -> list[LoadHandle]:
        """Open several handles under ONE snapshot epoch (consistent set)."""
        return [
            LoadHandle.from_loaded(name, lm, bits=bits)
            for name, lm in zip(names, self.engine.load_models(names, bits=bits))
        ]

    def models(self) -> list[str]:
        return self.engine.list_models()

    def stats(self) -> StoreStats:
        return StoreStats.from_engine(self.engine.stats())

    # --------------------------------------------------------- observability
    def accounting(self) -> dict:
        """Space accounting: ``{"store", "per_model", "per_dim",
        "per_tenant"}`` byte attribution (``docs/observability.md``)."""
        return self.engine.accounting_report()

    def explain(self, name: str) -> dict:
        """Persisted save EXPLAIN (per-tensor dedup decisions) + the
        model's current space attribution."""
        return self.engine.model_explain(name)

    def metrics(self) -> dict:
        """Parsed snapshot of the process-wide metrics registry.

        Returns ``{family_name: {"type": ..., "help": ..., "samples":
        [{"name", "labels", "value"}, ...]}}`` — the same structure
        :func:`repro.obs.metrics.parse_prometheus_text` produces, so
        embedded callers and scrape consumers see one schema.
        """
        from ..obs.metrics import default_registry, parse_prometheus_text
        return parse_prometheus_text(default_registry().render())

    def metrics_text(self) -> str:
        """Prometheus text exposition (what ``GET /v1/metrics`` serves)."""
        from ..obs.metrics import default_registry
        return default_registry().render()

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        self.engine.close()

    def __enter__(self) -> "NeurStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
