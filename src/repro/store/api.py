"""Typed request/response surface shared by every front door.

One set of dataclasses is the whole contract: the embedded facade
(:class:`repro.store.NeurStore`), the HTTP handlers
(``repro.server.app``) and the network client
(``repro.server.client.StoreClient``) all construct and consume exactly
these types, so the wire schema and the Python API cannot drift apart.

Canonical knob set (the one documented parameter vocabulary — see
``docs/serving.md`` for the full table):

* **store-level defaults**, set once at ``NeurStore.open`` /
  ``StorageEngine(...)``: ``tolerance`` (quantization error bound *p*,
  paper §4.2) and ``tau`` (delta-range similarity threshold, §6.1.3);
* **per-save overrides**: :attr:`SaveRequest.tolerance` /
  :attr:`SaveRequest.tau` — ``None`` means "use the store default";
* **per-load knobs**: ``bits`` (flexible loading — read only the top
  *b* delta bit-planes, §4.3.1; ``None`` = full precision) and
  ``shared_cache`` (route page bytes through the buffer pool; ``False``
  is the private-bytes baseline used by benchmarks).

There are no other spellings: anything that used to be passed ad hoc
(``tolerance=``/``tau=`` kwargs vs engine attributes, ``bits=`` vs
``shared_cache=``) is one of the three tiers above.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Iterator, Mapping

import numpy as np

# Re-exported verbatim: the engine's save statistics ARE the wire-level
# save response (SaveReport.to_dict/from_dict is the JSON body).
from ..core.engine import DEFAULT_TAU, DEFAULT_TOLERANCE, SaveReport

__all__ = [
    "DEFAULT_TAU",
    "DEFAULT_TOLERANCE",
    "LoadHandle",
    "SaveReport",
    "SaveRequest",
    "StoreStats",
]


@dataclasses.dataclass
class SaveRequest:
    """One model to persist — the typed argument of every save surface.

    ``tensors`` maps tensor name → float array, iterated in architecture
    order (records land in page order). ``tolerance``/``tau`` override
    the store defaults for this save only (``None`` = store default).
    """

    name: str
    tensors: Mapping[str, np.ndarray]
    architecture: dict = dataclasses.field(default_factory=dict)
    tolerance: float | None = None
    tau: float | None = None

    def total_bytes(self) -> int:
        """Uncompressed float32 footprint (what quota admission sees).

        The store casts every input to float32 before quantizing, so the
        footprint is ``size * itemsize(f32)`` regardless of the input
        dtype — an f16 upload is *not* half price, and an f64 upload is
        not double. This keeps quota admission and the space accountant
        (``repro.obs.accounting``) charging the same logical bytes.
        """
        itemsize = np.dtype(np.float32).itemsize
        return sum(
            int(np.asarray(t).size) * itemsize for t in self.tensors.values()
        )

    def wire_header(self) -> dict:
        """The JSON header frame of a streamed upload (tensors excluded)."""
        return {
            "name": self.name,
            "architecture": self.architecture,
            "tolerance": self.tolerance,
            "tau": self.tau,
            "n_tensors": len(self.tensors),
        }

    @classmethod
    def from_wire(cls, header: dict,
                  tensors: Mapping[str, np.ndarray]) -> "SaveRequest":
        return cls(
            name=str(header.get("name", "")),
            tensors=tensors,
            architecture=header.get("architecture") or {},
            tolerance=header.get("tolerance"),
            tau=header.get("tau"),
        )


class LoadHandle:
    """Unified typed read handle over one model — embedded or remote.

    Both backends expose the same three access patterns:

    * :meth:`tensors` — stream ``(name, array)`` record-by-record, the
      bounded-memory path (one tensor resident at a time). A remote
      handle decodes frames straight off the socket; an embedded handle
      reconstructs lazily off its pinned snapshot.
    * :meth:`materialize` — the full ``{name: array}`` dict (cached).
    * :meth:`tensor` — one tensor by name.

    Remote streams are one-shot: ``tensors()`` can be consumed once,
    after which only the materialized cache (if built) serves access.
    ``close()`` releases the snapshot (embedded) or drains/abandons the
    response (remote); the handle is a context manager.
    """

    def __init__(self, name: str, architecture: dict, bits: int | None,
                 *, loaded=None, stream=None, close=None):
        self.name = name
        self.architecture = architecture
        self.bits = bits
        self._loaded = loaded        # LoadedModel (embedded backend)
        self._stream = stream        # iterator[(name, array)] (remote)
        self._close = close
        self._cache: dict[str, np.ndarray] | None = None

    # ------------------------------------------------------------ builders
    @classmethod
    def from_loaded(cls, name: str, loaded, bits: int | None = None):
        return cls(name, loaded.info["architecture"], bits, loaded=loaded)

    @classmethod
    def from_stream(cls, header: dict, stream: Iterator, close=None):
        return cls(str(header.get("name", "")),
                   header.get("architecture") or {},
                   header.get("bits"), stream=stream, close=close)

    # -------------------------------------------------------------- access
    def tensors(self) -> Iterator[tuple[str, np.ndarray]]:
        """Stream records one at a time (bounded memory)."""
        if self._cache is not None:
            yield from self._cache.items()
        elif self._loaded is not None:
            yield from self._loaded.iter_tensors()
        elif self._stream is not None:
            stream, self._stream = self._stream, None
            yield from stream
        else:
            raise RuntimeError("load handle already consumed (one-shot "
                               "remote stream); use materialize() up front")

    def materialize(self) -> dict[str, np.ndarray]:
        """Every tensor, reconstructed to float32 (cached after first call)."""
        if self._cache is None:
            self._cache = dict(self.tensors())
        return self._cache

    def tensor(self, name: str) -> np.ndarray:
        if self._loaded is not None and self._cache is None:
            return self._loaded.tensor(name)
        return self.materialize()[name]

    def tensor_names(self) -> list[str]:
        if self._loaded is not None:
            return self._loaded.tensor_names()
        return list(self.materialize())

    # ----------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._loaded is not None:
            self._loaded.close()
        if self._close is not None:
            close, self._close = self._close, None
            close()

    def __enter__(self) -> "LoadHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass
class StoreStats:
    """The *documented* slice of ``StorageEngine.stats()`` — stats-as-API.

    Every field here is stable contract (``docs/serving.md`` documents
    each counter); the admission policy consumes **only** these fields.
    ``raw`` carries the full engine dump for humans and dashboards, with
    no stability promise.
    """

    schema_version: int
    epoch: int
    models: int
    snapshots_live: int
    oldest_epoch: int | None
    pool_resident_bytes: int
    pool_budget_bytes: int
    pool_pinned_bytes: int
    read_only: bool
    corrupt_models: int
    # Space accounting (repro.obs.accounting): logical = uncompressed
    # f32 footprint of all committed models; physical = page bytes plus
    # shared 8-bit base codes; ratio = physical / logical (None when the
    # store is empty).
    logical_bytes: int = 0
    physical_bytes: int = 0
    compression_ratio: float | None = None
    raw: dict = dataclasses.field(default_factory=dict, repr=False)

    @classmethod
    def from_engine(cls, stats: dict) -> "StoreStats":
        """Project an ``StorageEngine.stats()`` dump onto the stable schema."""
        pool = stats.get("buffer_pool", {})
        snaps = stats.get("snapshots", {})
        integ = stats.get("integrity", {})
        acct = stats.get("accounting", {})
        return cls(
            schema_version=int(stats.get("schema_version", 0)),
            epoch=int(stats.get("epoch", 0)),
            models=int(stats.get("models", 0)),
            snapshots_live=int(snaps.get("live", 0)),
            oldest_epoch=snaps.get("oldest_epoch"),
            pool_resident_bytes=int(pool.get("resident_bytes", 0)),
            pool_budget_bytes=int(pool.get("budget_bytes", 0)),
            pool_pinned_bytes=int(pool.get("pinned_bytes", 0)),
            read_only=bool(integ.get("read_only", False)),
            corrupt_models=len(integ.get("corrupt_models", ())),
            logical_bytes=int(acct.get("logical_bytes", 0)),
            physical_bytes=int(acct.get("physical_bytes", 0)),
            compression_ratio=acct.get("compression_ratio"),
            raw=stats,
        )

    # Derived signals the admission policy keys on.
    @property
    def pool_utilization(self) -> float:
        if self.pool_budget_bytes <= 0:
            return 0.0
        return self.pool_resident_bytes / self.pool_budget_bytes

    @property
    def epoch_lag(self) -> int:
        """How many commits behind the oldest live snapshot is (0 if none)."""
        if self.oldest_epoch is None:
            return 0
        return max(0, self.epoch - self.oldest_epoch)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "StoreStats":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})
