"""Dense transformer layers: norms, RoPE, chunked attention, MLPs, MoE.

Pure-functional JAX. Every layer has (a) a sequence ``forward`` used by
train/prefill, and (b) a single-token ``decode`` step against a cache.
Attention is flash-style chunked (lax.scan over KV blocks with running
max/sum) so 32k-prefill and 4k-train never materialize (S, S) scores —
required to keep the dry-run memory analysis inside HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed import sharding as sh

Params = dict[str, Any]


# --------------------------------------------------------------------- norms
def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


# ---------------------------------------------------------------------- rope
def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                      # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
#
# GQA is computed in *grouped* form everywhere: q is viewed as
# (B, Sq, KV, G, dh) with H = KV·G and contracted directly against the
# (B, Sk, KV, dh) keys/values. The repeated-KV tensor (B, S, H, dh) is never
# materialized — at deepseek decode_32k that repeat was 4.3 GB per layer per
# device and forced GSPMD to all-gather the sequence-sharded cache.


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 1024, q_offset: int = 0,
                      unroll: bool = False):
    """Flash-style attention: scan over KV chunks with running (m, l, acc).

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh) (grouped GQA — no repeat).
    ``q_offset`` is the absolute position of q[0] relative to k[0]
    (prefill: 0; decode: cache length). ``window > 0`` restricts to a
    causal local window (recurrentgemma). Never materializes (Sq, Sk);
    peak extra memory is (B, H, Sq, chunk) scores per step.
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, dh)
    scale = 1.0 / (dh ** 0.5)
    ck = min(chunk, sk)
    assert sk % ck == 0, (sk, ck)
    n_chunks = sk // ck

    q_pos = (q_offset + jnp.arange(sq))[None, :]           # (1, Sq)

    def body(carry, inputs):
        m, l, acc = carry
        k_c, v_c, k_start = inputs                         # (B, ck, KV, dh)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_c).astype(jnp.float32) * scale
        k_pos = (k_start + jnp.arange(ck))
        mask = jnp.ones(s.shape[-2:], dtype=bool)[None, None, None]
        qp = q_pos[:, None, None, :, None]
        kp = k_pos[None, None, None, None, :]
        if causal:
            mask = mask & (qp >= kp)
        if window > 0:
            mask = mask & ((qp - kp) < window)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(v_c.dtype), v_c).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32),
        jnp.zeros((b, kv, g, sq), jnp.float32),
        jnp.zeros((b, kv, g, sq, dh), jnp.float32),
    )
    ks = k.reshape(b, n_chunks, ck, kv, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_chunks, ck, kv, dh).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(n_chunks) * ck
    (m, l, acc), _ = jax.lax.scan(body, init, (ks, vs, starts),
                                  unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,KV,G,Sq,dh)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttentionBlock:
    """GQA attention with RoPE, optional qk-norm and local window."""

    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float
    causal: bool = True
    window: int = 0
    qk_norm: bool = False
    chunk: int = 1024
    norm_eps: float = 1e-6
    unroll: bool = False

    def init(self, key, d_model, dtype):
        ks = jax.random.split(key, 4)
        h, kv, dh = self.n_heads, self.n_kv_heads, self.d_head
        std = d_model ** -0.5
        p = {
            "wq": (jax.random.normal(ks[0], (d_model, h, dh)) * std).astype(dtype),
            "wk": (jax.random.normal(ks[1], (d_model, kv, dh)) * std).astype(dtype),
            "wv": (jax.random.normal(ks[2], (d_model, kv, dh)) * std).astype(dtype),
            "wo": (jax.random.normal(ks[3], (h, dh, d_model)) * std * (2 * h) ** -0.5).astype(dtype),
        }
        if self.qk_norm:
            p["q_norm"] = jnp.ones((dh,), dtype)
            p["k_norm"] = jnp.ones((dh,), dtype)
        return p

    def _qkv(self, p, x, positions):
        q = sh.constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), "heads")
        k = sh.constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), "kv_heads")
        v = sh.constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), "kv_heads")
        if self.qk_norm:
            q = rms_norm(q, p["q_norm"], self.norm_eps)
            k = rms_norm(k, p["k_norm"], self.norm_eps)
        q = apply_rope(q, positions, self.rope_theta)
        k = apply_rope(k, positions, self.rope_theta)
        return q, k, v

    def forward(self, p, x, positions):
        """x: (B, S, D) → (B, S, D); full-sequence (train / prefill)."""
        q, k, v = self._qkv(p, x, positions)
        o = chunked_attention(q, k, v, causal=self.causal, window=self.window,
                              chunk=self.chunk, unroll=self.unroll)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return sh.constrain(out, "residual")

    # ------------------------------------------------------------- decode
    def init_cache(self, batch, max_len, dtype):
        # Layout (B, KV, S, dh): the decode einsums contract directly over
        # the trailing (S, dh) — no per-layer transposes of the multi-GB
        # cache (the (B, S, KV, dh) layout cost 256 MiB copies per layer on
        # deepseek decode_32k).
        kv, dh = self.n_kv_heads, self.d_head
        length = min(max_len, self.window) if self.window else max_len
        return {
            "k": jnp.zeros((batch, kv, length, dh), dtype),
            "v": jnp.zeros((batch, kv, length, dh), dtype),
        }

    def decode(self, p, x, cache, pos):
        """x: (B, 1, D); pos: scalar absolute position. Returns (out, cache)."""
        q, k, v = self._qkv(p, x, pos[None, None] if pos.ndim == 0 else pos)
        length = cache["k"].shape[2]
        slot = (pos % length) if self.window else pos
        k_new = k.transpose(0, 2, 1, 3)                    # (B, KV, 1, dh)
        v_new = v.transpose(0, 2, 1, 3)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=2)
        logical = sh.cache_logical(self.n_kv_heads)
        ck = sh.constrain(ck, logical)
        cv = sh.constrain(cv, logical)
        kv, g = self.n_kv_heads, self.n_heads // self.n_kv_heads
        b = q.shape[0]
        qg = q.reshape(b, 1, kv, g, self.d_head)[:, 0]     # (B, KV, G, dh)
        scale = 1.0 / (self.d_head ** 0.5)
        # Grouped scores against the (possibly sequence-sharded) cache —
        # clean batched matmul over (S, dh); softmax/combine reductions over
        # the sharded S are partial-reduce + tiny all-reduce under GSPMD.
        s = jnp.einsum("bkgd,bksd->bkgs", qg, ck).astype(jnp.float32) * scale
        k_idx = jnp.arange(length)[None, None, None, :]
        if self.window:
            # Ring buffer: entry j holds absolute position
            # a_j = pos - ((slot - j) mod L); valid iff a_j >= 0 (window == L
            # keeps every live entry in range automatically).
            a_j = pos - ((slot - k_idx) % length)
            s = jnp.where(a_j >= 0, s, -1e30)
        else:
            s = jnp.where(k_idx <= pos, s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
        o = jnp.einsum("bkgs,bksd->bkgd", w, cv)
        o = o.reshape(b, 1, self.n_heads, self.d_head)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------- MLPs
@dataclasses.dataclass(frozen=True)
class SwiGLU:
    d_ff: int

    def init(self, key, d_model, dtype):
        ks = jax.random.split(key, 3)
        std_in = d_model ** -0.5
        std_out = self.d_ff ** -0.5
        return {
            "wg": (jax.random.normal(ks[0], (d_model, self.d_ff)) * std_in).astype(dtype),
            "wu": (jax.random.normal(ks[1], (d_model, self.d_ff)) * std_in).astype(dtype),
            "wd": (jax.random.normal(ks[2], (self.d_ff, d_model)) * std_out).astype(dtype),
        }

    def forward(self, p, x):
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
        h = sh.constrain(h, "ffn")
        return sh.constrain(h @ p["wd"], "residual")

    decode = None  # stateless


@dataclasses.dataclass(frozen=True)
class GeluMLP:
    d_ff: int

    def init(self, key, d_model, dtype):
        ks = jax.random.split(key, 2)
        return {
            "w1": (jax.random.normal(ks[0], (d_model, self.d_ff)) * d_model ** -0.5).astype(dtype),
            "w2": (jax.random.normal(ks[1], (self.d_ff, d_model)) * self.d_ff ** -0.5).astype(dtype),
        }

    def forward(self, p, x):
        h = jax.nn.gelu(sh.constrain(x @ p["w1"], "ffn"))
        return sh.constrain(h @ p["w2"], "residual")

    decode = None


@dataclasses.dataclass(frozen=True)
class MoE:
    """Top-k routed experts with capacity-based einsum dispatch (EP over
    the data axis, expert-hidden over model — DESIGN.md §5). Optionally a
    parallel dense residual MLP (arctic)."""

    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_residual: bool = False

    def init(self, key, d_model, dtype):
        ks = jax.random.split(key, 5)
        e, f = self.n_experts, self.d_ff
        std_in = d_model ** -0.5
        p = {
            "router": (jax.random.normal(ks[0], (d_model, e)) * std_in).astype(jnp.float32),
            "wg": (jax.random.normal(ks[1], (e, d_model, f)) * std_in).astype(dtype),
            "wu": (jax.random.normal(ks[2], (e, d_model, f)) * std_in).astype(dtype),
            "wd": (jax.random.normal(ks[3], (e, f, d_model)) * f ** -0.5).astype(dtype),
        }
        if self.dense_residual:
            p["dense"] = SwiGLU(self.d_ff).init(ks[4], d_model, dtype)
        return p

    def _capacity(self, n_tokens: int) -> int:
        c = int(self.capacity_factor * self.top_k * n_tokens / self.n_experts)
        return max(c, self.top_k)

    def forward(self, p, x):
        """Grouped (per-batch-row) dispatch, GShard-style.

        Routing positions come from a cumsum *within each row* — fully local
        under batch sharding (a global cumsum over all tokens serializes
        across every data shard; that was the dominant collective cost of
        the first implementation — EXPERIMENTS.md §Perf, arctic train_4k).
        Capacity is per row (cf·k·S/E). Expert compute is E-sharded over
        'data' (EP): GSPMD turns the B-sharded → E-sharded boundary into the
        canonical token all-to-all, ~B·S·k·cf·D bytes per layer.
        No (T, E, C) one-hot tensor is ever built (10^13 elements at arctic
        train scale)."""
        b, s, d = x.shape
        e, k = self.n_experts, self.top_k
        cap = max(int(self.capacity_factor * k * s / e), k)      # per row
        logits = x.astype(jnp.float32) @ p["router"]             # (B, S, E)
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gates, k)                   # (B, S, k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(b, s * k)                         # (B, S·k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (B, S·k, E)
        pos = ((jnp.cumsum(onehot, axis=1) - 1) * onehot).sum(-1)  # (B, S·k)
        keep = pos < cap
        dest = jnp.where(keep, flat_e * cap + pos, e * cap)      # overflow row
        tok = jnp.arange(s * k) // k
        x_rows = x[:, tok]                                       # (B, S·k, D)
        b_idx = jnp.arange(b)[:, None]
        xe = jnp.zeros((b, e * cap + 1, d), x.dtype).at[b_idx, dest].add(x_rows)
        xe = sh.constrain(xe[:, : e * cap].reshape(b, e, cap, d), "moe_tokens")
        # E-sharded expert compute — the constraint boundary below is the
        # all-to-all (tokens travel to their experts' data shards).
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"]))
        h = h * jnp.einsum("becd,edf->becf", xe, p["wu"])
        h = sh.constrain(h, "moe_hidden")
        ye = jnp.einsum("becf,efd->becd", h, p["wd"])
        ye = sh.constrain(ye, "moe_tokens")                      # a2a back
        ye_flat = jnp.concatenate(
            [ye.reshape(b, e * cap, d),
             jnp.zeros((b, 1, d), ye.dtype)], axis=1)
        y = ye_flat[b_idx, dest] * top_g.reshape(b, s * k, 1).astype(ye.dtype)
        y = y.reshape(b, s, k, d).sum(2)
        if self.dense_residual:
            y = y + SwiGLU(self.d_ff).forward(p["dense"], x)
        return sh.constrain(y, "residual")

    decode = None
