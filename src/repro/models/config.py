"""Model configuration for the assigned architecture zoo.

One :class:`ModelConfig` describes any of the 10 assigned architectures.
Layers are organised as repeated *periods* (e.g. recurrentgemma's
(rglru, rglru, attn) 2:1 pattern) so the stack can be `lax.scan`-ned over
periods with stacked parameters — essential to keep HLO size and compile
time bounded for 95-layer models.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

BlockType = Literal["attn", "local_attn", "rglru", "rwkv6"]
MixType = Literal["swiglu", "gelu", "moe", "moe_dense", "rwkv_cm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 → d_model // n_heads

    # Sequence-mix / channel-mix block types per layer period.
    period: tuple[str, ...] = ("attn",)       # BlockType per period slot
    mix: tuple[str, ...] = ("swiglu",)        # MixType per period slot
    tail: tuple[str, ...] = ()                # remainder BlockTypes
    tail_mix: tuple[str, ...] = ()

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # Recurrent / local attention
    window: int = 0              # local attention window (recurrentgemma)
    d_rnn: int = 0               # RG-LRU width (0 → d_model)
    rwkv_head_dim: int = 64

    # Features
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    has_decode: bool = True      # encoder-only → False
    subquadratic: bool = False   # eligible for long_500k
    frontend: str = "tokens"     # tokens | embeddings (audio/vlm stub)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # Precision
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # Training memory knobs (overridable per shape at launch)
    remat: bool = True
    attn_chunk: int = 1024       # flash-style KV/Q chunking
    # Dry-run probe flags: fully unroll scans so XLA cost_analysis (which
    # counts while bodies ONCE) sees every iteration. Never set in prod.
    unroll_periods: bool = False
    scan_unroll: bool = False

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.d_rnn == 0:
            object.__setattr__(self, "d_rnn", self.d_model)
        assert len(self.period) == len(self.mix)
        assert len(self.tail) == len(self.tail_mix)
        n = self.n_periods * len(self.period) + len(self.tail)
        assert n == self.n_layers, (
            f"{self.name}: period×{self.n_periods}+tail covers {n} layers, "
            f"config says {self.n_layers}")

    @property
    def n_periods(self) -> int:
        return (self.n_layers - len(self.tail)) // len(self.period)

    @property
    def n_params(self) -> int:
        """Total parameter count (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, dh = self.n_heads, self.n_kv_heads, self.d_head
        counts = {"embed": v * d, "head": 0 if self.tie_embeddings else d * v}
        per_block = {}
        per_block["attn"] = d * h * dh + 2 * d * kv * dh + h * dh * d
        per_block["local_attn"] = per_block["attn"]
        r = self.d_rnn
        per_block["rglru"] = 2 * d * r + 4 * r + 2 * r * r + 2 * r + r * d
        hd = self.rwkv_head_dim
        nh = d // hd
        per_block["rwkv6"] = 4 * d * d + 2 * (d * 64 + 64 * d) + nh * hd + d * d
        per_mix = {
            "swiglu": 3 * d * f,
            "gelu": 2 * d * f,
            "moe": d * self.n_experts + self.n_experts * 3 * d * f,
            "moe_dense": d * self.n_experts + self.n_experts * 3 * d * f + 3 * d * f,
            "rwkv_cm": 2 * d * f + d * d,
        }
        total = counts["embed"] + counts["head"] + 2 * d  # final norm + bias-ish
        for b, m in self.layer_types():
            total += per_block[b] + per_mix[m] + 2 * d
        return total

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.n_params
        d, f = self.d_model, self.d_ff
        inactive = 0
        for _, m in self.layer_types():
            if m.startswith("moe"):
                inactive += (self.n_experts - self.top_k) * 3 * d * f
        return self.n_params - inactive

    def layer_types(self) -> list[tuple[str, str]]:
        """[(block, mix)] for all n_layers in order."""
        out = list(zip(self.period, self.mix)) * self.n_periods
        out += list(zip(self.tail, self.tail_mix))
        return out

    def supports_shape(self, shape_name: str) -> bool:
        if not self.has_decode and shape_name in ("decode_32k", "long_500k"):
            return False
        if shape_name == "long_500k" and not self.subquadratic:
            return False
        return True


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
