"""Recurrent sequence-mix blocks: RG-LRU (Griffin/recurrentgemma) and RWKV-6.

Both are sub-quadratic and state-based → they serve the ``long_500k`` cell
with O(1)-in-seq decode state.

RG-LRU trains via ``jax.lax.associative_scan`` (O(T log T) work, parallel
depth log T — the TPU-idiomatic mapping of a linear recurrence).

RWKV-6 trains in **chunked linear-attention form** (GLA-style): the
recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T is advanced chunk-by-chunk
with intra-chunk contributions computed as masked matmuls on the MXU.
Per-channel decays are kept in log space; with ``logw`` clamped to
[-CLAMP, 0) and chunk length L, every factored exponent is bounded by
L·CLAMP < 88 so all intermediates stay inside f32 range (the TPU-side
equivalent of fla's secondary-chunking trick — recorded in DESIGN.md).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..distributed import sharding as sh

RWKV_CHUNK = 32
LOGW_CLAMP = 2.5  # |logw| <= 2.5 → exponents <= 32*2.5 = 80 < log(f32max)


# ------------------------------------------------------------------- RG-LRU
@dataclasses.dataclass(frozen=True)
class RGLRUBlock:
    """Griffin recurrent block: conv4 → RG-LRU → GeLU-gated output."""

    d_rnn: int
    conv_width: int = 4
    c: float = 8.0

    def init(self, key, d_model, dtype):
        ks = jax.random.split(key, 6)
        r = self.d_rnn
        std = d_model ** -0.5
        stdr = r ** -0.5
        return {
            "wx": (jax.random.normal(ks[0], (d_model, r)) * std).astype(dtype),
            "wgate": (jax.random.normal(ks[1], (d_model, r)) * std).astype(dtype),
            "conv": (jax.random.normal(ks[2], (self.conv_width, r)) * 0.1).astype(dtype),
            "wa": (jax.random.normal(ks[3], (r, r)) * stdr).astype(dtype),
            "wi": (jax.random.normal(ks[4], (r, r)) * stdr).astype(dtype),
            # Λ init so a^c ≈ 0.9..0.99 decay (Griffin §2.4).
            "lam": jnp.linspace(0.5, 4.0, r).astype(jnp.float32),
            "wo": (jax.random.normal(ks[5], (r, d_model)) * stdr).astype(dtype),
        }

    def _gates(self, p, u):
        """u: (B, S, R) post-conv → (log_a, gated input) in f32."""
        r_g = jax.nn.sigmoid(u @ p["wa"]).astype(jnp.float32)
        i_g = jax.nn.sigmoid(u @ p["wi"]).astype(jnp.float32)
        log_a = -self.c * jax.nn.softplus(p["lam"]) * r_g      # (B,S,R) < 0
        beta = jnp.sqrt(1.0 - jnp.exp(2.0 * log_a) + 1e-9)
        b = beta * (i_g * u.astype(jnp.float32))
        return log_a, b

    def _conv(self, p, u, carry=None):
        """Causal depthwise conv width-4. carry: (B, w-1, R) previous inputs."""
        w = self.conv_width
        if carry is None:
            carry = jnp.zeros((u.shape[0], w - 1, u.shape[-1]), u.dtype)
        ext = jnp.concatenate([carry, u], axis=1)
        out = sum(ext[:, i:i + u.shape[1]] * p["conv"][i] for i in range(w))
        return out, ext[:, -(w - 1):]

    def forward(self, p, x, state=None):
        """x: (B,S,D) → (B,S,D); optionally return final state for decode."""
        u = sh.constrain(x @ p["wx"], "rnn_act")
        g = jax.nn.gelu(x @ p["wgate"])
        h0 = None if state is None else state["h"]
        conv_carry = None if state is None else state["conv"]
        u, conv_out = self._conv(p, u, conv_carry)
        log_a, b = self._gates(p, u)
        if h0 is not None:
            # Fold the incoming state into the first step: b_0 += a_0 * h0.
            b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

        def combine(left, right):
            la_l, b_l = left
            la_r, b_r = right
            return la_l + la_r, b_l * jnp.exp(la_r) + b_r

        _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
        h = sh.constrain(h.astype(x.dtype), "rnn_act")
        out = sh.constrain((g * h) @ p["wo"], "residual")
        new_state = {"h": h[:, -1].astype(jnp.float32), "conv": conv_out}
        return out, new_state

    # -------------------------------------------------------------- decode
    def init_state(self, batch, dtype):
        return {
            "h": jnp.zeros((batch, self.d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, self.conv_width - 1, self.d_rnn), dtype),
        }

    def decode(self, p, x, state):
        """x: (B,1,D) single step."""
        u = x @ p["wx"]
        g = jax.nn.gelu(x @ p["wgate"])
        u, conv_carry = self._conv(p, u, state["conv"])
        log_a, b = self._gates(p, u)
        h = jnp.exp(log_a[:, 0]) * state["h"] + b[:, 0]
        out = (g[:, 0] * h.astype(x.dtype)) @ p["wo"]
        return out[:, None], {"h": h, "conv": conv_carry}


# -------------------------------------------------------------------- RWKV6
@dataclasses.dataclass(frozen=True)
class RWKV6TimeMix:
    """Finch time-mix: data-dependent per-channel decay, chunked training."""

    n_heads: int
    d_head: int
    lora_rank: int = 64
    unroll: bool = False

    def init(self, key, d_model, dtype):
        ks = jax.random.split(key, 8)
        d = d_model
        h, dh = self.n_heads, self.d_head
        assert h * dh == d
        std = d ** -0.5
        return {
            "mu": (jax.random.uniform(ks[0], (5, d))).astype(dtype),  # r,k,v,w,g
            "wr": (jax.random.normal(ks[1], (d, d)) * std).astype(dtype),
            "wk": (jax.random.normal(ks[2], (d, d)) * std).astype(dtype),
            "wv": (jax.random.normal(ks[3], (d, d)) * std).astype(dtype),
            "wg": (jax.random.normal(ks[4], (d, d)) * std).astype(dtype),
            "w_lora_a": (jax.random.normal(ks[5], (d, self.lora_rank)) * std).astype(dtype),
            "w_lora_b": (jax.random.normal(ks[6], (self.lora_rank, d))
                         * self.lora_rank ** -0.5).astype(dtype),
            "lam": jnp.full((d,), -1.5, jnp.float32),
            "u": (jax.random.normal(ks[7], (h, dh)) * 0.1).astype(jnp.float32),
            "ln_w": jnp.ones((d,), dtype),
            "wo": (jax.random.normal(ks[0], (d, d)) * std).astype(dtype),
        }

    def _proj(self, p, x, x_prev):
        """Token-shift lerp + projections. x, x_prev: (B,S,D)."""
        mu = p["mu"]
        mix = lambda i: x * mu[i] + x_prev * (1 - mu[i])
        b, s, d = x.shape
        h, dh = self.n_heads, self.d_head
        r = (mix(0) @ p["wr"]).reshape(b, s, h, dh)
        k = (mix(1) @ p["wk"]).reshape(b, s, h, dh)
        v = (mix(2) @ p["wv"]).reshape(b, s, h, dh)
        lora = jnp.tanh(mix(3) @ p["w_lora_a"]) @ p["w_lora_b"]
        logw = -jnp.exp(p["lam"] + lora.astype(jnp.float32))
        logw = jnp.clip(logw, -LOGW_CLAMP, -1e-6).reshape(b, s, h, dh)
        g = jax.nn.silu(mix(4) @ p["wg"])
        return r, k, v, logw, g

    def _norm_out(self, p, y, g, b, s):
        d = self.n_heads * self.d_head
        y = y.reshape(b, s, self.n_heads, self.d_head)
        # Per-head group norm.
        mean = y.mean(-1, keepdims=True)
        var = y.var(-1, keepdims=True)
        y = ((y - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d)
        y = y * p["ln_w"]
        return (y.astype(g.dtype) * g) @ p["wo"]

    def forward(self, p, x, state=None):
        """x: (B,S,D), S % RWKV_CHUNK == 0. Returns (out, new_state)."""
        b, s, d = x.shape
        h, dh = self.n_heads, self.d_head
        L = min(RWKV_CHUNK, s)
        assert s % L == 0
        shift = state["shift_tm"] if state is not None else jnp.zeros((b, d), x.dtype)
        x_prev = jnp.concatenate([shift[:, None], x[:, :-1]], axis=1)
        r, k, v, logw, g = self._proj(p, x, x_prev)
        r = sh.constrain(r, "rwkv_act")
        k = sh.constrain(k, "rwkv_act")
        v = sh.constrain(v, "rwkv_act")
        n_chunks = s // L
        # (C, B, H, L, dh) chunk-major for the scan.
        resh = lambda t: t.reshape(b, n_chunks, L, h, dh).transpose(1, 0, 3, 2, 4)
        rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(logw)
        S0 = (state["wkv"] if state is not None
              else jnp.zeros((b, h, dh, dh), jnp.float32))
        u = p["u"]  # (H, dh)
        mask = jnp.tril(jnp.ones((L, L), bool), k=-1)

        def chunk_step(S, inp):
            rc_, kc_, vc_, wc_ = inp           # (B,H,L,dh); wc_ f32
            c_inc = jnp.cumsum(wc_, axis=2)    # inclusive Σ logw
            c_exc = c_inc - wc_                # exclusive
            cL = c_inc[:, :, -1:]              # (B,H,1,dh)
            rf = rc_.astype(jnp.float32)
            kf = kc_.astype(jnp.float32)
            vf = vc_.astype(jnp.float32)
            q_t = rf * jnp.exp(c_exc)                    # exponents <= 0
            k_t = kf * jnp.exp(-c_inc)                   # exponents in [0, L*CLAMP]
            A = jnp.einsum("bhid,bhjd->bhij", q_t, k_t)
            A = jnp.where(mask, A, 0.0)
            # Diagonal bonus: A_ii = Σ_d r_id · u_d · k_id  (RWKV "u" term).
            diag = (rf * u[None, :, None, :] * kf).sum(-1)     # (B,H,L)
            A = A + diag[..., None] * jnp.eye(L, dtype=A.dtype)
            y = jnp.einsum("bhij,bhjd->bhid", A, vf)
            y = y + jnp.einsum("bhid,bhde->bhie", q_t, S)
            k_hat = kf * jnp.exp(cL - c_inc)             # exponents <= 0
            S_new = jnp.exp(cL.squeeze(2))[..., None] * S + jnp.einsum(
                "bhjd,bhje->bhde", k_hat, vf)
            return S_new, y

        S_final, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc),
                                   unroll=True if self.unroll else 1)
        y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h * dh)
        out = self._norm_out(p, y, g, b, s)
        new_state = {"wkv": S_final, "shift_tm": x[:, -1]}
        return sh.constrain(out, "residual"), new_state

    # -------------------------------------------------------------- decode
    def init_state(self, batch, d_model, dtype):
        return {
            "wkv": jnp.zeros((batch, self.n_heads, self.d_head, self.d_head),
                             jnp.float32),
            "shift_tm": jnp.zeros((batch, d_model), dtype),
        }

    def decode(self, p, x, state):
        b, _, d = x.shape
        h, dh = self.n_heads, self.d_head
        x_prev = state["shift_tm"][:, None]
        r, k, v, logw, g = self._proj(p, x, x_prev)
        rf = r[:, 0].astype(jnp.float32)        # (B,H,dh)
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        w = jnp.exp(logw[:, 0])
        S = state["wkv"]
        u = p["u"]
        # y = r · (S + diag(u) k v^T); S' = diag(w) S + k v^T
        y = jnp.einsum("bhd,bhde->bhe", rf, S)
        y = y + jnp.einsum("bhd,bhd,bhe->bhe", rf, u[None] * kf, vf)
        S_new = w[..., None] * S + jnp.einsum("bhd,bhe->bhde", kf, vf)
        out = self._norm_out(p, y.reshape(b, 1, h * dh), g, b, 1)
        return out, {"wkv": S_new, "shift_tm": x[:, 0]}


@dataclasses.dataclass(frozen=True)
class RWKV6ChannelMix:
    """Finch channel-mix: token-shift + squared-ReLU MLP with receptance."""

    d_ff: int

    def init(self, key, d_model, dtype):
        ks = jax.random.split(key, 3)
        return {
            "mu": jax.random.uniform(ks[0], (2, d_model)).astype(dtype),  # k, r
            "wk": (jax.random.normal(ks[0], (d_model, self.d_ff)) * d_model ** -0.5).astype(dtype),
            "wv": (jax.random.normal(ks[1], (self.d_ff, d_model)) * self.d_ff ** -0.5).astype(dtype),
            "wr": (jax.random.normal(ks[2], (d_model, d_model)) * d_model ** -0.5).astype(dtype),
        }

    def forward(self, p, x, state=None):
        b, s, d = x.shape
        shift = state["shift_cm"] if state is not None else jnp.zeros((b, d), x.dtype)
        x_prev = jnp.concatenate([shift[:, None], x[:, :-1]], axis=1)
        mu = p["mu"]
        xk = x * mu[0] + x_prev * (1 - mu[0])
        xr = x * mu[1] + x_prev * (1 - mu[1])
        k = jnp.square(jax.nn.relu(sh.constrain(xk @ p["wk"], "ffn")))
        out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
        return sh.constrain(out, "residual"), {"shift_cm": x[:, -1]}

    def init_state(self, batch, d_model, dtype):
        return {"shift_cm": jnp.zeros((batch, d_model), dtype)}

    def decode(self, p, x, state):
        out, new_state = self.forward(p, x, state)
        return out, new_state
