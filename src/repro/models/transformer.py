"""The composable model stack: init, train forward, prefill, decode.

Layers are organised as repeated *periods* (config.period), scanned with
stacked parameters (`lax.scan` over the period axis) and per-period remat —
the standard JAX recipe that keeps HLO size O(1) in depth for 95-layer
models and bounds saved activations to one residual per period.

Block = sequence-mix (attn / local_attn / rglru / rwkv6) + channel-mix
(swiglu / gelu / moe / moe_dense / rwkv_cm), each pre-RMSNormed with a
residual add (pre-LN).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..distributed import sharding as sh
from .config import ModelConfig
from .layers import AttentionBlock, GeluMLP, MoE, SwiGLU, rms_norm
from .recurrent import RGLRUBlock, RWKV6ChannelMix, RWKV6TimeMix

Params = dict[str, Any]


# ------------------------------------------------------------- block builders
def _seq_block(cfg: ModelConfig, kind: str):
    if kind in ("attn", "local_attn"):
        return AttentionBlock(
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head,
            rope_theta=cfg.rope_theta,
            causal=cfg.causal,
            window=cfg.window if kind == "local_attn" else 0,
            qk_norm=cfg.qk_norm,
            chunk=cfg.attn_chunk,
            norm_eps=cfg.norm_eps,
            unroll=cfg.scan_unroll,
        )
    if kind == "rglru":
        return RGLRUBlock(d_rnn=cfg.d_rnn)
    if kind == "rwkv6":
        return RWKV6TimeMix(n_heads=cfg.d_model // cfg.rwkv_head_dim,
                            d_head=cfg.rwkv_head_dim,
                            unroll=cfg.scan_unroll)
    raise ValueError(kind)


def _mix_block(cfg: ModelConfig, kind: str):
    if kind == "swiglu":
        return SwiGLU(cfg.d_ff)
    if kind == "gelu":
        return GeluMLP(cfg.d_ff)
    if kind in ("moe", "moe_dense"):
        return MoE(cfg.d_ff, cfg.n_experts, cfg.top_k, cfg.capacity_factor,
                   dense_residual=(kind == "moe_dense"))
    if kind == "rwkv_cm":
        return RWKV6ChannelMix(cfg.d_ff)
    raise ValueError(kind)


def _blocks_for_period(cfg: ModelConfig):
    return [( _seq_block(cfg, b), _mix_block(cfg, m))
            for b, m in zip(cfg.period, cfg.mix)]


def _blocks_for_tail(cfg: ModelConfig):
    return [( _seq_block(cfg, b), _mix_block(cfg, m))
            for b, m in zip(cfg.tail, cfg.tail_mix)]


# ----------------------------------------------------------------------- init
def _init_layer(key, cfg, seq_blk, mix_blk, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "seq": seq_blk.init(k1, cfg.d_model, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mix": mix_blk.init(k2, cfg.d_model, dtype),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    period_blocks = _blocks_for_period(cfg)
    tail_blocks = _blocks_for_tail(cfg)

    def init_period(k):
        ks = jax.random.split(k, len(period_blocks))
        return {f"slot{i}": _init_layer(ks[i], cfg, sb, mb, dtype)
                for i, (sb, mb) in enumerate(period_blocks)}

    period_keys = jax.random.split(keys[0], cfg.n_periods)
    params: Params = {
        "embed": (jax.random.normal(keys[1], (cfg.vocab_size, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "periods": jax.vmap(init_period)(period_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if tail_blocks:
        tks = jax.random.split(keys[2], len(tail_blocks))
        params["tail"] = [
            _init_layer(tks[i], cfg, sb, mb, dtype)
            for i, (sb, mb) in enumerate(tail_blocks)
        ]
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[3], (cfg.d_model, cfg.vocab_size))
            * cfg.d_model ** -0.5).astype(dtype)
    return params


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct pytree without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# --------------------------------------------------------- forward (sequence)
def _apply_layer(cfg, seq_blk, mix_blk, p, x, positions, state=None):
    """Pre-LN residual block. Returns (x, new_state)."""
    # Pin the norm OUTPUT sharding (bf16): without this GSPMD may place the
    # layer-boundary all-gather on the norm's f32 intermediate — 2× the
    # collective bytes (measured on internlm2 train_4k, EXPERIMENTS.md §Perf).
    h = sh.constrain(rms_norm(x, p["norm1"], cfg.norm_eps), "residual")
    if isinstance(seq_blk, AttentionBlock):
        a = seq_blk.forward(p["seq"], h, positions)
        new_seq_state = None
    else:
        a, new_seq_state = seq_blk.forward(p["seq"], h, state)
    x = x + a
    h = sh.constrain(rms_norm(x, p["norm2"], cfg.norm_eps), "residual")
    if isinstance(mix_blk, RWKV6ChannelMix):
        m, new_cm_state = mix_blk.forward(p["mix"], h,
                                          state if state else None)
        if new_seq_state is None:
            new_seq_state = {}
        if new_cm_state:
            new_seq_state.update(new_cm_state)
    else:
        m = mix_blk.forward(p["mix"], h)
    x = sh.constrain(x + m, "residual")
    return x, new_seq_state


def _embed_in(cfg: ModelConfig, params, batch):
    # Modality-stub frontends (audio/vlm) feed precomputed embeddings; VLM
    # decode still feeds text tokens — dispatch on the batch key.
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
        return sh.constrain(x, "embeds_in")
    tokens = sh.constrain(batch["tokens"], "tokens")
    x = jnp.take(params["embed"], tokens, axis=0)
    return sh.constrain(x.astype(jnp.dtype(cfg.compute_dtype)), "residual")


def forward(params: Params, batch: dict, cfg: ModelConfig):
    """Full-sequence forward → logits (B, S, V). Train/prefill path."""
    x = _embed_in(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    period_blocks = _blocks_for_period(cfg)

    def period_fn(x, p_period):
        for i, (sb, mb) in enumerate(period_blocks):
            x, _ = _apply_layer(cfg, sb, mb, p_period[f"slot{i}"], x, positions)
        return x, None

    if cfg.remat:
        period_fn = jax.checkpoint(period_fn)
    if cfg.unroll_periods:
        for i in range(cfg.n_periods):
            x, _ = period_fn(x, jax.tree.map(lambda t: t[i], params["periods"]))
    else:
        x, _ = jax.lax.scan(period_fn, x, params["periods"])
    for i, (sb, mb) in enumerate(_blocks_for_tail(cfg)):
        x, _ = _apply_layer(cfg, sb, mb, params["tail"][i], x, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return sh.constrain(logits, "logits")


def loss_fn(params: Params, batch: dict, cfg: ModelConfig):
    """Mean next-token cross entropy (labels already shifted by the data
    pipeline). Returns (loss, metrics)."""
    logits = forward(params, batch, cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch.get("mask")
    # Sharding-friendly CE: all reductions over the (model-sharded) vocab
    # dim are partial-reduce + tiny all-reduce. take_along_axis would force
    # GSPMD to all-gather the full (B, S, V) logits — never do that.
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = labels[..., None] == jnp.arange(logits.shape[-1])[None, None, :]
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    if mask is None:
        loss = nll.mean()
        denom = nll.size
    else:
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        denom = mask.sum()
    return loss, {"loss": loss, "tokens": denom}


# -------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache pytree, stacked over periods like the params."""
    dtype = jnp.dtype(cfg.compute_dtype)
    period_blocks = _blocks_for_period(cfg)

    def one_layer(sb, mb):
        c = {}
        if isinstance(sb, AttentionBlock):
            c.update(sb.init_cache(batch, max_len, dtype))
        elif isinstance(sb, RGLRUBlock):
            c.update(sb.init_state(batch, dtype))
        elif isinstance(sb, RWKV6TimeMix):
            c.update(sb.init_state(batch, cfg.d_model, dtype))
        if isinstance(mb, RWKV6ChannelMix):
            c.update(mb.init_state(batch, cfg.d_model, dtype))
        return c

    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), tree)

    cache = {
        "periods": {f"slot{i}": stack(one_layer(sb, mb))
                    for i, (sb, mb) in enumerate(period_blocks)},
    }
    tail_blocks = _blocks_for_tail(cfg)
    if tail_blocks:
        cache["tail"] = [one_layer(sb, mb) for sb, mb in tail_blocks]
    return cache


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def _decode_layer(cfg, seq_blk, mix_blk, p, x, cache, pos):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if isinstance(seq_blk, AttentionBlock):
        a, new_cache = seq_blk.decode(p["seq"], h, cache, pos)
    else:
        a, new_cache = seq_blk.decode(p["seq"], h,
                                      {k: cache[k] for k in cache
                                       if not k.startswith("shift_cm")})
    x = x + a
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if isinstance(mix_blk, RWKV6ChannelMix):
        m, cm_cache = mix_blk.decode(p["mix"], h,
                                     {"shift_cm": cache["shift_cm"]})
        new_cache = {**new_cache, **cm_cache}
    else:
        m = mix_blk.forward(p["mix"], h)
    return x + m, new_cache


def decode_step(params: Params, cache, batch: dict, pos, cfg: ModelConfig):
    """One token for the whole batch. batch: {"tokens": (B,1)} (or embeds).

    ``pos`` is the scalar absolute position (cache fill level). Returns
    (logits (B, 1, V), new_cache).
    """
    x = _embed_in(cfg, params, batch)
    period_blocks = _blocks_for_period(cfg)

    def period_fn(x, inp):
        p_period, c_period = inp
        new_c = {}
        for i, (sb, mb) in enumerate(period_blocks):
            x, nc = _decode_layer(cfg, sb, mb, p_period[f"slot{i}"], x,
                                  c_period[f"slot{i}"], pos)
            new_c[f"slot{i}"] = nc
        return x, new_c

    if cfg.unroll_periods:
        new_cs = []
        for i in range(cfg.n_periods):
            x, nc = period_fn(x, jax.tree.map(lambda t: t[i],
                                              (params["periods"],
                                               cache["periods"])))
            new_cs.append(nc)
        new_period_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cs)
    else:
        x, new_period_cache = jax.lax.scan(
            period_fn, x, (params["periods"], cache["periods"]))
    new_cache = {"periods": new_period_cache}
    if "tail" in cache:
        new_tail = []
        for i, (sb, mb) in enumerate(_blocks_for_tail(cfg)):
            x, nc = _decode_layer(cfg, sb, mb, params["tail"][i], x,
                                  cache["tail"][i], pos)
            new_tail.append(nc)
        new_cache["tail"] = new_tail
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return sh.constrain(logits, "logits"), new_cache
