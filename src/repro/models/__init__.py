"""Composable model zoo: dense/GQA/MoE transformers, RG-LRU hybrid, RWKV6."""

from .config import SHAPES, ModelConfig, ShapeConfig
from .transformer import (
    cache_specs,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_specs,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "cache_specs",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "param_specs",
]
