"""Error-feedback quantized gradient sync for the slow cross-pod hop.

Production posture (DESIGN.md §5): within a pod, gradients reduce over fast
ICI in bf16/f32; ACROSS pods (data-center interconnect, ~10× slower) they
sync as int8 with per-tensor scale and error feedback. For two pods the
quantized all-gather moves size/4 bytes vs 2×size/2 for a f32 all-reduce —
an 8× cross-pod byte reduction, with the quantization residual carried to
the next step (error feedback keeps SGD unbiased in the long run; Seide et
al. 2014, 1-bit SGD).

The quantizer is the NeurStore adaptive quantizer (paper Eq. 3) applied
in-graph: gradients are "deltas" with narrow ranges, the same observation
the paper exploits for storage.

Usable two ways:
* ``quantize_grad`` / ``dequantize_grad`` — jit-safe pair for custom
  schedules;
* ``cross_pod_sync`` — shard_map collective over the ``pod`` axis.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_grad(g, err, nbit: int = 8):
    """Error-feedback int quantization of one gradient tensor.

    Returns (codes int8, scale, new_err). dequant = codes * scale.
    Symmetric per-tensor scale (gradients are zero-centred deltas).
    """
    g32 = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(g32))
    levels = 2 ** (nbit - 1) - 1
    scale = jnp.maximum(amax / levels, 1e-20)
    codes = jnp.clip(jnp.round(g32 / scale), -levels, levels).astype(jnp.int8)
    deq = codes.astype(jnp.float32) * scale
    new_err = g32 - deq
    return codes, scale, new_err


def dequantize_grad(codes, scale):
    return codes.astype(jnp.float32) * scale


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def cross_pod_sync(grads, err_state, mesh, *, axis: str = "pod", nbit: int = 8):
    """Average gradients across the pod axis with int8 error feedback.

    grads/err leaves must be sharded identically on the non-pod axes;
    the pod axis itself carries replicated (per-pod-reduced) gradients.
    """
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def sync_leaf(g, err):
        codes, scale, new_err = quantize_grad(g, err, nbit)
        all_codes = jax.lax.all_gather(codes, axis)          # (P, ...) int8
        all_scales = jax.lax.all_gather(scale, axis)         # (P,)
        deq = all_codes.astype(jnp.float32) * all_scales.reshape(
            (-1,) + (1,) * codes.ndim)
        return jnp.mean(deq, axis=0).astype(g.dtype), new_err

    def synced(gs, errs):
        flat_g, treedef = jax.tree.flatten(gs)
        flat_e = treedef.flatten_up_to(errs)
        out = [sync_leaf(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    spec = P(axis)
    fn = jax.shard_map(
        synced, mesh=mesh,
        in_specs=(spec, spec), out_specs=(spec, spec))
    # Note: callers on the production mesh use per-leaf specs; this simple
    # wrapper covers the replicated-per-pod case used by the tests.
    return fn(grads, err_state)
