"""Logical-axis sharding context (GSPMD rules for the production mesh).

Model code annotates activations with *logical* names
(``constrain(x, "residual")``); the launcher activates a rule table mapping
logical names → ``PartitionSpec`` over the live mesh. Outside a mesh context
the calls are no-ops, so the same model code runs single-device smoke tests
and 512-chip dry-runs unchanged.

Rule tables encode the parallelism design of DESIGN.md §5:
DP over (pod, data); TP over model; SP (sequence sharding of the residual
stream) over model; EP (experts) over data; FSDP parameter sharding over
data for the large 2D+ weights.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules_single_pod(seq_shard: bool, serve: bool = False) -> dict:
    dp = ("data",)
    tp = "model"
    sp = tp if seq_shard else None
    # Decode: shard attention on d_head. Replicating heads made GSPMD
    # all-gather the full f32 wq/wk/wv per layer (256 MB/layer on deepseek
    # decode_32k — §Perf iteration 7); dh-sharding keeps q/k/v projections,
    # the cache update AND the cache reads fully local, at the cost of one
    # small (B,KV,G,S) score all-reduce per layer.
    decode = serve and not seq_shard
    hd = tp
    return {
        # Activations.
        "residual": P(dp, sp, None),          # (B, S, D) — SP between blocks
        "residual_gathered": P(dp, None, None),
        "heads": (P(dp, None, None, tp) if decode
                  else P(dp, None, hd, None)),  # (B, S, H, dh)
        "kv_heads": (P(dp, None, None, tp) if decode
                     else P(dp, None, hd, None)),
        "ffn": P(dp, None, tp),               # (B, S, F)
        "logits": P(dp, None, tp),            # (B, S, V)
        "tokens": P(dp, None),
        "embeds_in": P(dp, None, None),
        "rnn_state": P(dp, tp),               # (B, R)
        "rnn_act": P(dp, None, tp),           # (B, S, R)
        "rwkv_state": P(dp, tp, None, None),  # (B, H, dh, dh)
        "rwkv_act": P(dp, None, tp, None),    # (B, S, H, dh)
        # MoE.
        "expert_in": P(dp, None, None),       # (E, C, D) — EP over data
        "expert_h": P(dp, None, tp),          # (E, C, F)
        # Grouped dispatch (B, E, C, D/F): tokens are batch-sharded before/
        # after expert compute; hidden is E-sharded (EP) + F-sharded (TP) —
        # the boundary between the two is the token all-to-all.
        "moe_tokens": P(dp, None, None, None),
        "moe_hidden": P(None, "data", None, tp),
        # KV cache (decode), layout (B, KV, S, dh): batch over data; heads
        # over model when they divide the axis, else sequence over model
        # (adaptive — see cache_logical()).
        "cache_bh": (P(dp, None, None, tp) if decode
                     else P(dp, tp, None, None)),   # heads/dh sharded
        "cache_bs": (P(dp, None, None, tp) if decode
                     else P(dp, None, tp, None)),   # seq/dh sharded
        "cache_conv": P(dp, None, tp),        # (B, w-1, R)
        "cache_shift": P(dp, None),           # (B, D)
        # Parameters.
        "p_embed": P(tp, "data"),             # (V, D) vocab over model
        "p_attn_qkv": (P(None, None, tp) if decode
                       else P("data", tp, None)),   # decode: dh-sharded
        "p_attn_o": (P(None, tp, None) if decode
                     else P(tp, None, "data")),
        "p_ffn_in": P("data", tp),            # (D, F)
        "p_ffn_out": P(tp, "data"),           # (F, D)
        "p_router": P("data", None),          # (D, E)
        "p_expert_in": P(dp, None, tp),       # (E, D, F) — EP + TP
        "p_expert_out": P(dp, tp, None),      # (E, F, D)
        "p_rnn_in": P("data", tp),            # (D, R)
        "p_rnn_sq": P("data", tp),            # (R, R)
        "p_rnn_vec": P(tp,),                  # (R,)
        "p_conv": P(None, tp),                # (4, R)
        "p_vec": P(None,),                    # (D,) norms
        "p_head": P("data", tp),              # (D, V)
        "p_rwkv_lora_a": P("data", None),
        "p_rwkv_lora_b": P(None, tp),
        "p_rwkv_u": P(tp, None),              # (H, dh)
        "scalar": P(),
    }


def _rules_dp(n_axes: int = 2) -> dict:
    """Pure-DP + ZeRO-3 profile (hillclimb, EXPERIMENTS.md §Perf): batch
    over the *flattened* mesh, parameters fully sharded over the flat mesh
    on their largest dim and re-gathered per layer. No per-layer activation
    collectives at all — the right profile for ≤10B dense models where TP
    traffic dwarfs compute. Select with use_mesh(profile="dp")."""
    flat = ("data", "model") if n_axes == 2 else ("pod", "data", "model")
    dp = flat
    return {
        "residual": P(dp, None, None),
        "residual_gathered": P(dp, None, None),
        "heads": P(dp, None, None, None),
        "kv_heads": P(dp, None, None, None),
        "ffn": P(dp, None, None),
        "logits": P(dp, None, None),
        "tokens": P(dp, None),
        "embeds_in": P(dp, None, None),
        "rnn_state": P(dp, None),
        "rnn_act": P(dp, None, None),
        "rwkv_state": P(dp, None, None, None),
        "rwkv_act": P(dp, None, None, None),
        "expert_in": P(None, None, None),
        "expert_h": P(None, None, None),
        "moe_tokens": P(dp, None, None, None),
        "moe_hidden": P(None, dp, None, None),
        "cache_bh": P(dp, None, None, None),
        "cache_bs": P(dp, None, None, None),
        "cache_conv": P(dp, None, None),
        "cache_shift": P(dp, None),
        # ZeRO-3: every big param sharded over the flat mesh, dim 0.
        "p_embed": P(dp, None),
        "p_attn_qkv": P(dp, None, None),
        "p_attn_o": P(None, None, dp),
        "p_ffn_in": P(dp, None),
        "p_ffn_out": P(None, dp),
        "p_router": P(dp, None),
        "p_expert_in": P(None, dp, None),
        "p_expert_out": P(None, None, dp),
        "p_rnn_in": P(dp, None),
        "p_rnn_sq": P(dp, None),
        "p_rnn_vec": P(dp,),
        "p_conv": P(None, dp),
        "p_vec": P(None,),
        "p_head": P(dp, None),
        "p_rwkv_lora_a": P(dp, None),
        "p_rwkv_lora_b": P(None, dp),
        "p_rwkv_u": P(dp, None),
        "scalar": P(),
    }


def _serving_params(rules: dict) -> dict:
    """Serving profile: no optimizer state → dense params fit replicated
    over 'data' (TP-only). No per-step FSDP all-gathers. Expert weights
    (EP over data) stay sharded — tokens travel, not weights."""
    out = {}
    for k, spec in rules.items():
        if k.startswith("p_") and "expert" not in k:
            out[k] = P(*[None if a == "data" else a for a in tuple(spec)])
        else:
            out[k] = spec
    return out


def _rules_multi_pod(seq_shard: bool, serve: bool = False) -> dict:
    """Pod axis joins data-parallelism: DP over ('pod','data')."""
    rules = _rules_single_pod(seq_shard, serve)
    out = {}
    for k, spec in rules.items():
        parts = list(spec)
        new = []
        for axis in parts:
            if axis == ("data",):
                new.append(("pod", "data"))
            elif axis == "data":
                # parameter FSDP axis: shard over data only (pods replicate
                # params — they all-gather over ICI within pod; gradient
                # all-reduce crosses pods once per step).
                new.append("data")
            else:
                new.append(axis)
        out[k] = P(*new)
    return out


class ShardingCtx:
    def __init__(self, mesh, rules: dict, serve: bool = False):
        self.mesh = mesh
        self.rules = rules
        self.serve = serve

    def spec(self, name: str) -> P:
        return self.rules[name]

    def constrain(self, x, name: str):
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, self.rules[name])
        )


def current() -> ShardingCtx | None:
    return getattr(_state, "ctx", None)


def cache_logical(kv_heads: int) -> str:
    """Adaptive KV-cache sharding: heads over 'model' when they divide the
    axis (deepseek kv=8 on model=8|4|2...), else sequence over 'model'
    (glm4 kv=2, recurrentgemma kv=1 on model=16)."""
    ctx = current()
    if ctx is None:
        return "cache_bh"
    model_size = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get("model", 1)
    return "cache_bh" if kv_heads % model_size == 0 else "cache_bs"


def constrain(x, name: str):
    """Annotate activation x with logical sharding ``name`` (no-op w/o ctx)."""
    ctx = current()
    if ctx is None:
        return x
    return ctx.constrain(x, name)


def spec(name: str) -> P:
    ctx = current()
    if ctx is None:
        return P()
    return ctx.spec(name)


@contextlib.contextmanager
def use_mesh(mesh, multi_pod: bool = False, seq_shard: bool = True,
             serve: bool = False, profile: str = "tp"):
    if profile == "dp":
        rules = _rules_dp(n_axes=3 if multi_pod else 2)
    else:
        rules = (_rules_multi_pod(seq_shard, serve) if multi_pod
                 else _rules_single_pod(seq_shard, serve))
        if serve:
            rules = _serving_params(rules)
    ctx = ShardingCtx(mesh, rules)
    prev = getattr(_state, "ctx", None)
    _state.ctx = ctx
    try:
        with mesh:
            yield ctx
    finally:
        _state.ctx = prev
