"""Distributed runtime: sharding rules, collectives, gradient compression."""
