"""Deterministic synthetic data pipeline (shard-aware, restart-safe).

Generates next-token-predictable sequences from a ground-truth bigram chain
so training loss measurably decreases — the e2e driver trains on this. The
pipeline is indexed by (step, shard): any host can regenerate any batch,
which is what makes checkpoint-restart and elastic rescale trivially
deterministic (DESIGN.md §5).
"""

from __future__ import annotations

import numpy as np


class SyntheticLM:
    """Bigram-chain language data: token t+1 = perm[token t] with noise."""

    def __init__(self, vocab_size: int, seed: int = 0, noise: float = 0.1):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(vocab_size)
        self.noise = noise
        self.seed = seed

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, n_shards: int = 1):
        """Deterministic batch for (step, shard). Returns tokens + labels."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        b = batch_size // n_shards
        toks = np.empty((b, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        for t in range(seq_len):
            nxt = self.perm[toks[:, t]]
            flip = rng.random(b) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab, b), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batches(dataset: SyntheticLM, start_step: int, n_steps: int,
                 batch_size: int, seq_len: int):
    for step in range(start_step, start_step + n_steps):
        yield step, dataset.batch(step, batch_size, seq_len)
