"""AdamW in pure JAX with f32 moments (ZeRO-sharded via param shardings).

Moments inherit each parameter's PartitionSpec, so under the FSDP rules the
optimizer state is fully sharded (ZeRO-1/2 equivalent) with zero extra code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, *, lr=1e-4, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mh = m / bc1
        # max(·,0): v restored from a lossy (±2^-24) checkpoint can dip
        # infinitesimally negative — sqrt would NaN the whole run.
        vh = jnp.maximum(v / bc2, 0.0)
        step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
