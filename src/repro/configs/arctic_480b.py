"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]. The dense residual runs in
parallel with the routed experts (mix="moe_dense").
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    mix=("moe_dense",),
    n_experts=128,
    top_k=2,
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    mix=("moe_dense",),
    n_experts=8,
    top_k=2,
    capacity_factor=8.0,  # no token drops in smoke tests
    param_dtype="float32",
    compute_dtype="float32",
    attn_chunk=32,
)
