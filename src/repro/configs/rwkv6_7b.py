"""rwkv6-7b [ssm] — Finch, data-dependent decay; attention-free.

32L d_model=4096 d_ff=14336 vocab=65536 [arXiv:2404.05892; hf].
64 heads × head_dim 64. Sub-quadratic → serves long_500k with O(1) state.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab_size=65_536,
    period=("rwkv6",),
    mix=("rwkv_cm",),
    rwkv_head_dim=64,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    period=("rwkv6",),
    mix=("rwkv_cm",),
    rwkv_head_dim=16,
    subquadratic=True,
    param_dtype="float32",
    compute_dtype="float32",
    attn_chunk=32,
)
