"""Assigned-architecture registry: --arch <id> → (CONFIG, SMOKE)."""

import importlib

ARCHS = [
    "recurrentgemma-9b",
    "deepseek-67b",
    "internlm2-1.8b",
    "glm4-9b",
    "qwen3-8b",
    "granite-moe-3b-a800m",
    "arctic-480b",
    "rwkv6-7b",
    "hubert-xlarge",
    "llava-next-34b",
]


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str, smoke: bool = False):
    m = _module(arch_id)
    return m.SMOKE if smoke else m.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)
