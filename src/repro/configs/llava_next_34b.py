"""llava-next-34b [vlm] — decoder backbone; anyres vision tiling is a STUB.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. input_specs() provides
precomputed patch+text embeddings for train/prefill; decode feeds text
tokens through the embedding table.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    frontend="embeddings",
)

SMOKE = ModelConfig(
    name="llava-next-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    frontend="embeddings",
    param_dtype="float32",
    compute_dtype="float32",
    attn_chunk=32,
)
