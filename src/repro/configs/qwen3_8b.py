"""qwen3-8b [dense] — qk_norm, GQA.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
[hf:Qwen/Qwen3-8B; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab_size=151_936,
    qk_norm=True,
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
    param_dtype="float32",
    compute_dtype="float32",
    attn_chunk=32,
)
