"""hubert-xlarge [audio] — encoder-only, bidirectional MHA, GELU MLP.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 [arXiv:2106.07447;
unverified]. The conv waveform frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, D). No decode step (encoder-only) →
decode_32k and long_500k cells are skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    mix=("gelu",),
    causal=False,
    has_decode=False,
    frontend="embeddings",
)

SMOKE = ModelConfig(
    name="hubert-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    mix=("gelu",),
    causal=False,
    has_decode=False,
    frontend="embeddings",
    param_dtype="float32",
    compute_dtype="float32",
    attn_chunk=32,
)
