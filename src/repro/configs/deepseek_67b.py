"""deepseek-67b [dense] — llama-arch GQA decoder.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400
[arXiv:2401.02954; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102_400,
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    param_dtype="float32",
    compute_dtype="float32",
    attn_chunk=32,
)
