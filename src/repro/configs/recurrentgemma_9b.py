"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000 window=2048
[arXiv:2402.19427; unverified]. 38 = 12×(rglru, rglru, local_attn) + 2 tail
rglru layers. Sub-quadratic → serves long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    period=("rglru", "rglru", "local_attn"),
    mix=("swiglu", "swiglu", "swiglu"),
    tail=("rglru", "rglru"),
    tail_mix=("swiglu", "swiglu"),
    window=2048,
    d_rnn=4096,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    period=("rglru", "rglru", "local_attn"),
    mix=("swiglu", "swiglu", "swiglu"),
    tail=("rglru", "rglru"),
    tail_mix=("swiglu", "swiglu"),
    window=16,
    d_rnn=64,
    subquadratic=True,
    param_dtype="float32",
    compute_dtype="float32",
    attn_chunk=32,
)
