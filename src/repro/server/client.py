"""``StoreClient`` — the typed network client (stdlib ``http.client``).

Mirrors the embedded :class:`repro.store.NeurStore` facade method for
method and speaks the same dataclasses (:class:`SaveRequest` in,
:class:`SaveReport`/:class:`LoadHandle`/:class:`StoreStats` out), so
swapping embedded ↔ served access is a one-line change at the call
site. Uploads stream chunked record-by-record (the client never builds
one model-sized buffer either); downloads default to eager
materialization so the keep-alive connection is immediately reusable —
pass ``stream=True`` for a bounded-memory lazy handle that owns the
connection until closed.

Error contract: a non-2xx response body is ``{"error": {"code",
"message"}}``; the client re-raises the **same typed exception** the
embedded API would (``KeyError``, ``CorruptPageError``,
``QuotaExceededError``, ``AdmissionRejectedError``, ...) via
:func:`repro.store.errors.raise_for_code`.

Connections are per-thread (thread-local keep-alive), so one client
instance is safe to share across reader threads. A request that hits a
dead keep-alive socket (server restarted, idle timeout) reconnects and
retries once before surfacing the failure.
"""

from __future__ import annotations

import http.client
import json
import socket
import threading
from urllib.parse import quote

from ..obs.trace import trace
from ..store.api import LoadHandle, SaveReport, SaveRequest, StoreStats
from ..store.errors import RemoteStoreError, raise_for_code
from . import wire

__all__ = ["StoreClient"]

_RETRYABLE = (
    http.client.RemoteDisconnected,
    http.client.CannotSendRequest,
    BrokenPipeError,
    ConnectionResetError,
    ConnectionAbortedError,
)


class _BufferedResponse:
    """A fully-read response detached from its (now closed) connection."""

    def __init__(self, status: int, data: bytes):
        self.status = status
        self._data = data

    def read(self, n: int = -1) -> bytes:
        out = self._data if n is None or n < 0 else self._data[:n]
        self._data = b"" if n is None or n < 0 else self._data[len(out):]
        return out


class StoreClient:
    """Typed client for one tenant namespace on one model-store server."""

    def __init__(self, host: str, port: int, tenant: str = "default",
                 timeout: float = 60.0):
        self.host = host
        self.port = port
        self.tenant = tenant
        self.timeout = timeout
        self._local = threading.local()

    # --------------------------------------------------------- connections
    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
            conn.connect()
            # Chunked uploads are many small sends; Nagle + delayed ACK
            # would add ~40ms per request on loopback.
            conn.sock.setsockopt(
                socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._local.conn = conn
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
            self._local.conn = None

    def close(self) -> None:
        """Close this thread's keep-alive connection (others unaffected)."""
        self._drop_conn()

    def __enter__(self) -> "StoreClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ plumbing
    def _request(self, method: str, path: str, body=None,
                 chunked: bool = False):
        """One request with a single reconnect-and-retry on a dead socket.

        ``body`` may be a callable returning a fresh bytes-iterator so a
        chunked upload can be replayed on retry (a plain generator would
        be half-exhausted after the first attempt).

        Every request carries a W3C ``traceparent`` header, so the
        server's ``http.request`` span (and the engine spans under it)
        joins this client's trace — nested under the caller's span when
        one is active on this thread, a fresh trace otherwise.
        """
        with trace("client.request", method=method, path=path) as span:
            headers = {"traceparent": span.traceparent()}
            for attempt in (0, 1):
                conn = self._conn()
                try:
                    payload = body() if callable(body) else body
                    try:
                        if chunked:
                            headers["Transfer-Encoding"] = "chunked"
                            conn.request(method, path, body=payload,
                                         headers=headers,
                                         encode_chunked=True)
                        else:
                            conn.request(method, path, body=payload,
                                         headers=headers)
                    except (BrokenPipeError, ConnectionResetError):
                        # The server can reject an upload EARLY (e.g. 429
                        # backpressure) and stop reading mid-body; the
                        # error response is already waiting on the socket
                        # — read it instead of surfacing the pipe failure.
                        early = self._read_early_response(conn)
                        if early is not None:
                            return early
                        raise
                    return conn.getresponse()
                except _RETRYABLE:
                    self._drop_conn()
                    if attempt:
                        raise
                except OSError:
                    self._drop_conn()
                    raise
        raise AssertionError("unreachable")

    def _read_early_response(self, conn):
        """Salvage a response the server sent before the upload finished.

        The connection is misaligned afterwards (part of our body is
        unconsumed), so the response is buffered fully and the socket
        dropped before returning.
        """
        try:
            resp = conn.getresponse()
            buffered = _BufferedResponse(resp.status, resp.read())
        except Exception:  # noqa: BLE001 — no response to salvage
            return None
        finally:
            self._drop_conn()
        return buffered

    def _json(self, method: str, path: str, body=None, chunked=False) -> dict:
        resp = self._request(method, path, body=body, chunked=chunked)
        data = resp.read()  # fully drain → connection stays reusable
        if resp.status >= 400:
            self._raise_error(resp.status, data)
        try:
            return json.loads(data.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as exc:
            raise RemoteStoreError(
                f"malformed response body from server: {exc}") from exc

    def _raise_error(self, status: int, data: bytes) -> None:
        try:
            err = json.loads(data.decode("utf-8"))["error"]
            code, message = err["code"], err.get("message", "")
        except (UnicodeDecodeError, json.JSONDecodeError, KeyError,
                TypeError):
            raise RemoteStoreError(
                f"HTTP {status}: {data[:200]!r}") from None
        raise_for_code(code, message)

    def _model_path(self, name: str, suffix: str = "") -> str:
        return (f"/v1/tenants/{quote(self.tenant, safe='')}"
                f"/models/{quote(name, safe='/')}"  # names may contain '/'
                f"{suffix}")

    # --------------------------------------------------------------- writes
    def save(self, request: SaveRequest) -> SaveReport:
        """Stream one model up and commit it (server-side Algorithm 1)."""
        return self._save(request, method="POST")

    def replace(self, request: SaveRequest) -> SaveReport:
        """Atomic replace: new version in, old version dropped, one txn."""
        return self._save(request, method="PUT")

    def _save(self, request: SaveRequest, method: str) -> SaveReport:
        def body():
            return wire.encode_model_stream(
                request.wire_header(), iter(request.tensors.items()))

        out = self._json(method, self._model_path(request.name),
                         body=body, chunked=True)
        return SaveReport.from_dict(out)

    def delete(self, name: str) -> None:
        self._json("DELETE", self._model_path(name))

    def vacuum(self, min_dead_fraction: float = 0.0) -> dict:
        return self._json("POST", "/v1/admin/vacuum",
                          body=json.dumps(
                              {"min_dead_fraction": min_dead_fraction}
                          ).encode("utf-8"))

    # ---------------------------------------------------------------- reads
    def load(self, name: str, bits: int | None = None,
             stream: bool = False) -> LoadHandle:
        """Download a model as a :class:`LoadHandle`.

        Default is **eager**: the stream is fully decoded into the
        handle's cache before returning, so the trailer (completeness
        proof) is verified here and the connection is free for the next
        request. ``stream=True`` returns a lazy one-shot handle — bounded
        memory, but it owns this thread's connection until consumed or
        closed.
        """
        suffix = f"?bits={int(bits)}" if bits is not None else ""
        resp = self._request("GET", self._model_path(name, suffix))
        if resp.status >= 400:
            self._raise_error(resp.status, resp.read())
        header, records = wire.decode_model_stream(resp)

        def _close():
            # Abandon the response mid-stream: kill the socket rather
            # than read an unbounded remainder.
            resp.close()
            self._drop_conn()

        handle = LoadHandle.from_stream(header, records, close=_close)
        if not stream:
            try:
                handle.materialize()  # validates trailer + per-tensor CRCs
            except BaseException:
                _close()
                raise
            resp.read()  # response exhausted → keep-alive stays valid
            handle._close = None
        return handle

    def model_info(self, name: str) -> dict:
        return self._json("GET", self._model_path(name, "?info=1"))

    def models(self) -> list[str]:
        path = f"/v1/tenants/{quote(self.tenant, safe='')}/models"
        return list(self._json("GET", path)["models"])

    def quota(self) -> dict:
        path = f"/v1/tenants/{quote(self.tenant, safe='')}/quota"
        return self._json("GET", path)

    def stats(self) -> StoreStats:
        return StoreStats.from_dict(self._json("GET", "/v1/stats"))

    def accounting(self) -> dict:
        """Store-wide space accounting report (``GET /v1/accounting``).

        Same shape as the embedded ``NeurStore.accounting()``:
        ``{"store", "per_model", "per_dim", "per_tenant"}`` — see
        ``docs/observability.md`` for field semantics.
        """
        return self._json("GET", "/v1/accounting")

    def explain(self, name: str) -> dict:
        """Persisted save EXPLAIN + space attribution for one model."""
        return self._json("GET", self._model_path(name, "/explain"))

    def healthz(self) -> bool:
        return bool(self._json("GET", "/v1/healthz").get("ok"))
