"""Per-tenant namespaces and byte quotas (enforced at save-commit time).

Tenancy is a *naming* convention the server owns: a model saved by
tenant ``t`` under name ``n`` lives in the engine catalog as ``t/n``.
Tenant ids are validated (``[A-Za-z0-9_-]+``, no ``/``) so namespaces
cannot collide or escape; model names may themselves contain ``/``.

Quotas bound the **on-disk page bytes** a tenant's committed models
occupy — post-dedup, post-quantization — so a tenant whose fine-tunes
dedup well against existing bases is charged only for its delta pages
(shared base vertices in the HNSW index are charged to nobody, matching
the engine's own storage accounting).

Enforcement happens inside the engine's save transaction via
``StorageEngine.commit_gate``: the gate runs under the engine lock
immediately before the journal intent, sees the exact encoded page
bytes about to commit (plus the bytes of any page the save replaces),
and raises :class:`~repro.store.errors.QuotaExceededError` to abort the
save before any durable side effect. A racing pair of saves for the
same tenant cannot both slip under the limit — the gate and the commit
are one critical section.
"""

from __future__ import annotations

import re
import threading

from ..store.errors import QuotaExceededError

__all__ = ["QuotaManager", "split_tenant", "tenant_model_name",
           "validate_tenant"]

_TENANT_RE = re.compile(r"^[A-Za-z0-9_-]{1,64}$")


def validate_tenant(tenant: str) -> str:
    """Return ``tenant`` or raise ``ValueError`` (``invalid_request``)."""
    if not _TENANT_RE.match(tenant):
        raise ValueError(f"invalid tenant id {tenant!r} "
                         "(allowed: [A-Za-z0-9_-], max 64 chars)")
    return tenant


def tenant_model_name(tenant: str, name: str) -> str:
    """The engine-catalog name for ``name`` in ``tenant``'s namespace."""
    validate_tenant(tenant)
    if not name:
        raise ValueError("empty model name")
    return f"{tenant}/{name}"


def split_tenant(full_name: str) -> tuple[str, str] | None:
    """Inverse of :func:`tenant_model_name`; None for non-namespaced rows."""
    tenant, sep, name = full_name.partition("/")
    if not sep or not _TENANT_RE.match(tenant):
        return None
    return tenant, name


class QuotaManager:
    """Byte quotas per tenant namespace.

    ``default_limit`` applies to tenants without an explicit entry;
    ``None`` means unlimited. Usage is derived from the engine catalog
    (sum of committed page sizes per namespace), so it needs no separate
    persistence and survives restarts, vacuums (which shrink pages) and
    out-of-band deletes for free.
    """

    def __init__(self, default_limit: int | None = None,
                 limits: dict[str, int] | None = None):
        self.default_limit = default_limit
        self.limits = dict(limits or {})
        self._lock = threading.Lock()

    def limit(self, tenant: str) -> int | None:
        with self._lock:
            return self.limits.get(tenant, self.default_limit)

    def set_limit(self, tenant: str, limit: int | None) -> None:
        with self._lock:
            if limit is None:
                self.limits.pop(tenant, None)
            else:
                self.limits[tenant] = int(limit)

    def usage(self, engine, tenant: str) -> int:
        """Committed on-disk page bytes in ``tenant``'s namespace."""
        prefix = f"{tenant}/"
        total = 0
        for name in engine.list_models():
            if name.startswith(prefix):
                total += engine._page_size(engine.model_info(name))
        return total

    def report(self, engine, tenant: str) -> dict:
        limit = self.limit(tenant)
        used = self.usage(engine, tenant)
        return {
            "tenant": tenant,
            "limit_bytes": limit,
            "used_bytes": used,
            "remaining_bytes": None if limit is None else max(0, limit - used),
        }

    def gate(self, engine):
        """Build the ``StorageEngine.commit_gate`` callable.

        The engine calls it under its lock with one entry per model in
        the committing transaction: ``{"name", "page_bytes",
        "old_page_bytes"}``. Charges are grouped per tenant so a batch
        save is admitted or rejected atomically, matching the engine's
        all-or-nothing batch commit.
        """

        def check(entries: list[dict]) -> None:
            deltas: dict[str, int] = {}
            for e in entries:
                split = split_tenant(str(e["name"]))
                if split is None:
                    continue  # non-namespaced (embedded) saves are ungated
                tenant = split[0]
                deltas[tenant] = (
                    deltas.get(tenant, 0)
                    + int(e["page_bytes"]) - int(e["old_page_bytes"])
                )
            for tenant, delta in deltas.items():
                limit = self.limit(tenant)
                if limit is None:
                    continue
                used = self.usage(engine, tenant)
                if used + delta > limit:
                    raise QuotaExceededError(
                        f"tenant {tenant!r}: save would use "
                        f"{used + delta} bytes of a {limit}-byte quota "
                        f"({used} already committed)")

        return check
