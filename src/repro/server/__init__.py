"""Networked front door for the model store.

``ModelStoreServer`` serves one :class:`~repro.core.engine.StorageEngine`
over HTTP (stdlib ``ThreadingHTTPServer`` — no framework dependency);
``StoreClient`` is the matching typed client. Both speak the shared
dataclasses from :mod:`repro.store.api` and the error-code registry from
:mod:`repro.store.errors`, so embedded and served access are the same
API with a socket in between. See ``docs/serving.md``.

Run a server from the command line::

    python -m repro.server --store /path/to/store --port 8750
"""

from .admission import AdmissionPolicy
from .app import ModelStoreServer
from .client import StoreClient
from .quota import QuotaManager, split_tenant, tenant_model_name
from .wire import STREAM_VERSION, WireError

__all__ = [
    "AdmissionPolicy",
    "ModelStoreServer",
    "QuotaManager",
    "STREAM_VERSION",
    "StoreClient",
    "WireError",
    "split_tenant",
    "tenant_model_name",
]
