"""Write admission / backpressure policy fed by ``StorageEngine.stats()``.

Reads are never gated: the snapshot read path is lock-free after capture
and pins its own resources, so a read costs the writers nothing. Writes
are the amplifying operations — each save commits a catalog snapshot
(bumping the epoch every live reader's lag is measured against) and
pushes bytes through the index cache and buffer pool — so writes are
what admission sheds when the store is under pressure.

The policy consumes **only the documented stats fields** (the
:class:`repro.store.api.StoreStats` projection of
``StorageEngine.stats()`` — stats-as-API, ``docs/serving.md``):

* ``pool_utilization`` — ``pool_resident_bytes / pool_budget_bytes``.
  Above the watermark, new page writes would start evicting frames that
  live readers are actively sharing; shedding writes lets the
  maintenance daemon's trims catch up.
* ``epoch_lag`` — ``epoch - oldest_epoch`` over live snapshots. Every
  write commit widens the gap between the catalog head and the oldest
  pinned snapshot; unbounded lag means unbounded retained page versions
  (copy-on-write vacuum keeps every pinned generation alive). Shedding
  writes bounds version retention while long reads drain.

Rejection raises :class:`~repro.store.errors.AdmissionRejectedError`,
which the server surfaces as HTTP 429 + ``{"code": "backpressure"}``
with a ``Retry-After`` hint — the request is safe to retry verbatim.
"""

from __future__ import annotations

import dataclasses

from ..obs.metrics import default_registry
from ..store.api import StoreStats
from ..store.errors import AdmissionRejectedError

__all__ = ["AdmissionPolicy"]

_M_REJECTED = default_registry().counter(
    "neurstore_server_admission_rejects_total",
    "Writes shed by the admission policy, by trigger.",
    ("reason",),
)


@dataclasses.dataclass
class AdmissionPolicy:
    """Threshold policy over the documented stats fields.

    ``max_pool_utilization`` — reject writes while the buffer pool holds
    more than this fraction of its byte budget (> 1.0 disables; pinned
    frames can push utilization past 1.0).
    ``max_epoch_lag`` — reject writes while the oldest live snapshot is
    more than this many commits behind the catalog head (negative
    disables).
    ``retry_after_s`` — the backoff hint returned with a rejection.
    """

    max_pool_utilization: float = 0.95
    max_epoch_lag: int = 256
    retry_after_s: float = 0.05

    # Telemetry (exposed via /v1/stats so load tests can see shed counts).
    rejected: int = 0

    def check_write(self, stats: StoreStats) -> None:
        """Raise :class:`AdmissionRejectedError` if a write must shed."""
        util = stats.pool_utilization
        if 0 <= self.max_pool_utilization < util:
            self.rejected += 1
            _M_REJECTED.labels("pool_utilization").inc()
            raise AdmissionRejectedError(
                f"buffer pool at {util:.0%} of budget "
                f"(> {self.max_pool_utilization:.0%}); retry after "
                f"{self.retry_after_s}s")
        lag = stats.epoch_lag
        if 0 <= self.max_epoch_lag < lag:
            self.rejected += 1
            _M_REJECTED.labels("epoch_lag").inc()
            raise AdmissionRejectedError(
                f"oldest live snapshot is {lag} commits behind "
                f"(> {self.max_epoch_lag}); retry after "
                f"{self.retry_after_s}s")

    def stats(self) -> dict:
        return {
            "max_pool_utilization": self.max_pool_utilization,
            "max_epoch_lag": self.max_epoch_lag,
            "rejected": self.rejected,
        }
