"""``python -m repro.server`` — stand up a model-store server.

Example::

    python -m repro.server --store /tmp/store --port 8750 \
        --quota-default $((1 << 30)) --max-epoch-lag 512
"""

from __future__ import annotations

import argparse

from ..core.engine import DEFAULT_TAU, DEFAULT_TOLERANCE, StorageEngine
from .admission import AdmissionPolicy
from .app import ModelStoreServer
from .quota import QuotaManager


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.server",
        description="Serve a NeurStore model store over HTTP.")
    ap.add_argument("--store", required=True, help="store directory path")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8750)
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="store-default quantization error bound p")
    ap.add_argument("--tau", type=float, default=DEFAULT_TAU,
                    help="store-default delta-range similarity threshold")
    ap.add_argument("--pool-bytes", type=int, default=1 << 30,
                    help="buffer pool byte budget")
    ap.add_argument("--quota-default", type=int, default=None,
                    help="default per-tenant byte quota (unset = unlimited)")
    ap.add_argument("--max-pool-utilization", type=float, default=0.95)
    ap.add_argument("--max-epoch-lag", type=int, default=256)
    ap.add_argument("--no-maintenance", action="store_true",
                    help="disable the background maintenance daemon")
    args = ap.parse_args(argv)

    engine = StorageEngine(
        args.store,
        tolerance=args.tolerance,
        tau=args.tau,
        pool_bytes=args.pool_bytes,
        auto_maintenance=not args.no_maintenance,
    )
    server = ModelStoreServer(
        engine,
        host=args.host,
        port=args.port,
        quotas=QuotaManager(default_limit=args.quota_default),
        admission=AdmissionPolicy(
            max_pool_utilization=args.max_pool_utilization,
            max_epoch_lag=args.max_epoch_lag,
        ),
    )
    print(f"serving {args.store} on http://{server.host}:{server.port}")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
        engine.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
