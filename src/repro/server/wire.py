"""Streaming wire format for model upload/download (docs/serving.md).

A model crosses the wire as a flat sequence of length-prefixed frames —
never as one buffer — so both sides keep memory bounded by the largest
single tensor regardless of model size:

=======  ====================================================~==========
frame    content
=======  ==============================================================
0        JSON header: ``{"stream_version", "name", "architecture",
         ...}`` (upload adds ``tolerance``/``tau``, download ``bits``)
2k+1     JSON tensor meta: ``{"tensor", "shape", "dtype", "crc"}``
2k+2     raw C-order tensor bytes (CRC32-checked against the meta)
last     JSON trailer: ``{"eof": true, "n_tensors": N}``
=======  ==============================================================

Each frame is ``<u64 little-endian length><payload>``. The trailer is
load-bearing: a stream that ends without it (server died mid-stream, a
proxy truncated the body) raises :class:`WireError` instead of silently
yielding a partial model. Per-tensor CRCs extend the storage layer's
end-to-end checksum chain across the network hop.

The encoder accepts any ``(name, ndarray)`` iterable, so the server
streams straight off :meth:`LoadedModel.iter_tensors` (one record
resident at a time) and the client streams straight out of a
``SaveRequest``'s tensor mapping.
"""

from __future__ import annotations

import json
import struct
from collections.abc import Iterable, Iterator

import numpy as np

from ..core.integrity import crc32

__all__ = [
    "WireError",
    "STREAM_VERSION",
    "encode_model_stream",
    "decode_model_stream",
    "read_frame",
    "write_frame",
]

STREAM_VERSION = 1
_LEN = struct.Struct("<Q")
# One frame never exceeds this (guards a corrupted/hostile length prefix
# from driving a giant allocation). Tensors larger than 1 GiB per record
# do not exist in this store's page format either.
MAX_FRAME_BYTES = 1 << 30


class WireError(ValueError):
    """The byte stream violates the framing contract (truncation, bad
    CRC, missing trailer, oversized frame). Maps to ``invalid_request``
    on the server and is raised as-is by the client."""


def _read_exact(r, n: int) -> bytes:
    """Read exactly ``n`` bytes from a ``.read(k)`` object or fail typed."""
    chunks = []
    remaining = n
    while remaining > 0:
        chunk = r.read(remaining)
        if not chunk:
            raise WireError(
                f"stream truncated: expected {n} more frame bytes, got "
                f"{n - remaining}")
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


def read_frame(r) -> bytes:
    """Read one length-prefixed frame from a ``.read(n)`` source."""
    (length,) = _LEN.unpack(_read_exact(r, _LEN.size))
    if length > MAX_FRAME_BYTES:
        raise WireError(f"frame length {length} exceeds {MAX_FRAME_BYTES}")
    return _read_exact(r, length)


def write_frame(w, payload: bytes) -> None:
    """Write one frame via a ``write(bytes)`` callable-style object."""
    w.write(_LEN.pack(len(payload)))
    w.write(payload)


def _frame(payload: bytes) -> bytes:
    return _LEN.pack(len(payload)) + payload


def _parse_json(buf: bytes, what: str) -> dict:
    try:
        obj = json.loads(buf.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise WireError(f"bad {what} frame: {exc}") from exc
    if not isinstance(obj, dict):
        raise WireError(f"bad {what} frame: not an object")
    return obj


def encode_model_stream(
    header: dict, tensors: Iterable[tuple[str, np.ndarray]]
) -> Iterator[bytes]:
    """Yield the framed byte chunks of one model stream.

    Lazy: each tensor is framed as the iterable produces it, so a
    server streaming off :meth:`LoadedModel.iter_tensors` holds one
    reconstructed tensor at a time.
    """
    head = {"stream_version": STREAM_VERSION}
    head.update(header)
    yield _frame(json.dumps(head).encode("utf-8"))
    n = 0
    for name, arr in tensors:
        arr = np.ascontiguousarray(arr)
        data = arr.tobytes()
        meta = {
            "tensor": str(name),
            "shape": [int(s) for s in arr.shape],
            "dtype": arr.dtype.str,
            "crc": crc32(data),
        }
        yield _frame(json.dumps(meta).encode("utf-8"))
        yield _frame(data)
        n += 1
    yield _frame(json.dumps({"eof": True, "n_tensors": n}).encode("utf-8"))


def decode_model_stream(r) -> tuple[dict, Iterator[tuple[str, np.ndarray]]]:
    """Parse a model stream from a ``.read(n)`` source.

    Returns ``(header, generator)``; the generator yields
    ``(name, ndarray)`` record-by-record and validates the trailer, so
    exhausting it guarantees the stream arrived complete and intact.
    Arrays are zero-copy views over the received frame (read-only).
    """
    header = _parse_json(read_frame(r), "header")
    version = header.get("stream_version")
    if version != STREAM_VERSION:
        raise WireError(f"unsupported stream_version {version!r}")

    def records() -> Iterator[tuple[str, np.ndarray]]:
        count = 0
        while True:
            meta = _parse_json(read_frame(r), "tensor meta")
            if meta.get("eof"):
                expect = meta.get("n_tensors")
                if expect is not None and int(expect) != count:
                    raise WireError(
                        f"trailer claims {expect} tensors, stream carried "
                        f"{count}")
                return
            data = read_frame(r)
            if crc32(data) != meta.get("crc"):
                raise WireError(
                    f"tensor {meta.get('tensor')!r}: payload CRC mismatch "
                    "(bytes damaged in transit)")
            try:
                arr = np.frombuffer(data, dtype=np.dtype(meta["dtype"]))
                arr = arr.reshape([int(s) for s in meta["shape"]])
            except (KeyError, TypeError, ValueError) as exc:
                raise WireError(f"bad tensor meta: {exc}") from exc
            yield str(meta.get("tensor", "")), arr
            count += 1

    return header, records()
