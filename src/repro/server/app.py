"""The networked model-store front door — stdlib-only HTTP service.

``ModelStoreServer`` mounts one :class:`~repro.core.engine.StorageEngine`
behind a ``ThreadingHTTPServer``: every request handler thread is exactly
one of the N concurrent readers the snapshot read path was built for —
a ``GET`` pins an epoch-stamped snapshot and streams the model out
record-by-record without ever blocking writers; writes pass the
admission policy, then run the engine's ordinary journaled commit with
the tenant quota gate inside the transaction.

Routes (wire details in ``docs/serving.md``)::

    GET    /v1/healthz                              liveness
    GET    /v1/stats                                StoreStats (versioned)
    GET    /v1/accounting                           space accounting report
    POST   /v1/admin/vacuum                         {"min_dead_fraction"}
    GET    /v1/tenants/{t}/models                   list model names
    GET    /v1/tenants/{t}/models/{name}/explain    save EXPLAIN + space
    GET    /v1/tenants/{t}/quota                    quota usage report
    POST   /v1/tenants/{t}/models/{name}            save   (streamed body)
    PUT    /v1/tenants/{t}/models/{name}            replace (streamed body)
    GET    /v1/tenants/{t}/models/{name}[?bits=b]   download (streamed)
    GET    /v1/tenants/{t}/models/{name}?info=1     catalog entry JSON
    DELETE /v1/tenants/{t}/models/{name}            delete

Uploads stream record-by-record (chunked transfer encoding, one frame
per tensor — see ``repro.server.wire``), so a multi-GB model never
materializes server-side as a single buffer; downloads stream the same
format off :meth:`LoadedModel.iter_tensors`. Handlers speak only the
typed dataclasses from :mod:`repro.store.api` and map every failure
through the :mod:`repro.store.errors` registry — same codes, same
statuses, on every route.
"""

from __future__ import annotations

import json
import threading
import time
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, unquote, urlsplit

from ..core.engine import STATS_SCHEMA_VERSION
from ..obs.metrics import default_registry
from ..obs.trace import (
    get_slow_op_threshold,
    parse_traceparent,
    set_slow_op_threshold,
    trace,
)
from ..store.api import SaveRequest, StoreStats
from ..store.errors import error_payload
from . import wire
from .admission import AdmissionPolicy
from .quota import (
    QuotaManager,
    split_tenant,
    tenant_model_name,
    validate_tenant,
)

__all__ = ["ModelStoreServer"]

_WRITE_METHODS = frozenset({"POST", "PUT", "DELETE"})

# Process-wide server metrics (docs/observability.md). Route labels are
# fixed templates assigned at dispatch — never raw paths — so label
# cardinality is bounded by the route table.
_REG = default_registry()
_M_REQUESTS = _REG.counter(
    "neurstore_server_requests_total",
    "HTTP requests by route template, method and status class.",
    ("route", "method", "status"),
)
_M_REQ_SECONDS = _REG.histogram(
    "neurstore_server_request_seconds",
    "HTTP request wall time by route template.",
    ("route",),
)
_M_INFLIGHT = _REG.gauge(
    "neurstore_server_inflight_requests",
    "HTTP requests currently being handled.",
)
_M_RC_HITS = _REG.counter(
    "neurstore_server_response_cache_hits_total",
    "Response-cache hits (download served as one send).",
)
_M_RC_MISSES = _REG.counter(
    "neurstore_server_response_cache_misses_total",
    "Response-cache misses (download reconstructed from the store).",
)
_M_RC_ADMITTED = _REG.counter(
    "neurstore_server_response_cache_admissions_total",
    "Encoded downloads admitted to the response cache.",
)
_M_RC_BYPASSED = _REG.counter(
    "neurstore_server_response_cache_bypasses_total",
    "Encoded downloads refused admission (larger than max_entry_bytes).",
)
_M_RC_EVICTED = _REG.counter(
    "neurstore_server_response_cache_evictions_total",
    "Response-cache entries evicted by the byte budget.",
)


class _ResponseSent(Exception):
    """A failure occurred after response bytes hit the wire; the
    connection is already marked for close — no error body may follow."""


class _BoundedReader:
    """``.read(n)`` over a Content-Length-delimited request body."""

    def __init__(self, rfile, length: int):
        self._r = rfile
        self._left = length

    def read(self, n: int = -1) -> bytes:
        if self._left <= 0:
            return b""
        take = self._left if n is None or n < 0 else min(n, self._left)
        data = self._r.read(take)
        self._left -= len(data)
        return data


class _ChunkedReader:
    """``.read(n)`` decoding a chunked transfer-encoded request body.

    ``BaseHTTPRequestHandler`` does not decode chunked bodies; streamed
    uploads need it (the client cannot know Content-Length up front).
    """

    def __init__(self, rfile):
        self._r = rfile
        self._chunk_left = 0
        self._eof = False

    def _next_chunk(self) -> None:
        line = self._r.readline(1 << 16)
        if line in (b"\r\n", b"\n"):  # separator after previous chunk
            line = self._r.readline(1 << 16)
        try:
            self._chunk_left = int(line.split(b";", 1)[0].strip(), 16)
        except ValueError as exc:
            raise wire.WireError(f"bad chunk size line {line!r}") from exc
        if self._chunk_left == 0:
            # Consume the (possibly empty) trailer section up to CRLF.
            while True:
                trailer = self._r.readline(1 << 16)
                if trailer in (b"\r\n", b"\n", b""):
                    break
            self._eof = True

    def read(self, n: int = -1) -> bytes:
        out = []
        want = None if n is None or n < 0 else n
        while not self._eof and (want is None or want > 0):
            if self._chunk_left == 0:
                self._next_chunk()
                continue
            take = self._chunk_left if want is None else min(want, self._chunk_left)
            data = self._r.read(take)
            if not data:
                raise wire.WireError("chunked body truncated mid-chunk")
            self._chunk_left -= len(data)
            if want is not None:
                want -= len(data)
            out.append(data)
        return b"".join(out)


class _ResponseCache:
    """Byte-budgeted LRU of fully-encoded download streams.

    A committed model version is immutable, so its encoded wire stream
    (frames, CRCs and all) is deterministic given ``(model_id, bits)`` —
    ``model_id`` is allocated fresh by every save/replace, which makes
    writer churn invalidate hot entries by key drift, with no explicit
    invalidation hook. A hit turns a read into one socket send: no
    snapshot, no reconstruction, no re-CRC.
    """

    def __init__(self, budget_bytes: int, max_entry_bytes: int | None = None):
        self.budget = budget_bytes
        # Admission policy for very large models: an entry above this
        # threshold bypasses the cache instead of wiping it. Default:
        # a single entry may use at most half the budget, so at least
        # two hot models can stay resident. The bypass is *counted*
        # (admissions/bypasses/evictions below and in the registry), so
        # the policy is visible instead of silent.
        if max_entry_bytes is None:
            max_entry_bytes = budget_bytes // 2
        self.max_entry_bytes = int(max_entry_bytes)
        self._entries: "OrderedDict[tuple, bytes]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.admissions = 0
        self.bypasses = 0
        self.evictions = 0

    def get(self, key: tuple) -> bytes | None:
        with self._lock:
            blob = self._entries.get(key)
            if blob is None:
                self.misses += 1
                _M_RC_MISSES.inc()
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            _M_RC_HITS.inc()
            return blob

    def put(self, key: tuple, blob: bytes) -> None:
        if len(blob) > self.max_entry_bytes:
            with self._lock:
                self.bypasses += 1
            _M_RC_BYPASSED.inc()
            return
        with self._lock:
            if key in self._entries:
                return
            self._entries[key] = blob
            self._bytes += len(blob)
            self.admissions += 1
            _M_RC_ADMITTED.inc()
            while self._bytes > self.budget and self._entries:
                _, old = self._entries.popitem(last=False)
                self._bytes -= len(old)
                self.evictions += 1
                _M_RC_EVICTED.inc()

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes": self._bytes,
                "budget_bytes": self.budget,
                "max_entry_bytes": self.max_entry_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "admissions": self.admissions,
                "bypasses": self.bypasses,
                "evictions": self.evictions,
            }


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "neurstore"
    # Latency hygiene: without these, a streamed response is one small
    # send per frame and Nagle + delayed ACK turn every request into a
    # ~40ms stall on loopback.
    disable_nagle_algorithm = True
    wbufsize = 1 << 16  # handle_one_request() flushes per response

    # The owning ModelStoreServer (set on the server object at mount).
    @property
    def ctx(self) -> "ModelStoreServer":
        return self.server.ctx  # type: ignore[attr-defined]

    def log_message(self, fmt, *args):  # quiet by default; ctx counts
        pass

    def send_response(self, code, message=None):
        # Remember the status for the per-route metrics in _route()
        # (BaseHTTPRequestHandler has no other hook for it).
        self._last_status = code
        super().send_response(code, message)

    # ------------------------------------------------------------ plumbing
    def _send_json(self, status: int, obj: dict, headers: dict | None = None):
        body = json.dumps(obj).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _send_error_for(self, exc: BaseException) -> None:
        status, payload = error_payload(exc)
        headers = {}
        if payload["error"]["code"] == "backpressure":
            headers["Retry-After"] = str(self.ctx.admission.retry_after_s)
        if status >= 500:
            self.ctx.count("errors_5xx")
        if self.headers.get("Transfer-Encoding") or \
                int(self.headers.get("Content-Length") or 0):
            # The request body may be partially unread (an admission
            # reject fires before the upload is consumed); anything left
            # on the socket would be misparsed as the next request, so
            # this connection must not be reused.
            self.close_connection = True
            headers["Connection"] = "close"
        self._send_json(status, payload, headers)

    def _body_reader(self):
        if self.headers.get("Transfer-Encoding", "").lower() == "chunked":
            return _ChunkedReader(self.rfile)
        length = int(self.headers.get("Content-Length") or 0)
        return _BoundedReader(self.rfile, length)

    def _read_json_body(self) -> dict:
        data = self._body_reader().read(-1)
        if not data:
            return {}
        try:
            obj = json.loads(data.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as exc:
            raise ValueError(f"request body is not JSON: {exc}") from exc
        if not isinstance(obj, dict):
            raise ValueError("request body must be a JSON object")
        return obj

    # -------------------------------------------------------------- routes
    def do_GET(self):
        self._route("GET")

    def do_POST(self):
        self._route("POST")

    def do_PUT(self):
        self._route("PUT")

    def do_DELETE(self):
        self._route("DELETE")

    def _route(self, method: str) -> None:
        """Metrics/tracing envelope around the actual dispatch.

        The request span adopts a client-supplied ``traceparent`` (W3C
        format), so engine spans opened on this handler thread hang off
        the caller's trace id. Per-route counters use fixed route
        templates (``self._route_label``, assigned at dispatch) and the
        status class of the *first* response line sent.
        """
        ctx = self.ctx
        ctx.count("requests")
        parent = parse_traceparent(self.headers.get("traceparent") or "")
        self._route_label = "unknown"
        self._last_status = 0
        span = trace("http.request", parent=parent, method=method,
                     path=self.path)
        _M_INFLIGHT.inc()
        try:
            with span:
                self._dispatch(method)
        finally:
            _M_INFLIGHT.dec()
            status = f"{self._last_status // 100}xx" if self._last_status \
                else "aborted"
            _M_REQUESTS.labels(self._route_label, method, status).inc()
            _M_REQ_SECONDS.labels(self._route_label).observe(span.elapsed())

    def _dispatch(self, method: str) -> None:
        ctx = self.ctx
        url = urlsplit(self.path)
        query = parse_qs(url.query)
        parts = [unquote(p) for p in url.path.strip("/").split("/")]
        try:
            if parts[:1] != ["v1"]:
                raise KeyError(url.path)
            rest = parts[1:]
            if rest == ["healthz"] and method == "GET":
                self._route_label = "healthz"
                self._healthz()
                return
            if rest == ["stats"] and method == "GET":
                self._route_label = "stats"
                self._get_stats()
                return
            if rest == ["metrics"] and method == "GET":
                self._route_label = "metrics"
                self._get_metrics()
                return
            if rest == ["accounting"] and method == "GET":
                self._route_label = "accounting"
                self._send_json(200, _jsonable(ctx.engine.accounting_report(
                    tenant_of=_tenant_of)))
                return
            if rest == ["admin", "vacuum"] and method == "POST":
                self._route_label = "admin.vacuum"
                body = self._read_json_body()
                report = ctx.engine.vacuum(
                    min_dead_fraction=float(body.get("min_dead_fraction", 0.0))
                )
                self._send_json(200, _jsonable(report))
                return
            if len(rest) >= 3 and rest[0] == "tenants":
                tenant = validate_tenant(rest[1])
                if rest[2:] == ["models"] and method == "GET":
                    self._route_label = "tenant.models"
                    self._list_models(tenant)
                    return
                if rest[2:] == ["quota"] and method == "GET":
                    self._route_label = "tenant.quota"
                    self._send_json(
                        200, ctx.quotas.report(ctx.engine, tenant))
                    return
                if (len(rest) >= 5 and rest[2] == "models"
                        and rest[-1] == "explain" and method == "GET"):
                    # Checked before the generic model routes: model
                    # names may contain "/", so ".../models/x/explain"
                    # would otherwise parse as model "x/explain".
                    self._route_label = "model.explain"
                    name = "/".join(rest[3:-1])
                    self._send_json(200, _jsonable(ctx.engine.model_explain(
                        tenant_model_name(tenant, name))))
                    return
                if len(rest) >= 4 and rest[2] == "models":
                    name = "/".join(rest[3:])
                    if method in _WRITE_METHODS:
                        ctx.admission.check_write(
                            StoreStats.from_engine(ctx.engine.stats()))
                    if method == "GET":
                        if query.get("info"):
                            self._route_label = "model.info"
                            self._model_info(tenant, name)
                        else:
                            self._route_label = "model.download"
                            self._download(tenant, name, query)
                        return
                    if method in ("POST", "PUT"):
                        self._route_label = (
                            "model.replace" if method == "PUT"
                            else "model.upload"
                        )
                        self._upload(tenant, name, replace=(method == "PUT"))
                        return
                    if method == "DELETE":
                        self._route_label = "model.delete"
                        ctx.engine.delete_model(
                            tenant_model_name(tenant, name))
                        self._send_json(200, {"deleted": name})
                        return
            raise KeyError(url.path)
        except _ResponseSent:
            pass  # connection already aborted mid-stream
        except BrokenPipeError:
            self.close_connection = True
        except BaseException as exc:  # noqa: BLE001 — typed via the registry
            try:
                self._send_error_for(exc)
            except (BrokenPipeError, ConnectionResetError):
                self.close_connection = True

    # ------------------------------------------------------------ handlers
    def _healthz(self) -> None:
        """Liveness plus the facts a probe needs to page on: schema
        version, uptime, degraded-mode flag, maintenance-daemon health."""
        ctx = self.ctx
        engine = ctx.engine
        daemon = engine.maintenance
        maint = {"running": False, "consecutive_errors": 0,
                 "last_error_age_s": None}
        if daemon is not None:
            maint = {
                "running": daemon.running,
                "consecutive_errors": daemon.consecutive_errors,
                "last_error_age_s": daemon.last_error_age_s(),
            }
        self._send_json(200, {
            "ok": True,
            "stats_schema_version": STATS_SCHEMA_VERSION,
            "uptime_s": time.monotonic() - ctx.started_at,
            "read_only": engine.read_only,
            "slow_op_threshold_s": get_slow_op_threshold(),
            "maintenance": maint,
        })

    def _get_metrics(self) -> None:
        """Prometheus text exposition of the process-wide registry."""
        body = default_registry().render().encode("utf-8")
        self.send_response(200)
        self.send_header(
            "Content-Type", "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _get_stats(self) -> None:
        stats = StoreStats.from_engine(self.ctx.engine.stats())
        out = stats.to_dict()
        # Server-side telemetry rides in the undocumented raw dump; the
        # documented schema stays exactly the StoreStats fields.
        out["raw"]["server"] = self.ctx.server_stats()
        self._send_json(200, out)

    def _list_models(self, tenant: str) -> None:
        prefix = f"{tenant}/"
        names = [
            n[len(prefix):]
            for n in self.ctx.engine.list_models()
            if n.startswith(prefix)
        ]
        self._send_json(200, {"models": names})

    def _model_info(self, tenant: str, name: str) -> None:
        full = tenant_model_name(tenant, name)
        entry = self.ctx.engine.model_info(full)
        if entry is None:
            raise KeyError(name)
        info = entry.to_dict()
        info["name"] = name
        info["page_bytes"] = self.ctx.engine._page_size(entry)
        self._send_json(200, info)

    def _upload(self, tenant: str, name: str, replace: bool) -> None:
        """Streamed save: decode tensors record-by-record, commit, report.

        Tensor arrays are collected as independent per-record buffers
        (the dict the engine's Algorithm-1 pipeline wants); the *model*
        never exists as one contiguous buffer on this side of the wire.
        """
        full = tenant_model_name(tenant, name)
        reader = self._body_reader()
        header, records = wire.decode_model_stream(reader)
        tensors = OrderedDict()
        for tname, arr in records:
            if tname in tensors:
                raise ValueError(f"duplicate tensor {tname!r} in upload")
            tensors[tname] = arr
        # Drain the body to its end (the chunked terminator / any slack)
        # so the keep-alive connection is positioned at the next request.
        reader.read(-1)
        req = SaveRequest.from_wire(header, tensors)
        engine = self.ctx.engine
        if replace:
            report = engine.replace_model(
                full, req.architecture, req.tensors,
                tolerance=req.tolerance, tau=req.tau)
        else:
            report = engine.save_model(
                full, req.architecture, req.tensors,
                tolerance=req.tolerance, tau=req.tau)
        out = report.to_dict()
        out["name"] = name  # strip the tenant prefix from the wire name
        self._send_json(200, out)

    def _download(self, tenant: str, name: str, query: dict) -> None:
        full = tenant_model_name(tenant, name)
        bits = None
        if query.get("bits"):
            bits = int(query["bits"][0])
        cache = self.ctx.response_cache
        entry = self.ctx.engine.model_info(full)
        if entry is not None:
            blob = cache.get((entry.model_id, bits))
            if blob is not None:  # hot path: one send, nothing recomputed
                self._send_stream_headers()
                self._stream_body([blob])
                return
        # Open the handle (snapshot capture) BEFORE committing to a 200:
        # not_found/corrupt surface as proper statuses. After streaming
        # starts the only honest failure mode is connection abort — the
        # client detects it via the missing trailer frame.
        lm = self.ctx.engine.load_model(full, bits=bits)
        try:
            header = {
                "name": name,
                "architecture": lm.info["architecture"],
                "bits": bits,
                "n_tensors": len(lm.tensor_names()),
            }
            frames: list[bytes] = []
            self._send_stream_headers()
            # The span covers dequant + wire encode + socket writes — the
            # part of a cold download the response cache saves on a hit.
            with trace("decode", model=name, n_tensors=header["n_tensors"]):
                self._stream_body(
                    wire.encode_model_stream(header, lm.iter_tensors()),
                    collect=frames)
            if frames:
                cache.put((lm.info["id"], bits), b"".join(frames))
        finally:
            lm.close()

    def _send_stream_headers(self) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "application/x-neurstore-stream")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()

    def _stream_body(self, frames, collect: list | None = None) -> None:
        """Send frames as chunks; on ``collect`` success-only accumulate."""
        try:
            for frame in frames:
                self._write_chunk(frame)
                if collect is not None:
                    collect.append(frame)
            self.wfile.write(b"0\r\n\r\n")
        except (BrokenPipeError, ConnectionResetError):
            self.close_connection = True
            if collect is not None:
                collect.clear()  # encode may not have finished cleanly
        except BaseException as exc:
            # Mid-stream failure after the 200 went out: abort the
            # connection so the client sees a truncated stream
            # (WireError), never a silently short model — and never a
            # second response spliced into the chunk sequence.
            self.ctx.count("errors_5xx")
            self.close_connection = True
            raise _ResponseSent() from exc

    def _write_chunk(self, data: bytes) -> None:
        self.wfile.write(f"{len(data):x}\r\n".encode("ascii"))
        self.wfile.write(data)
        self.wfile.write(b"\r\n")


def _tenant_of(full_name: str) -> str | None:
    """Accounting tenant attribution: the validated tenant namespace of
    a catalog name, or ``None`` for embedded (non-namespaced) models."""
    parsed = split_tenant(full_name)
    return parsed[0] if parsed is not None else None


def _jsonable(obj):
    """Deep-convert a report dict to JSON-safe types (int dict keys)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set)):
        return [_jsonable(v) for v in obj]
    return obj


class ModelStoreServer:
    """One engine behind a threaded HTTP front door.

    ``port=0`` binds an ephemeral port (read it back via ``.port``).
    The server installs the tenant quota gate as the engine's
    ``commit_gate`` for its lifetime; embedded (non-namespaced) saves
    through the same engine are unaffected by tenant quotas.
    """

    def __init__(
        self,
        engine,
        host: str = "127.0.0.1",
        port: int = 0,
        quotas: QuotaManager | None = None,
        admission: AdmissionPolicy | None = None,
        response_cache_bytes: int = 256 << 20,
        response_cache_max_entry_bytes: int | None = None,
        slow_op_threshold_s: float | None = None,
    ):
        self.engine = engine
        if slow_op_threshold_s is not None:
            # Process-wide knob (one trace ring, one threshold); the
            # active value is surfaced in /v1/healthz. None = leave the
            # env-var / set_slow_op_threshold() configured value alone.
            set_slow_op_threshold(slow_op_threshold_s)
        self.quotas = quotas if quotas is not None else QuotaManager()
        self.admission = admission if admission is not None else AdmissionPolicy()
        self.started_at = time.monotonic()
        # Hot downloads skip reconstruction entirely (keyed by immutable
        # model version, so replaces invalidate by key drift).
        self.response_cache = _ResponseCache(
            response_cache_bytes,
            max_entry_bytes=response_cache_max_entry_bytes,
        )
        self.httpd = ThreadingHTTPServer((host, port), _Handler)
        self.httpd.daemon_threads = True
        self.httpd.ctx = self  # type: ignore[attr-defined]
        self._counters: dict[str, int] = {"requests": 0, "errors_5xx": 0}
        self._counter_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        engine.commit_gate = self.quotas.gate(engine)

    # ------------------------------------------------------------- control
    @property
    def host(self) -> str:
        return self.httpd.server_address[0]

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    def start(self) -> "ModelStoreServer":
        """Serve on a background thread (returns immediately)."""
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, kwargs={"poll_interval": 0.05},
            name="neurstore-server", daemon=True,
        )
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Serve on the calling thread (the ``python -m repro.server`` path)."""
        self.httpd.serve_forever()

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        # The engine may outlive the server (caller-owned), but queued
        # EXPLAIN sidecars should not wait for its close().
        self.engine.flush_explains()
        if self.engine.commit_gate is not None:
            self.engine.commit_gate = None

    def __enter__(self) -> "ModelStoreServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ----------------------------------------------------------- telemetry
    def count(self, key: str) -> None:
        with self._counter_lock:
            self._counters[key] = self._counters.get(key, 0) + 1

    def server_stats(self) -> dict:
        with self._counter_lock:
            out = dict(self._counters)
        out["admission"] = self.admission.stats()
        out["response_cache"] = self.response_cache.stats()
        return out
