"""NeurStore storage engine (paper §3, §4.1, §4.2 / Algorithm 1).

Components mirroring Figure 3:

* **Index storage** — a pool of HNSW indexes, one per flattened tensor
  length, holding 8-bit quantized base tensors; fronted by a byte-budgeted
  **index cache** with LRU eviction (evicted indexes are serialized to disk
  and reloaded on demand — paper §4.1 "Index Cache", §5 "32 GB default").
* **Delta tensor storage** — read-only tensor pages, one per model, records
  ordered by the model architecture for locality (paper §4.1).
* **Metadata storage** — model id/name → architecture + page path, the
  library analogue of the paper's relational model table.

``save_model`` is Algorithm 1 verbatim: decouple → per-tensor ANN search →
delta encode → SHOULDCOMPRESS(δ) range-vs-τ check → (maybe) new vertex →
adaptive n-bit quantization → page write.

Save-pipeline hot path (this is the throughput-critical write side):

* tensors are **grouped by flattened dim** so each HNSW index is fetched
  from the cache once per save instead of once per tensor;
* only the index search/insert and metadata mutation run under the global
  lock — delta quantization, planar bit-packing and page assembly happen
  outside it, so concurrent saves overlap their CPU-heavy encode work;
* the index cache tracks a **dirty flag per index**: ``flush()`` (called at
  commit) reserializes only indexes that gained a vertex during this save.
  The seed flushed every resident index on every save — O(total resident
  index bytes) of pickling per save even when nothing changed.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import OrderedDict

import numpy as np

from .hnsw import HNSWIndex
from .pages import TensorPage, TensorRecord, encode_payload, read_page_header, write_page
from .quantize import (
    dequantize_delta,
    quantize_delta,
)

__all__ = ["StorageEngine", "SaveReport", "DEFAULT_TOLERANCE", "DEFAULT_TAU"]

# Paper §4.2 Discussion: default p = 2^-24 (below f32 machine epsilon);
# §6.1.3: default similarity threshold tau = 0.16.
DEFAULT_TOLERANCE = 2.0 ** -24
DEFAULT_TAU = 0.16


@dataclasses.dataclass
class SaveReport:
    """Statistics from one ``save_model`` call (feeds the benchmarks)."""

    model_id: int
    name: str
    original_bytes: int
    page_bytes: int
    n_tensors: int
    n_new_bases: int
    n_deltas: int
    nbits: list[int]
    seconds: float

    @property
    def mean_nbit(self) -> float:
        return float(np.mean(self.nbits)) if self.nbits else 0.0


class _IndexCache:
    """LRU cache of deserialized HNSW indexes, bounded by bytes (paper §4.1).

    Tracks a dirty flag per resident index: ``flush()`` writes only indexes
    mutated since their last serialization, and eviction skips the disk
    write for clean indexes that already have an on-disk copy. A save in
    progress **pins** the dims it is mutating so a concurrent load's
    ``get`` can never evict an index out from under the insert loop (a
    detached-but-still-mutating index would silently lose vertices).
    """

    def __init__(self, root: str, budget_bytes: int):
        self.root = root
        self.budget = budget_bytes
        self._live: OrderedDict[int, HNSWIndex] = OrderedDict()
        self._dirty: set[int] = set()
        self._pins: dict[int, int] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.dirty_flushes = 0

    def _path(self, dim: int) -> str:
        return os.path.join(self.root, f"hnsw_{dim}.idx")

    def get(self, dim: int, create: bool = False) -> HNSWIndex | None:
        with self._lock:
            if dim in self._live:
                self._live.move_to_end(dim)
                self.hits += 1
                return self._live[dim]
            path = self._path(dim)
            if os.path.exists(path):
                self.misses += 1
                with open(path, "rb") as f:
                    idx = HNSWIndex.from_bytes(f.read())
            elif create:
                # A fresh index is still a miss: nothing resident served it.
                self.misses += 1
                idx = HNSWIndex(dim)
            else:
                return None
            self._live[dim] = idx
            self._evict()
            return idx

    def mark_dirty(self, dim: int) -> None:
        """Record that the resident index for ``dim`` was mutated."""
        with self._lock:
            self._dirty.add(dim)

    def pin(self, dim: int) -> None:
        """Exempt ``dim`` from eviction while a save mutates it."""
        with self._lock:
            self._pins[dim] = self._pins.get(dim, 0) + 1

    def unpin(self, dim: int) -> None:
        with self._lock:
            n = self._pins.get(dim, 0) - 1
            if n > 0:
                self._pins[dim] = n
            else:
                self._pins.pop(dim, None)

    def _write(self, dim: int, idx: HNSWIndex) -> None:
        with open(self._path(dim), "wb") as f:
            f.write(idx.to_bytes())

    def _evict(self) -> None:
        while len(self._live) > 1 and self.resident_bytes() > self.budget:
            newest = next(reversed(self._live))  # being handed to a caller
            victim = next(
                (d for d in self._live if d not in self._pins and d != newest),
                None,
            )
            if victim is None:
                return  # everything else resident is pinned by in-flight saves
            idx = self._live.pop(victim)
            self.evictions += 1
            if victim in self._dirty or not os.path.exists(self._path(victim)):
                self._write(victim, idx)
                self._dirty.discard(victim)

    def resident_bytes(self) -> int:
        return sum(i.nbytes for i in self._live.values())

    def flush(self) -> None:
        """Serialize mutated resident indexes only (dirty-aware)."""
        with self._lock:
            for dim, idx in self._live.items():
                if dim in self._dirty or not os.path.exists(self._path(dim)):
                    self._write(dim, idx)
                    self.dirty_flushes += 1
            self._dirty.clear()

    def stats(self) -> dict:
        """Cache counters for the benchmarks (hnsw_bench reports these)."""
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "dirty_flushes": self.dirty_flushes,
                "resident": len(self._live),
                "dirty": len(self._dirty),
            }

    def dims(self) -> list[int]:
        with self._lock:
            on_disk = {
                int(f[len("hnsw_"):-len(".idx")])
                for f in os.listdir(self.root)
                if f.startswith("hnsw_") and f.endswith(".idx")
            }
            return sorted(on_disk | set(self._live))


class StorageEngine:
    """The NeurStore tensor-based storage engine."""

    def __init__(
        self,
        root: str,
        tolerance: float = DEFAULT_TOLERANCE,
        tau: float = DEFAULT_TAU,
        cache_bytes: int = 32 << 30,
        ef_search: int = 32,
    ):
        self.root = root
        os.makedirs(os.path.join(root, "pages"), exist_ok=True)
        os.makedirs(os.path.join(root, "index"), exist_ok=True)
        self.tolerance = tolerance
        self.tau = tau
        self.ef_search = ef_search
        self.index_cache = _IndexCache(os.path.join(root, "index"), cache_bytes)
        self._meta_path = os.path.join(root, "meta.json")
        self._meta: dict = {"models": {}, "next_id": 0, "vertex_refs": {}}
        if os.path.exists(self._meta_path):
            with open(self._meta_path) as f:
                self._meta = json.load(f)
        self._lock = threading.RLock()

    # --------------------------------------------------------------- helpers
    def _persist_meta(self) -> None:
        tmp = self._meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._meta, f)
        os.replace(tmp, self._meta_path)  # atomic commit

    def _page_path(self, model_id: int) -> str:
        return os.path.join(self.root, "pages", f"model_{model_id}.page")

    def _ref_vertex(self, dim: int, vid: int, delta: int = 1) -> None:
        key = f"{dim}:{vid}"
        refs = self._meta["vertex_refs"]
        refs[key] = refs.get(key, 0) + delta

    # ----------------------------------------------------------- save (Alg 1)
    def save_model(
        self,
        name: str,
        architecture: dict,
        tensors: "OrderedDict[str, np.ndarray] | dict[str, np.ndarray]",
        tolerance: float | None = None,
        tau: float | None = None,
    ) -> SaveReport:
        """Algorithm 1: delta-quantize ``tensors`` and persist one page.

        ``tensors`` is name → float array, iterated in architecture order so
        records land in page order matching the computation graph (paper
        §4.1 "delta tensors are organized in the order defined by the model
        architecture").

        The index work is grouped by flattened dim (one cache fetch per
        index) and runs under the engine lock; the CPU-heavy delta
        quantization + planar bit-packing run after the lock is released.
        Page records keep the original tensor order regardless of grouping.
        """
        t0 = time.perf_counter()
        p = self.tolerance if tolerance is None else tolerance
        tau_ = self.tau if tau is None else tau
        # Grouping needs only names/shapes — no float64 upcast is made here.
        items: list[tuple[str, tuple[int, ...], object]] = []
        by_dim: "OrderedDict[int, list[int]]" = OrderedDict()
        original_bytes = 0
        for tname, tensor in tensors.items():
            src = np.asarray(tensor)
            original_bytes += src.size * 4  # stored models are float32
            by_dim.setdefault(src.size, []).append(len(items))
            items.append((tname, tuple(int(s) for s in src.shape), src))

        # Phase 1 (locked): per-dim ANN search / vertex insert (Alg. 1
        # l.2-3). Dims are pinned so a concurrent load's cache fetch cannot
        # evict an index this save is mutating. Each tensor's float64
        # upcast lives only for its own search/insert; only the delta
        # survives the loop.
        bases: list[tuple[int, np.ndarray] | None] = [None] * len(items)
        n_new = 0
        for dim in by_dim:
            self.index_cache.pin(dim)
        try:
            with self._lock:
                for dim, positions in by_dim.items():
                    index = self.index_cache.get(dim, create=True)
                    for pos in positions:
                        flat = np.asarray(items[pos][2], dtype=np.float64).ravel()
                        # (2) ANN search for the closest base tensor.
                        hit = index.search(flat, k=1, ef=self.ef_search)
                        vid = hit[0][1] if hit else -1
                        if vid >= 0:
                            base = index.dequantize_vertex(vid)
                            delta = flat - base
                        else:
                            delta = None
                        # (3) SHOULDCOMPRESS: range-of-delta vs tau (§4.2).
                        if delta is None or float(delta.max() - delta.min()) > tau_:
                            # New vertex: quantize t to 8-bit, insert,
                            # recompute delta against its own de-quantized
                            # representation.
                            vid = index.insert(flat)
                            self.index_cache.mark_dirty(dim)
                            base = index.dequantize_vertex(vid)
                            delta = flat - base
                            n_new += 1
                        bases[pos] = (vid, delta)
                        self._ref_vertex(dim, vid)
        finally:
            for dim in by_dim:
                self.index_cache.unpin(dim)

        # Phase 2 (unlocked): adaptive n-bit quantization of each delta
        # (Eq. 2/3) + planar bit-packing + page assembly, in tensor order.
        # Deltas are released as they are consumed.
        records: list[TensorRecord] = []
        nbits: list[int] = []
        for i, (tname, shape, src) in enumerate(items):
            vid, delta = bases[i]
            bases[i] = None
            qd, meta = quantize_delta(delta, p)
            nbits.append(meta.nbit)
            rec = TensorRecord(
                name=tname,
                shape=shape,
                dim_key=src.size,
                vertex_id=vid,
                meta=meta,
                qdelta=qd,
            )
            rec.payload = encode_payload(rec)
            records.append(rec)
        page = write_page(records)

        # Phase 3 (locked): durable commit — page file, metadata, dirty
        # indexes only.
        with self._lock:
            model_id = self._meta["next_id"]
            self._meta["next_id"] = model_id + 1
            with open(self._page_path(model_id), "wb") as f:
                f.write(page)
            self._meta["models"][name] = {
                "id": model_id,
                "architecture": architecture,
                "page": os.path.basename(self._page_path(model_id)),
                "n_tensors": len(records),
                "original_bytes": original_bytes,
            }
            self._persist_meta()
            self.index_cache.flush()
        return SaveReport(
            model_id=model_id,
            name=name,
            original_bytes=original_bytes,
            page_bytes=len(page),
            n_tensors=len(records),
            n_new_bases=n_new,
            n_deltas=len(records) - n_new,
            nbits=nbits,
            seconds=time.perf_counter() - t0,
        )

    # ------------------------------------------------------------------ load
    def open_page(self, name: str) -> tuple[TensorPage, dict]:
        info = self._meta["models"][name]
        with open(os.path.join(self.root, "pages", info["page"]), "rb") as f:
            page = read_page_header(f.read())
        return page, info

    def load_model(self, name: str, bits: int | None = None):
        """Compression-aware load — see :mod:`repro.core.loader`."""
        from .loader import LoadedModel

        page, info = self.open_page(name)
        return LoadedModel(engine=self, page=page, info=info, bits=bits)

    # ------------------------------------------------------------ accounting
    def list_models(self) -> list[str]:
        return list(self._meta["models"].keys())

    def storage_bytes(self) -> dict:
        """Total storage split: pages vs index (paper Fig. 10a breakdown).

        Takes the engine lock so the flush never serializes an index that a
        concurrent ``save_model`` phase 1 is mutating.
        """
        with self._lock:
            pages = sum(
                os.path.getsize(os.path.join(self.root, "pages", m["page"]))
                for m in self._meta["models"].values()
            )
            self.index_cache.flush()
            index = sum(
                os.path.getsize(os.path.join(self.root, "index", f))
                for f in os.listdir(os.path.join(self.root, "index"))
            )
        return {"pages": pages, "index": index, "total": pages + index}

    def per_model_bytes(self, name: str) -> float:
        """Page bytes + amortized share of referenced base-tensor storage.

        Paper §6.3.2: "evenly distribute the storage cost of each base tensor
        in the index across all tensors that reference it".
        """
        page, info = self.open_page(name)
        total = float(os.path.getsize(os.path.join(self.root, "pages", info["page"])))
        refs = self._meta["vertex_refs"]
        from .pages import read_record

        for i in range(page.n_records):
            rec = read_record(page, i, with_payload=False)
            share = refs.get(f"{rec.dim_key}:{rec.vertex_id}", 1)
            # 8-bit base codes + graph overhead approximated by codes size.
            total += rec.numel / max(share, 1)
        return total

    def reconstruct_tensor(self, rec: TensorRecord) -> np.ndarray:
        """Full reconstruction: de-quantized base + de-quantized delta."""
        index = self.index_cache.get(rec.dim_key)
        base = index.dequantize_vertex(rec.vertex_id)
        delta = dequantize_delta(rec.qdelta, rec.meta)
        return (base + delta).reshape(rec.shape).astype(np.float32)
