"""NeurStore storage engine (paper §3, §4.1, §4.2 / Algorithm 1).

Components mirroring Figure 3:

* **Index storage** — a pool of HNSW indexes, one per flattened tensor
  length, holding 8-bit quantized base tensors; fronted by a byte-budgeted
  **index cache** with LRU eviction (evicted indexes are serialized to disk
  and reloaded on demand — paper §4.1 "Index Cache", §5 "32 GB default").
* **Delta tensor storage** — read-only tensor pages, one per model, records
  ordered by the model architecture for locality (paper §4.1).
* **Catalog** — the transactional model table (``repro.core.catalog``):
  typed entries, monotonic model ids, vertex reference counts, and a
  write-ahead journal that makes every lifecycle operation atomic.

``save_model`` is Algorithm 1 verbatim: decouple → per-tensor ANN search →
delta encode → SHOULDCOMPRESS(δ) range-vs-τ check → (maybe) new vertex →
adaptive n-bit quantization → page write.

Save-pipeline hot path (this is the throughput-critical write side):

* tensors are **grouped by flattened dim** so each HNSW index is fetched
  from the cache once per save instead of once per tensor;
* only the index search/insert and catalog mutation run under the global
  lock — delta quantization, planar bit-packing and page assembly happen
  outside it, so concurrent saves overlap their CPU-heavy encode work;
* the index cache tracks a **dirty flag per index**: ``flush()`` (called at
  commit) reserializes only indexes that gained a vertex during this save.

Model lifecycle (this is what makes the engine a catalog, not an archive):

* ``delete_model`` / ``replace_model`` decrement ``vertex_refs``, unlink
  the model's page, and tombstone base vertices whose reference count
  drops to zero (the vertex stays in the graph as a waypoint until vacuum).
* ``vacuum(min_dead_fraction=…)`` compacts each index past the dead-vertex
  threshold: tombstones are dropped from the vertex arrays and adjacency,
  surviving page records are rewritten with the old→new vertex-id remap,
  and the reference table is renumbered — all under one journal
  transaction, so a crash at any point rolls forward or back cleanly.
* Every operation follows the same protocol: journal intent → physical
  side effects → atomic catalog snapshot (the commit point) → cleanup →
  journal commit. ``StorageEngine.__init__`` replays any interrupted
  transaction, leaving no orphan pages and no dangling ``vertex_refs``.
  See ``docs/lifecycle.md`` for the full state machine.

Concurrent read path (this is what lets N readers serve while writers run;
see ``docs/concurrency.md``):

* all page bytes flow through one **buffer pool**
  (``repro.core.bufferpool``): a byte-budgeted LRU of pinned frames whose
  decoded payloads are shared across every handle over a page version;
* ``load_model`` captures an epoch-stamped :class:`ModelSnapshot` (catalog
  entry + pinned page frame + per-dim index references) in one short
  critical section and **never takes the engine lock again** — writers
  bump the epoch at their atomic ``meta.json`` commit point;
* vacuum is **copy-on-write**: it compacts a clone of the index and
  rewrites affected pages under *new* page names, so a reader that opened
  before the vacuum keeps materializing bit-identically from its pinned
  snapshot while later readers see the compacted store;
* a background :class:`~repro.core.maintenance.MaintenanceDaemon` can run
  incremental auto-vacuum and buffer-pool pressure trims off the write
  path (``start_maintenance``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from collections import Counter, OrderedDict, deque

import numpy as np

from .bufferpool import BufferPool
from .catalog import (
    STATUS_COMMITTED,
    STATUS_CORRUPT,
    STATUS_PENDING,
    Catalog,
    ModelEntry,
    explain_pack,
    explain_unpack,
    maybe_fail,
)
from .faultfs import FaultFS
from .hnsw import HNSWIndex
from .integrity import (
    CorruptIndexError,
    CorruptJournalError,
    CorruptMetaError,
    CorruptPageError,
    ReadOnlyStoreError,
    frame_index,
    unframe_index,
)
from .pages import (
    TensorPage,
    TensorRecord,
    encode_payload,
    page_dim_keys,
    read_page_header,
    read_page_refs,
    read_record,
    remap_page_vertices,
    salvage_page_refs,
    verify_page,
    write_page,
)
from .quantize import (
    dequantize_delta,
    dequantize_linear_batch,
    quantize_delta,
    quantize_linear_batch,
)
from ..obs.accounting import ModelSpace, SpaceAccountant, TensorSpace
from ..obs.metrics import default_registry
from ..obs.trace import trace

__all__ = [
    "StorageEngine", "SaveReport", "DEFAULT_TOLERANCE", "DEFAULT_TAU",
    "STATS_SCHEMA_VERSION",
]

# Paper §4.2 Discussion: default p = 2^-24 (below f32 machine epsilon);
# §6.1.3: default similarity threshold tau = 0.16.
DEFAULT_TOLERANCE = 2.0 ** -24
DEFAULT_TAU = 0.16

# Version stamp on StorageEngine.stats(): the documented counters (see
# docs/serving.md) are API — the serving admission policy and StoreStats
# consume them — so layout changes must bump this.
STATS_SCHEMA_VERSION = 1

# Process-wide observability families (docs/observability.md is the
# stability contract for these names). Counters sum over every engine
# open in the process; gauges attach per-engine via weakref callbacks so
# a closed/collected engine drops out of the sum.
_REG = default_registry()
_M_OPS = _REG.counter(
    "neurstore_engine_ops_total",
    "Completed engine operations by type.",
    ("op",),
)
_M_OP_SECONDS = _REG.histogram(
    "neurstore_engine_op_seconds",
    "Engine operation wall time by type.",
    ("op",),
)
_M_PAGE_READS = _REG.counter(
    "neurstore_engine_page_reads_total",
    "Page files read and verified (buffer-pool frame loads).",
)
_M_PAGE_READ_BYTES = _REG.counter(
    "neurstore_engine_page_read_bytes_total",
    "Bytes read from page files.",
)
_M_QUARANTINES = _REG.counter(
    "neurstore_engine_quarantines_total",
    "Models quarantined after failing an integrity check.",
)
_M_MODELS = _REG.gauge(
    "neurstore_engine_models",
    "Committed catalog entries, summed over open engines.",
)
_M_EPOCH = _REG.gauge(
    "neurstore_engine_epoch",
    "Snapshot-isolation epoch, summed over open engines.",
)
_M_SNAPSHOTS_LIVE = _REG.gauge(
    "neurstore_engine_snapshots_live",
    "Live reader snapshots, summed over open engines.",
)
_M_DEDUP_OUTCOMES = _REG.counter(
    "neurstore_dedup_outcomes_total",
    "Save-time dedup decision per stored tensor "
    "(new_base / delta / intra_save_dedup).",
    ("outcome",),
)
_M_DELTA_BITS = _REG.histogram(
    "neurstore_delta_bits",
    "Adaptive delta-quantization bit-width chosen per stored tensor.",
    buckets=tuple(range(0, 33)),  # nbit is an integer in [0, MAX_NBIT]
)
_M_LOGICAL_BYTES = _REG.gauge(
    "neurstore_logical_bytes",
    "Uncompressed float32 bytes of committed models, summed over engines.",
)
_M_PHYSICAL_BYTES = _REG.gauge(
    "neurstore_physical_bytes",
    "Physical bytes (pages + shared base codes), summed over engines.",
)

# Save-probe regime switch (`_probe_dim_group`): brute-force the whole
# (G, N) distance block while the index is small or the group is fat
# relative to it; fall back to per-tensor HNSW descents on a grown index
# so save latency stays O(polylog N). A graph walk evaluates roughly
# ef·m·levels ≈ 512 candidate rows, hence the group factor.
BRUTE_PROBE_MAX_INDEX = 4096
BRUTE_PROBE_GROUP_FACTOR = 512

# A save's per-tensor EXPLAIN is persisted for the first this-many
# tensors only (the full list always rides the SaveReport). It lives in
# a per-model sidecar file (explain/model_<id>.json), written behind the
# save path and never fsynced: folding it into meta.json would make
# EVERY later commit's snapshot serialize+fsync pay for it, and even one
# extra file create per save is visible in the lifecycle benchmark's
# accounting gate — EXPLAIN is advisory, so losing a queued sidecar in a
# crash only degrades model_explain(), never correctness.
EXPLAIN_PERSIST_MAX = 256

# Pending EXPLAIN sidecars are flushed to disk once this many saves have
# queued (and always at close()/vacuum()): bounds queue memory while
# keeping the amortized save-path cost at 1/EXPLAIN_FLUSH_MAX writes.
EXPLAIN_FLUSH_MAX = 128

# Dim groups are probed in chunks of at most this many float64 elements
# (~64 MB for the stacked block), so a save's peak memory stays bounded by
# the chunk and not the whole group. Bases a chunk creates are resident
# before the next chunk probes, so cross-chunk dedup still happens — via
# the index itself instead of an in-memory candidate matrix.
PROBE_CHUNK_ELEMS = 1 << 23


@dataclasses.dataclass
class SaveReport:
    """Statistics from one ``save_model`` call (feeds the benchmarks)."""

    model_id: int
    name: str
    original_bytes: int
    page_bytes: int
    n_tensors: int
    n_new_bases: int
    n_deltas: int
    nbits: list[int]
    seconds: float
    # Per-tensor EXPLAIN, in tensor order: how Algorithm 1 stored each
    # tensor — {"tensor", "dim", "vertex_id", "outcome", "probe_distance"
    # (squared L2 of the ANN match, None if the index was empty),
    # "delta_range" (the quantity SHOULDCOMPRESS compares), "tau",
    # "nbit", "delta_bytes", "error_bound"}. See docs/observability.md.
    explain: list | None = None

    @property
    def mean_nbit(self) -> float:
        return float(np.mean(self.nbits)) if self.nbits else 0.0

    def to_dict(self) -> dict:
        """JSON-safe form — this IS the wire body of a served save."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SaveReport":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


class _Retry(Exception):
    """Internal: snapshot capture raced a writer — retry the loop."""


class _SnapshotRelease:
    """GC-safe snapshot release: appends to the engine's release queue.

    Runs from a ``weakref`` finalizer, possibly inside garbage collection
    on an arbitrary thread — so it must not take any lock. ``deque.append``
    is atomic; the engine drains the queue at its next operation boundary.
    Holds the queue (not the engine) so a dropped engine can still be
    collected.
    """

    __slots__ = ("queue", "token", "frame")

    def __init__(self, queue, token, frame):
        self.queue = queue
        self.token = token
        self.frame = frame

    def __call__(self):
        self.queue.append((self.token, self.frame))


class _IndexCache:
    """LRU cache of deserialized HNSW indexes, bounded by bytes (paper §4.1).

    Tracks a dirty flag per resident index: ``flush()`` writes only indexes
    mutated since their last serialization, and eviction skips the disk
    write for clean indexes that already have an on-disk copy. A save in
    progress **pins** the dims it is mutating so a concurrent load's
    ``get`` can never evict an index out from under the insert loop (a
    detached-but-still-mutating index would silently lose vertices).

    Budget enforcement happens at two points: ``_evict`` (on ``get``)
    spills least-recently-used indexes but always keeps the index being
    handed to the caller resident, and ``trim`` (called by the engine at
    commit boundaries, when no handle is outstanding) may spill *every*
    unpinned index — including a single resident index larger than the
    whole budget, which ``_evict`` alone could never reclaim.
    """

    def __init__(self, root: str, budget_bytes: int, fs: FaultFS | None = None):
        self.root = root
        self.budget = budget_bytes
        self.fs = fs if fs is not None else FaultFS()
        self._live: OrderedDict[int, HNSWIndex] = OrderedDict()
        self._dirty: set[int] = set()
        self._pins: dict[int, int] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.dirty_flushes = 0

    def _path(self, dim: int) -> str:
        return os.path.join(self.root, f"hnsw_{dim}.idx")

    def get(self, dim: int, create: bool = False) -> HNSWIndex | None:
        with self._lock:
            if dim in self._live:
                self._live.move_to_end(dim)
                self.hits += 1
                return self._live[dim]
            path = self._path(dim)
            if os.path.exists(path):
                self.misses += 1
                idx = self._read(path)
            elif create:
                # A fresh index is still a miss: nothing resident served it.
                self.misses += 1
                idx = HNSWIndex(dim)
            else:
                return None
            self._live[dim] = idx
            self._evict()
            return idx

    def mark_dirty(self, dim: int) -> None:
        """Record that the resident index for ``dim`` was mutated."""
        with self._lock:
            self._dirty.add(dim)

    def mark_clean(self, dim: int) -> None:
        """Resident index already matches disk (e.g. vacuum just wrote it)."""
        with self._lock:
            self._dirty.discard(dim)

    def drop(self, dim: int) -> None:
        """Discard a resident index without writing it (failed mutation)."""
        with self._lock:
            self._live.pop(dim, None)
            self._dirty.discard(dim)

    def pin(self, dim: int) -> None:
        """Exempt ``dim`` from eviction while a save mutates it."""
        with self._lock:
            self._pins[dim] = self._pins.get(dim, 0) + 1

    def unpin(self, dim: int) -> None:
        with self._lock:
            n = self._pins.get(dim, 0) - 1
            if n > 0:
                self._pins[dim] = n
            else:
                self._pins.pop(dim, None)

    def _read(self, path: str) -> HNSWIndex:
        """Load an index file, verifying its frame CRC before unpickling.

        A flipped bit in a pickle can deserialize into silently wrong
        vertex codes — the worst failure mode, since every delta decodes
        against a wrong base — so the payload is checksum-verified first
        (:func:`~repro.core.integrity.unframe_index`); legacy unframed
        files get their parse errors wrapped as :class:`CorruptIndexError`.
        """
        payload = unframe_index(self.fs.read_bytes(path, site="index.read"), path)
        try:
            return HNSWIndex.from_bytes(payload)
        except Exception as exc:
            raise CorruptIndexError(f"{path}: does not parse: {exc!r}") from exc

    def _write(self, dim: int, idx: HNSWIndex) -> None:
        # fsync: the save protocol commits the catalog only after vertices
        # are durable — a page must never reference a vertex the index
        # file could lose in a power cut.
        self.fs.write_durable(
            self._path(dim), frame_index(idx.to_bytes()), site="index.write"
        )

    def _evict(self) -> None:
        while len(self._live) > 1 and self.resident_bytes() > self.budget:
            newest = next(reversed(self._live))  # being handed to a caller
            victim = next(
                (d for d in self._live if d not in self._pins and d != newest),
                None,
            )
            if victim is None:
                return  # everything else resident is pinned by in-flight saves
            idx = self._live.pop(victim)
            self.evictions += 1
            if victim in self._dirty or not os.path.exists(self._path(victim)):
                self._write(victim, idx)
                self._dirty.discard(victim)

    def trim(self) -> None:
        """Enforce the byte budget with no outstanding handle (commit time).

        Unlike ``_evict`` this may spill the sole resident index, closing
        the gap where one index larger than the entire budget stayed
        resident forever and its bytes were never reclaimed.
        """
        with self._lock:
            while self._live and self.resident_bytes() > self.budget:
                victim = next((d for d in self._live if d not in self._pins), None)
                if victim is None:
                    return
                idx = self._live.pop(victim)
                self.evictions += 1
                if victim in self._dirty or not os.path.exists(self._path(victim)):
                    self._write(victim, idx)
                    self._dirty.discard(victim)

    def resident_bytes(self) -> int:
        return sum(i.nbytes for i in self._live.values())

    def flush(self) -> None:
        """Serialize mutated resident indexes only (dirty-aware)."""
        with self._lock:
            for dim, idx in self._live.items():
                if dim in self._dirty or not os.path.exists(self._path(dim)):
                    self._write(dim, idx)
                    self.dirty_flushes += 1
            self._dirty.clear()

    def replace(self, dim: int, idx: HNSWIndex) -> None:
        """Install ``idx`` as the resident index for ``dim`` (clean).

        Copy-on-write vacuum compacts a clone and swaps it in here; the
        previous object stays alive for the snapshots that captured it.
        The clone was just written to disk, so it installs clean.
        """
        with self._lock:
            self._live[dim] = idx
            self._live.move_to_end(dim)
            self._dirty.discard(dim)

    def stats(self) -> dict:
        """Cache counters for the benchmarks (hnsw_bench reports these)."""
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "dirty_flushes": self.dirty_flushes,
                "resident": len(self._live),
                "dirty": len(self._dirty),
            }

    def dims(self) -> list[int]:
        with self._lock:
            on_disk = {
                int(f[len("hnsw_"):-len(".idx")])
                for f in os.listdir(self.root)
                if f.startswith("hnsw_") and f.endswith(".idx")
            }
            return sorted(on_disk | set(self._live))


class StorageEngine:
    """The NeurStore tensor-based storage engine."""

    def __init__(
        self,
        root: str,
        tolerance: float = DEFAULT_TOLERANCE,
        tau: float = DEFAULT_TAU,
        cache_bytes: int = 32 << 30,
        ef_search: int = 32,
        pool_bytes: int = 1 << 30,
        auto_maintenance: bool = False,
        fs: FaultFS | None = None,
        checksums: bool = True,
        accounting: bool = True,
    ):
        self.root = root
        os.makedirs(os.path.join(root, "pages"), exist_ok=True)
        os.makedirs(os.path.join(root, "index"), exist_ok=True)
        os.makedirs(os.path.join(root, "explain"), exist_ok=True)
        self.tolerance = tolerance
        self.tau = tau
        self.ef_search = ef_search
        # All file access routes through one FaultFS shim so tests can
        # inject EIO / torn writes / bit flips / crash-at-fsync at any
        # individual I/O call; checksums=False skips page CRC compute +
        # verify (the durability benchmark's baseline mode).
        self.fs = fs if fs is not None else FaultFS()
        self.checksums = checksums
        # Incremental space accounting (docs/observability.md): the
        # ledger is updated at every commit point and reseeded by a full
        # rescan at open and after vacuum (which renumbers vertex ids).
        # accounting=False skips ledger maintenance and catalog EXPLAIN
        # persistence (SaveReport.explain is still produced) — the
        # lifecycle benchmark prices the difference.
        self.accounting = accounting
        self._accountant = SpaceAccountant()
        # Write-behind queue for EXPLAIN sidecars: model_id → bounded
        # explain slice, flushed to explain/model_<id>.json on close(),
        # vacuum(), or when EXPLAIN_FLUSH_MAX saves are pending. The
        # sidecar is advisory, so deferring it keeps its (measurable)
        # file-create cost out of the save path entirely; a crash loses
        # at most the queued tail, never ledger or model state.
        self._pending_explains: dict[int, list] = {}
        # Degraded read-only mode: set when the journal body or meta.json
        # is corrupt — serving the last good state is safe, mutating on
        # top of it is not.
        self.read_only = False
        self.degraded_reason: str | None = None
        self._corrupt_reasons: dict[str, str] = {}
        self._scrub_cursor = 0
        self.index_cache = _IndexCache(
            os.path.join(root, "index"), cache_bytes, fs=self.fs
        )
        # Single path to page bytes: every load shares frames (and decoded
        # payloads) here instead of re-reading files per handle.
        self.page_pool = BufferPool(pool_bytes)
        self.catalog = Catalog(root, fs=self.fs)
        if self.catalog.meta_fallback is not None:
            self._degrade(f"meta.json corrupt, serving last good snapshot "
                          f"({self.catalog.meta_fallback})")
        # (dim, vid) refs held by saves between ANN match and commit: keeps
        # a concurrent delete/vacuum from tombstoning a base an in-flight
        # page is about to reference.
        self._inflight: Counter = Counter()
        # Live reader snapshots: token → epoch. Handles release through
        # _released (a GC-safe queue drained at operation boundaries), so
        # stats() can report the oldest live snapshot and the pool can
        # unpin frames promptly.
        self._live_snapshots: dict[int, int] = {}
        self._snap_token = 0
        self._released: deque = deque()  # (token, frame) — append is atomic
        # Dims whose vacuum failed in-process (not a crash): the on-disk
        # index/pages/refs may be half-switched, so further use of the dim
        # must fail loudly until a reopen replays the journal.
        self._quarantined_dims: set[int] = set()
        # Optional save-commit veto hook (the serving layer's quota
        # enforcement point). Called under the engine lock, immediately
        # before a save's journal intent, with a list of
        # ``{"name", "page_bytes", "old_page_bytes"}`` dicts — one per
        # model in the transaction. Raising aborts the save before any
        # durable side effect is journaled (vertices already inserted in
        # phase 1 become unreferenced and are swept by vacuum, the same
        # contract as a crashed save). The hook must not invoke engine
        # write operations; read-only catalog access is safe (RLock).
        self.commit_gate = None
        self._lock = threading.RLock()
        self.maintenance = None
        self._recover()
        if self.accounting:
            self._accountant.reset(self._scan_model_spaces())
        # Gauge callbacks receive the engine weakly (no closure over
        # self): an engine that goes away stops being summed.
        _M_MODELS.attach(self, lambda e: len(e.catalog.state.models))
        _M_EPOCH.attach(self, lambda e: e.catalog.state.epoch)
        _M_SNAPSHOTS_LIVE.attach(self, lambda e: len(e._live_snapshots))
        _M_LOGICAL_BYTES.attach(
            self, lambda e: e._accountant.totals(e.catalog.ref_count)[0])
        _M_PHYSICAL_BYTES.attach(
            self, lambda e: e._accountant.totals(e.catalog.ref_count)[1])
        self.page_pool.attach_gauges()
        if auto_maintenance:
            self.start_maintenance()

    # --------------------------------------------------------------- helpers
    @property
    def _meta(self) -> dict:
        """Legacy read-only view of the catalog (pre-catalog dict format)."""
        return self.catalog.snapshot_dict()

    def _degrade(self, reason: str) -> None:
        """Enter read-only mode: loads keep serving, writes fail typed."""
        self.read_only = True
        if self.degraded_reason is None:
            self.degraded_reason = reason

    def _check_writable(self) -> None:
        if self.read_only:
            raise ReadOnlyStoreError(
                f"store is read-only: {self.degraded_reason}"
            )

    def _page_file(self, page_name: str) -> str:
        return os.path.join(self.root, "pages", page_name)

    def _page_path(self, model_id: int) -> str:
        return self._page_file(f"model_{model_id}.page")

    def _explain_file(self, model_id: int) -> str:
        return os.path.join(self.root, "explain", f"model_{model_id}.json")

    def _write_explain_sidecar(self, model_id: int, explain: list) -> None:
        """Persist the bounded EXPLAIN slice beside the catalog (packed
        rows, see ``catalog.EXPLAIN_FIELDS``). One plain write, no fsync
        — EXPLAIN is advisory, and an injected/real I/O error must never
        fail the already-committed save it annotates."""
        rows = explain_pack(explain[:EXPLAIN_PERSIST_MAX])
        data = json.dumps(rows).encode("utf-8")
        try:
            with self.fs.open(
                self._explain_file(model_id), "wb", site="explain.write"
            ) as f:
                f.write(data)
        except OSError:
            pass

    def flush_explains(self) -> int:
        """Drain the EXPLAIN write-behind queue to sidecar files.

        Runs automatically at close(), vacuum(), and every
        EXPLAIN_FLUSH_MAX queued saves; callers that need sidecars on
        disk *now* (e.g. before handing the store directory to another
        process) may invoke it directly. Returns the number flushed."""
        with self._lock:
            pending, self._pending_explains = self._pending_explains, {}
        for model_id, explain in pending.items():
            self._write_explain_sidecar(model_id, explain)
        return len(pending)

    def _load_explain_sidecar(self, model_id: int) -> list | None:
        """Read a model's persisted EXPLAIN rows back into dict form.
        None when absent/unreadable (pre-EXPLAIN stores, accounting-off
        saves, or a crash that outran the advisory write)."""
        try:
            rows = json.loads(self.fs.read_bytes(
                self._explain_file(model_id), site="explain.read"))
            if not isinstance(rows, list):
                return None
            return explain_unpack(rows)
        except (OSError, ValueError, TypeError):
            return None

    def _page_size(self, entry: ModelEntry | None) -> int:
        """On-disk bytes of an entry's page (0 when absent/unreadable)."""
        if entry is None:
            return 0
        try:
            return os.path.getsize(self._page_file(entry.page))
        except OSError:
            return 0

    def _unlink(self, path: str) -> None:
        try:
            self.fs.unlink(path, site="unlink")
        except FileNotFoundError:
            pass

    def _page_refs(self, page_name: str, strict: bool = False) -> Counter:
        """(dim, vertex_id) → count of records in a page (empty if missing).

        Header-only scan (``read_page_refs``): lifecycle ops run this under
        the engine lock, so it must not read whole page payloads. On a
        damaged page (unless ``strict``) it falls back to salvaging refs
        from records whose CRCs still verify — under-counting only *leaks*
        references (fsck rebuilds them); it never frees a base a surviving
        record depends on.
        """
        path = self._page_file(page_name)
        refs: Counter = Counter()
        if not os.path.exists(path):
            return refs
        try:
            with self.fs.open(path, "rb", site="page.refs") as f:
                for dim, vid in read_page_refs(f):
                    refs[(dim, vid)] += 1
        except CorruptPageError:
            if strict:
                raise
            refs = Counter()
            try:
                buf = self.fs.read_bytes(path, site="page.refs")
            except OSError:
                return refs
            for dim, vid in salvage_page_refs(buf):
                refs[(dim, vid)] += 1
        except OSError:
            if strict:
                raise
            return Counter()
        return refs

    def _check_quarantine(self, dim: int) -> None:
        if dim in self._quarantined_dims:
            raise RuntimeError(
                f"dim {dim} has a half-applied vacuum (in-process failure); "
                "reopen the engine to replay the journal"
            )

    def _tombstone_unreferenced(self, pairs) -> None:
        """Tombstone vertices from ``pairs`` with zero live references."""
        by_dim: dict[int, list[int]] = {}
        for dim, vid in pairs:
            if (
                self.catalog.ref_count(dim, vid) <= 0
                and self._inflight.get((dim, vid), 0) <= 0
            ):
                by_dim.setdefault(dim, []).append(vid)
        for dim, vids in by_dim.items():
            try:
                idx = self.index_cache.get(dim)
            except CorruptIndexError:
                # Nothing sound to tombstone in a corrupt index; fsck
                # removes/rebuilds the file once nothing references it.
                continue
            if idx is None:
                continue
            changed = False
            for vid in vids:
                # A crash can leave intents naming vertices that were never
                # flushed; skip ids past the durable end of the index.
                if 0 <= vid < len(idx) and not idx.is_deleted(vid):
                    idx.mark_deleted(vid)
                    changed = True
            if changed:
                self.index_cache.mark_dirty(dim)

    # --------------------------------------------------------------- recovery
    def _recover(self) -> None:
        """Replay the catalog journal: roll interrupted operations forward
        (catalog snapshot already switched) or back (snapshot untouched).

        Skipped entirely in degraded mode: replaying intents against a
        fallback (possibly stale) snapshot could roll back transactions
        that actually committed — read-only means *no* disk mutation.
        A corrupt journal body (damage before a valid record) likewise
        degrades instead of replaying guesses.
        """
        if self.read_only:
            return
        try:
            pending = self.catalog.recover_journal()
        except CorruptJournalError as exc:
            self._degrade(f"journal corrupt, replay skipped ({exc})")
            return
        dirty = self._drop_pending_entries()
        for group in pending:
            head = group[0]
            op = head.get("op")
            if op in ("save", "replace"):
                self._recover_put(head)
            elif op == "save_batch":
                self._recover_save_batch(head)
            elif op == "delete":
                self._recover_delete(head)
            elif op == "vacuum":
                switch = next(
                    (r for r in group if r.get("op") == "vacuum_switch"), None
                )
                if switch is None:
                    self._recover_vacuum_rollback(head)
                else:
                    self._recover_vacuum_forward(switch)
            dirty = True
        if dirty:
            self.index_cache.flush()
            self.catalog.save_snapshot()
        if pending:
            self.catalog.truncate_journal()
        self._sweep_orphan_pages()

    def _sweep_orphan_pages(self) -> None:
        """Unlink page files no committed entry references (post-replay the
        journal is empty, so anything unreferenced is dead weight: garbage
        from torn writes, or ``.vac`` side files a rollback left behind).
        EXPLAIN sidecars of dead model ids go the same way — theirs is the
        one gap the unlink-on-delete protocol can leave (a crash between a
        delete's commit point and its cleanup)."""
        pages_dir = os.path.join(self.root, "pages")
        referenced = {
            self.catalog.state.models[n].page for n in self.catalog.state.models
        }
        for fname in os.listdir(pages_dir):
            if fname in referenced:
                continue
            if fname.endswith(".vac") or (
                fname.startswith("model_") and fname.endswith(".page")
            ):
                self._unlink(os.path.join(pages_dir, fname))
        live_ids = {
            f"model_{self.catalog.state.models[n].model_id}.json"
            for n in self.catalog.state.models
        }
        explain_dir = os.path.join(self.root, "explain")
        for fname in os.listdir(explain_dir):
            if fname not in live_ids:
                self._unlink(os.path.join(explain_dir, fname))

    def _drop_pending_entries(self) -> bool:
        """Defensive sweep: a snapshot should never hold non-committed
        entries; if one appears (torn external edit), roll it back."""
        changed = False
        for name in list(self.catalog.state.models):
            entry = self.catalog.state.models[name]
            if entry.status != STATUS_PENDING:
                # Committed entries are fine; quarantined (corrupt) entries
                # must survive reopen so the damage stays visible until
                # repaired or explicitly dropped.
                continue
            refs = self._page_refs(entry.page)
            del self.catalog.state.models[name]
            for (dim, vid), c in refs.items():
                self.catalog.ref(dim, vid, -c)
            self._tombstone_unreferenced(refs)
            self._unlink(self._page_file(entry.page))
            changed = True
        return changed

    def _recover_put(self, rec: dict) -> None:
        entry = self.catalog.get(rec["name"])
        if entry is not None and entry.model_id == rec["id"]:
            # Snapshot switched before the crash: the save committed. For a
            # replace, finish dropping the old version's remains.
            if rec["op"] == "replace":
                old_refs = [(int(d), int(v)) for d, v, _c in rec.get("old_refs", [])]
                self._tombstone_unreferenced(old_refs)
                if rec.get("old_page"):
                    self._unlink(self._page_file(rec["old_page"]))
            return
        # Snapshot never switched: undo the physical side effects.
        self._unlink(self._page_file(rec["page"]))
        new_pairs = [(int(d), int(v)) for d, v in rec.get("new_vertices", [])]
        self._tombstone_unreferenced(new_pairs)

    def _recover_save_batch(self, rec: dict) -> None:
        """Replay an interrupted ``save_models``: all-or-nothing.

        The snapshot replace is the single commit point for every model in
        the batch, so checking any one member tells the whole story: if its
        entry is present with the batch's model id the batch committed
        (finish dropping replaced versions' remains), otherwise none of it
        did (undo every page and every vertex the batch created).
        """
        models = rec.get("models", [])
        if not models:
            return
        head = models[0]
        entry = self.catalog.get(head["name"])
        if entry is not None and entry.model_id == head["id"]:
            for m in models:
                if m.get("old_page"):
                    old_refs = [
                        (int(d), int(v)) for d, v, _c in m.get("old_refs", [])
                    ]
                    self._tombstone_unreferenced(old_refs)
                    self._unlink(self._page_file(m["old_page"]))
            return
        for m in models:
            self._unlink(self._page_file(m["page"]))
        new_pairs = [(int(d), int(v)) for d, v in rec.get("new_vertices", [])]
        self._tombstone_unreferenced(new_pairs)

    def _recover_delete(self, rec: dict) -> None:
        entry = self.catalog.get(rec["name"])
        if entry is not None and entry.model_id == rec["id"]:
            return  # intent never committed — the model is untouched
        refs = [(int(d), int(v)) for d, v, _c in rec.get("refs", [])]
        self._tombstone_unreferenced(refs)
        self._unlink(self._page_file(rec["page"]))

    def _recover_vacuum_rollback(self, rec: dict) -> None:
        """No switch record: side files may be half-written, catalog is
        untouched — discard the ``.vac`` index (new-named page side files
        are unreferenced and fall to the orphan sweep)."""
        dim = rec["dim"]
        self._unlink(self.index_cache._path(dim) + ".vac")
        for page_name in rec.get("pages", []):
            # Legacy in-place protocol (pre-concurrency stores) staged
            # page rewrites as ``.vac`` side files under the same name.
            self._unlink(self._page_file(page_name) + ".vac")

    def _recover_vacuum_forward(self, switch: dict) -> None:
        """Switch record present: every side file was durable before it,
        so roll forward — re-point entries at the rewritten pages (a crash
        before the snapshot switch leaves them on the old names), install
        the compacted index, drop the old pages, replace the dim's refs
        wholesale (idempotent)."""
        dim = switch["dim"]
        # An earlier replay step may have loaded the pre-compaction index
        # into the cache (and marked it dirty); drop it so the final flush
        # cannot clobber the compacted file we are about to install.
        self.index_cache.drop(dim)
        vac = self.index_cache._path(dim) + ".vac"
        if os.path.exists(vac):
            self.fs.replace(vac, self.index_cache._path(dim),
                            site="index.replace")
        for name, old_page, new_page in switch.get("moves", []):
            entry = self.catalog.get(name)
            if entry is not None and entry.page == old_page:
                entry.page = new_page
            self._unlink(self._page_file(old_page))
        for page_name in switch.get("pages", []):
            # Legacy in-place protocol: swap the same-name side files in.
            pvac = self._page_file(page_name) + ".vac"
            if os.path.exists(pvac):
                self.fs.replace(pvac, self._page_file(page_name),
                                site="page.replace")
        self.catalog.set_dim_refs(
            dim, {int(v): int(c) for v, c in switch.get("refs", {}).items()}
        )

    # ----------------------------------------------------------- save (Alg 1)
    @staticmethod
    def _iter_group_chunks(positions: list, dim: int):
        """Split one dim group into probe chunks of bounded element count,
        so the (chunk, dim) float64 stack — and every intermediate
        ``_probe_dim_group`` builds from it — stays ~PROBE_CHUNK_ELEMS
        regardless of how many tensors share the dim."""
        step = max(1, PROBE_CHUNK_ELEMS // max(dim, 1))
        for i in range(0, len(positions), step):
            yield positions[i:i + step]

    def _probe_dim_group(
        self, index: HNSWIndex, flats: np.ndarray, tau_: float
    ) -> tuple[list[tuple[int, np.ndarray]], list[int], list[dict]]:
        """Batched Algorithm 1 lines 2–3 for one dim group (engine lock held).

        ``flats`` is the (G, dim) float64 block of every tensor in the
        group. Instead of G independent HNSW descents, one
        ``nearest_live_batch`` distance block (through the kernel dispatch
        seam) finds each tensor's closest live base; tensors whose delta
        range beats tau are quantized in **one** ``quantize_linear_batch``
        sweep (the per-group hoist — bit-exact with per-tensor
        ``quantize_linear``, see tests), checked against earlier in-group
        bases so intra-save dedup matches the sequential path (a tensor
        similar to a base created moments earlier in the same save becomes
        a delta, not a second base), and inserted via ``insert_batch``.

        Returns ``(bases, new_vids, explains)``: ``bases[j] =
        (vertex_id, delta)`` in group order, ``new_vids`` the vertex ids
        created, and ``explains[j]`` the per-tensor EXPLAIN skeleton —
        ``{"vertex_id", "outcome", "probe_distance", "delta_range"}`` —
        that the quantize phase completes. Callers bound ``flats`` to
        ``PROBE_CHUNK_ELEMS`` (see ``_iter_group_chunks``); the
        intermediates here are all O(chunk).
        """
        g = flats.shape[0]
        bases: list = [None] * g
        explains: list = [None] * g
        best_vid = np.full(g, -1, dtype=np.int64)
        best_dist = np.full(g, np.inf)
        if len(index):
            # Small index or fat group: one exact (G, N) distance block
            # beats G graph descents. Large index with a thin group: keep
            # the O(polylog N) HNSW descent per tensor — a brute-force
            # scan there would make save latency grow linearly with the
            # store.
            if (
                len(index) <= BRUTE_PROBE_MAX_INDEX
                or g * BRUTE_PROBE_GROUP_FACTOR >= len(index)
            ):
                best_vid, best_dist = index.nearest_live_batch(flats)
            else:
                for j in range(g):
                    hit = index.search(flats[j], k=1, ef=self.ef_search)
                    if hit:
                        best_dist[j], best_vid[j] = hit[0]
        deq_cache: dict[int, np.ndarray] = {}
        cand_pos: list[int] = []
        for j in range(g):
            vid = int(best_vid[j])
            dist = (
                float(best_dist[j])
                if vid >= 0 and np.isfinite(best_dist[j]) else None
            )
            if vid >= 0:
                base = deq_cache.get(vid)
                if base is None:
                    base = deq_cache[vid] = index.dequantize_vertex(vid)
                delta = flats[j] - base
                rng = float(delta.max() - delta.min())
                # SHOULDCOMPRESS: delta range vs tau (§4.2).
                if rng <= tau_:
                    bases[j] = (vid, delta)
                    explains[j] = {
                        "vertex_id": vid, "outcome": "delta",
                        "probe_distance": dist, "delta_range": rng,
                    }
                    continue
            cand_pos.append(j)
            explains[j] = {"probe_distance": dist}  # completed below
        if not cand_pos:
            return bases, [], explains
        cand = flats[cand_pos]
        qc, qs, qz, qm = quantize_linear_batch(cand, nbit=8)
        deq = dequantize_linear_batch(qc, qs, qz, qm)
        accepted: list[int] = []  # local candidate indices → new bases
        batch_refs: list[int] = []  # group positions resolved after insert
        acc_mat = np.empty_like(cand)  # dequantized accepted bases, in order
        for local_j, j in enumerate(cand_pos):
            flat = flats[j]
            if accepted:
                diff = acc_mat[: len(accepted)] - flat
                k = int(np.argmin(np.einsum("ad,ad->a", diff, diff)))
                delta = flat - acc_mat[k]
                rng = float(delta.max() - delta.min())
                if rng <= tau_:
                    bases[j] = (k, delta)  # k resolved to a vid below
                    batch_refs.append(j)
                    explains[j].update(
                        outcome="intra_save_dedup", delta_range=rng)
                    continue
            acc_mat[len(accepted)] = deq[local_j]
            delta = flats[j] - deq[local_j]
            bases[j] = (len(accepted), delta)
            batch_refs.append(j)
            accepted.append(local_j)
            explains[j].update(
                outcome="new_base",
                delta_range=float(delta.max() - delta.min()),
            )
        sel = np.asarray(accepted, dtype=np.int64)
        vids = index.insert_batch(
            cand[sel], quantized=(qc[sel], qs[sel], qz[sel], qm[sel])
        )
        for j in batch_refs:
            k, delta = bases[j]
            bases[j] = (vids[k], delta)
            explains[j]["vertex_id"] = int(vids[k])
        return bases, vids, explains

    def _account_committed_save(
        self, name: str, model_id: int, page_name: str, page_bytes: int,
        logical_bytes: int, tensors: tuple, explain: list,
    ) -> None:
        """Post-commit bookkeeping for one saved model: push the space
        facts into the ledger (replace-by-name covers ``replace_model``),
        persist the EXPLAIN sidecar, and publish the dedup-outcome /
        bit-width metric families."""
        if self.accounting:
            self._accountant.record_save(ModelSpace(
                name=name,
                page=page_name,
                page_bytes=page_bytes,
                logical_bytes=logical_bytes,
                tensors=tensors,
            ))
            self._pending_explains[model_id] = explain[:EXPLAIN_PERSIST_MAX]
            if len(self._pending_explains) >= EXPLAIN_FLUSH_MAX:
                self.flush_explains()
        for ex in explain:
            _M_DEDUP_OUTCOMES.labels(ex["outcome"]).inc()
            _M_DELTA_BITS.observe(ex["nbit"])

    def save_model(
        self,
        name: str,
        architecture: dict,
        tensors: "OrderedDict[str, np.ndarray] | dict[str, np.ndarray]",
        tolerance: float | None = None,
        tau: float | None = None,
    ) -> SaveReport:
        """Algorithm 1: delta-quantize ``tensors`` and persist one page.

        ``tensors`` is name → float array, iterated in architecture order so
        records land in page order matching the computation graph (paper
        §4.1 "delta tensors are organized in the order defined by the model
        architecture").

        The index work is grouped by flattened dim (one cache fetch per
        index) and runs under the engine lock; the CPU-heavy delta
        quantization + planar bit-packing run after the lock is released.
        Page records keep the original tensor order regardless of grouping.

        Saving under an existing name is a **replace**: the new version is
        written first, then the old page and its vertex references are
        dropped, all under one journal transaction.
        """
        with trace("engine.save", model=name) as op:
            report = self._save_model_impl(
                name, architecture, tensors, tolerance, tau, op
            )
        _M_OPS.labels("save").inc()
        _M_OP_SECONDS.labels("save").observe(op.elapsed())
        return report

    def _save_model_impl(
        self,
        name: str,
        architecture: dict,
        tensors,
        tolerance: float | None,
        tau: float | None,
        op,
    ) -> SaveReport:
        # `op` is the open engine.save span: SaveReport.seconds is derived
        # from it, so wall time in the report and the trace cannot differ.
        self._check_writable()
        self._drain_released()
        p = self.tolerance if tolerance is None else tolerance
        tau_ = self.tau if tau is None else tau
        # Grouping needs only names/shapes — no float64 upcast is made here.
        items: list[tuple[str, tuple[int, ...], object]] = []
        by_dim: "OrderedDict[int, list[int]]" = OrderedDict()
        original_bytes = 0
        for tname, tensor in tensors.items():
            src = np.asarray(tensor)
            original_bytes += src.size * 4  # stored models are float32
            by_dim.setdefault(src.size, []).append(len(items))
            items.append((tname, tuple(int(s) for s in src.shape), src))

        # Phase 1 (locked): per-dim batched ANN probe / batch vertex insert
        # (Alg. 1 l.2-3 through `_probe_dim_group`): one distance block +
        # one quantization sweep + one `insert_batch` per dim instead of
        # per-tensor graph probes. Dims are pinned so a concurrent load's
        # cache fetch cannot evict an index this save is mutating. The
        # float64 upcast now lives per *group* (the batch paths need the
        # (G, dim) block), released as each group resolves.
        bases: list[tuple[int, np.ndarray] | None] = [None] * len(items)
        probe_ex: list[dict | None] = [None] * len(items)
        refs: Counter = Counter()
        new_vertices: list[tuple[int, int]] = []
        n_new = 0
        try:
            for dim in by_dim:
                self.index_cache.pin(dim)
            try:
                with trace("probe", n_dims=len(by_dim)), self._lock:
                    for dim, positions in by_dim.items():
                        self._check_quarantine(dim)
                        index = self.index_cache.get(dim, create=True)
                        for chunk in self._iter_group_chunks(positions, dim):
                            flats = np.stack([
                                np.asarray(items[pos][2],
                                           dtype=np.float64).ravel()
                                for pos in chunk
                            ])
                            group_bases, group_new, group_ex = (
                                self._probe_dim_group(index, flats, tau_)
                            )
                            if group_new:
                                self.index_cache.mark_dirty(dim)
                                new_vertices.extend(
                                    (dim, v) for v in group_new
                                )
                                n_new += len(group_new)
                            for gj, pos in enumerate(chunk):
                                vid, delta = group_bases[gj]
                                bases[pos] = (vid, delta)
                                probe_ex[pos] = group_ex[gj]
                                refs[(dim, vid)] += 1
                                # Hold the ref until commit so a concurrent
                                # delete cannot tombstone this base under
                                # the page.
                                self._inflight[(dim, vid)] += 1
            finally:
                for dim in by_dim:
                    self.index_cache.unpin(dim)

            # Phase 2 (unlocked): adaptive n-bit quantization of each delta
            # (Eq. 2/3) + planar bit-packing + page assembly, in tensor
            # order. Deltas are released as they are consumed.
            records: list[TensorRecord] = []
            nbits: list[int] = []
            explain: list[dict] = []
            with trace("quantize", n_tensors=len(items)):
                for i, (tname, shape, src) in enumerate(items):
                    vid, delta = bases[i]
                    bases[i] = None
                    qd, meta = quantize_delta(delta, p)
                    nbits.append(meta.nbit)
                    rec = TensorRecord(
                        name=tname,
                        shape=shape,
                        dim_key=src.size,
                        vertex_id=vid,
                        meta=meta,
                        qdelta=qd,
                    )
                    rec.payload = encode_payload(rec)
                    records.append(rec)
                    ex = probe_ex[i]
                    explain.append({
                        "tensor": tname,
                        "dim": int(src.size),
                        "vertex_id": int(ex["vertex_id"]),
                        "outcome": ex["outcome"],
                        "probe_distance": ex["probe_distance"],
                        "delta_range": ex["delta_range"],
                        "tau": float(tau_),
                        "nbit": int(meta.nbit),
                        "delta_bytes": len(rec.payload),
                        "error_bound": float(p),
                    })
            with trace("pack"):
                page = write_page(records, checksums=self.checksums)

            # Phase 3 (locked): the journaled commit. Intent → index flush
            # (vertices durable before the page references them) → page
            # write → atomic catalog snapshot (commit point) → old-version
            # cleanup → journal commit. The span opens before the lock so
            # lock-wait time is attributed to the commit.
            with trace("commit"), self._lock:
                old = self.catalog.get(name)
                old_refs = self._page_refs(old.page) if old else Counter()
                if self.commit_gate is not None:
                    self.commit_gate([{
                        "name": name,
                        "page_bytes": len(page),
                        "old_page_bytes": self._page_size(old),
                    }])
                model_id = self.catalog.allocate_id()
                page_name = f"model_{model_id}.page"
                intent = {
                    "op": "replace" if old else "save",
                    "name": name,
                    "id": model_id,
                    "page": page_name,
                    "new_vertices": [[d, v] for d, v in new_vertices],
                }
                if old:
                    intent["old_id"] = old.model_id
                    intent["old_page"] = old.page
                    intent["old_refs"] = [
                        [d, v, c] for (d, v), c in old_refs.items()
                    ]
                with trace("journal"):
                    tx = self.catalog.begin(intent)
                maybe_fail("save.after_intent")
                self.index_cache.flush()
                maybe_fail("save.after_index_flush")
                self.fs.write_durable(
                    self._page_file(page_name), page, site="page.write"
                )
                maybe_fail("save.after_page_write")
                entry = ModelEntry(
                    model_id=model_id,
                    name=name,
                    architecture=architecture,
                    page=page_name,
                    n_tensors=len(records),
                    original_bytes=original_bytes,
                    status=STATUS_PENDING,
                    explain=(explain[:EXPLAIN_PERSIST_MAX]
                             if self.accounting else None),
                )
                self.catalog.state.models[name] = entry
                for (dim, vid), c in refs.items():
                    self.catalog.ref(dim, vid, c)
                if old:
                    for (dim, vid), c in old_refs.items():
                        self.catalog.ref(dim, vid, -c)
                entry.status = STATUS_COMMITTED
                self.catalog.save_snapshot()  # ← commit point
                self._account_committed_save(
                    name, model_id, page_name, len(page), original_bytes,
                    tuple(
                        TensorSpace(rec.dim_key, rec.vertex_id, rec.numel,
                                    len(rec.payload))
                        for rec in records
                    ),
                    explain,
                )
                maybe_fail("save.after_snapshot")
                if old:
                    self._tombstone_unreferenced(old_refs)
                    self.index_cache.flush()
                    self._unlink(self._page_file(old.page))
                    self._pending_explains.pop(old.model_id, None)
                    self._unlink(self._explain_file(old.model_id))
                    self.page_pool.invalidate(old.page)
                self.catalog.commit_tx(tx)
                self.index_cache.trim()
        finally:
            with self._lock:
                for pair, c in refs.items():
                    left = self._inflight[pair] - c
                    if left > 0:
                        self._inflight[pair] = left
                    else:
                        del self._inflight[pair]
        return SaveReport(
            model_id=model_id,
            name=name,
            original_bytes=original_bytes,
            page_bytes=len(page),
            n_tensors=len(records),
            n_new_bases=n_new,
            n_deltas=len(records) - n_new,
            nbits=nbits,
            seconds=op.elapsed(),
            explain=explain,
        )

    def save_models(
        self,
        models,
        tolerance: float | None = None,
        tau: float | None = None,
    ) -> list[SaveReport]:
        """Save several models under ONE catalog transaction (batch ingest).

        ``models`` is an iterable of ``(name, architecture, tensors)``
        triples. Tensor groups are formed **across the whole batch** per
        flattened dim, so a checkpoint sweep pays one index fetch, one
        batched probe and one ``insert_batch`` per dim for all models
        together (fine-tunes later in the batch dedup against bases the
        batch itself just created), and the commit protocol runs once:
        one journal intent, one index flush, one atomic ``meta.json``
        replace for every model — amortizing the fsyncs that dominate
        small-model save latency.

        All-or-nothing: a crash at any point replays to either every model
        committed or none (op ``save_batch`` in the journal; failpoints
        ``save_batch.after_intent`` / ``after_index_flush`` /
        ``after_page_write`` / ``after_snapshot``). Saving over an existing
        name is a replace, exactly as in :meth:`save_model`.

        Returns one :class:`SaveReport` per model, in input order, with the
        batch wall time amortized evenly over the ``seconds`` fields.
        """
        with trace("engine.save_batch") as op:
            reports = self._save_models_impl(models, tolerance, tau, op)
        _M_OPS.labels("save_batch").inc()
        _M_OP_SECONDS.labels("save_batch").observe(op.elapsed())
        return reports

    def _save_models_impl(self, models, tolerance, tau, op) -> list[SaveReport]:
        self._check_writable()
        p = self.tolerance if tolerance is None else tolerance
        tau_ = self.tau if tau is None else tau
        specs = [(str(n), a, t) for n, a, t in models]
        names = [n for n, _, _ in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names in batch: {names}")
        if not specs:
            return []

        # Flatten: per-model item lists + one cross-model dim grouping.
        all_items: list[list[tuple[str, tuple[int, ...], object]]] = []
        original_bytes: list[int] = []
        by_dim: "OrderedDict[int, list[tuple[int, int]]]" = OrderedDict()
        for mi, (_name, _arch, tensors) in enumerate(specs):
            items: list[tuple[str, tuple[int, ...], object]] = []
            nbytes = 0
            for tname, tensor in tensors.items():
                src = np.asarray(tensor)
                nbytes += src.size * 4  # stored models are float32
                by_dim.setdefault(src.size, []).append((mi, len(items)))
                items.append((tname, tuple(int(s) for s in src.shape), src))
            all_items.append(items)
            original_bytes.append(nbytes)

        # Phase 1 (locked): one batched probe + insert per dim for the
        # whole batch — the cross-model half of the ingest amortization.
        bases: list[list] = [[None] * len(items) for items in all_items]
        probe_ex: list[list] = [[None] * len(items) for items in all_items]
        refs: Counter = Counter()
        new_vertices: list[tuple[int, int]] = []
        n_new_per_model = [0] * len(specs)
        try:
            for dim in by_dim:
                self.index_cache.pin(dim)
            try:
                with trace("probe", n_dims=len(by_dim)), self._lock:
                    for dim, positions in by_dim.items():
                        self._check_quarantine(dim)
                        index = self.index_cache.get(dim, create=True)
                        for chunk in self._iter_group_chunks(positions, dim):
                            flats = np.stack([
                                np.asarray(
                                    all_items[mi][pos][2], dtype=np.float64
                                ).ravel()
                                for mi, pos in chunk
                            ])
                            group_bases, group_new, group_ex = (
                                self._probe_dim_group(index, flats, tau_)
                            )
                            if group_new:
                                self.index_cache.mark_dirty(dim)
                                new_vertices.extend(
                                    (dim, v) for v in group_new
                                )
                            group_new_set = set(group_new)
                            for gj, (mi, pos) in enumerate(chunk):
                                vid, delta = group_bases[gj]
                                bases[mi][pos] = (vid, delta)
                                probe_ex[mi][pos] = group_ex[gj]
                                refs[(dim, vid)] += 1
                                self._inflight[(dim, vid)] += 1
                                if vid in group_new_set:
                                    group_new_set.discard(vid)
                                    n_new_per_model[mi] += 1
            finally:
                for dim in by_dim:
                    self.index_cache.unpin(dim)

            # Phase 2 (unlocked): encode every model's page.
            pages: list[bytes] = []
            nbits_per_model: list[list[int]] = []
            explain_per_model: list[list[dict]] = []
            spaces_per_model: list[tuple] = []
            with trace("quantize", n_models=len(all_items)):
                for mi, items in enumerate(all_items):
                    records: list[TensorRecord] = []
                    nbits: list[int] = []
                    explain: list[dict] = []
                    for i, (tname, shape, src) in enumerate(items):
                        vid, delta = bases[mi][i]
                        bases[mi][i] = (vid, None)  # release the delta
                        qd, meta = quantize_delta(delta, p)
                        nbits.append(meta.nbit)
                        rec = TensorRecord(
                            name=tname,
                            shape=shape,
                            dim_key=src.size,
                            vertex_id=vid,
                            meta=meta,
                            qdelta=qd,
                        )
                        rec.payload = encode_payload(rec)
                        records.append(rec)
                        ex = probe_ex[mi][i]
                        explain.append({
                            "tensor": tname,
                            "dim": int(src.size),
                            "vertex_id": int(ex["vertex_id"]),
                            "outcome": ex["outcome"],
                            "probe_distance": ex["probe_distance"],
                            "delta_range": ex["delta_range"],
                            "tau": float(tau_),
                            "nbit": int(meta.nbit),
                            "delta_bytes": len(rec.payload),
                            "error_bound": float(p),
                        })
                    with trace("pack"):
                        pages.append(
                            write_page(records, checksums=self.checksums)
                        )
                    nbits_per_model.append(nbits)
                    explain_per_model.append(explain)
                    spaces_per_model.append(tuple(
                        TensorSpace(rec.dim_key, rec.vertex_id, rec.numel,
                                    len(rec.payload))
                        for rec in records
                    ))

            # Phase 3 (locked): ONE journaled commit for the whole batch.
            with trace("commit"), self._lock:
                olds = [self.catalog.get(n) for n in names]
                old_refs = [
                    self._page_refs(o.page) if o else Counter() for o in olds
                ]
                if self.commit_gate is not None:
                    self.commit_gate([
                        {
                            "name": names[mi],
                            "page_bytes": len(pages[mi]),
                            "old_page_bytes": self._page_size(olds[mi]),
                        }
                        for mi in range(len(specs))
                    ])
                model_ids = [self.catalog.allocate_id() for _ in specs]
                page_names = [f"model_{mid}.page" for mid in model_ids]
                intent_models = []
                for mi, (name, _arch, _t) in enumerate(specs):
                    m: dict = {
                        "name": name,
                        "id": model_ids[mi],
                        "page": page_names[mi],
                    }
                    if olds[mi]:
                        m["old_id"] = olds[mi].model_id
                        m["old_page"] = olds[mi].page
                        m["old_refs"] = [
                            [d, v, c] for (d, v), c in old_refs[mi].items()
                        ]
                    intent_models.append(m)
                with trace("journal"):
                    tx = self.catalog.begin({
                        "op": "save_batch",
                        "models": intent_models,
                        "new_vertices": [[d, v] for d, v in new_vertices],
                    })
                maybe_fail("save_batch.after_intent")
                self.index_cache.flush()
                maybe_fail("save_batch.after_index_flush")
                for mi in range(len(specs)):
                    self.fs.write_durable(
                        self._page_file(page_names[mi]), pages[mi],
                        site="page.write",
                    )
                maybe_fail("save_batch.after_page_write")
                for mi, (name, arch, _t) in enumerate(specs):
                    self.catalog.state.models[name] = ModelEntry(
                        model_id=model_ids[mi],
                        name=name,
                        architecture=arch,
                        page=page_names[mi],
                        n_tensors=len(all_items[mi]),
                        original_bytes=original_bytes[mi],
                        status=STATUS_COMMITTED,
                        explain=(
                            explain_per_model[mi][:EXPLAIN_PERSIST_MAX]
                            if self.accounting else None
                        ),
                    )
                for (dim, vid), c in refs.items():
                    self.catalog.ref(dim, vid, c)
                for mi in range(len(specs)):
                    for (dim, vid), c in old_refs[mi].items():
                        self.catalog.ref(dim, vid, -c)
                self.catalog.save_snapshot()  # ← commit point for ALL models
                for mi in range(len(specs)):
                    self._account_committed_save(
                        names[mi], model_ids[mi], page_names[mi],
                        len(pages[mi]), original_bytes[mi],
                        spaces_per_model[mi], explain_per_model[mi],
                    )
                maybe_fail("save_batch.after_snapshot")
                dropped_old = False
                for mi in range(len(specs)):
                    if olds[mi]:
                        self._tombstone_unreferenced(old_refs[mi])
                        self._unlink(self._page_file(olds[mi].page))
                        self._pending_explains.pop(olds[mi].model_id, None)
                        self._unlink(self._explain_file(olds[mi].model_id))
                        self.page_pool.invalidate(olds[mi].page)
                        dropped_old = True
                if dropped_old:
                    self.index_cache.flush()
                self.catalog.commit_tx(tx)
                self.index_cache.trim()
        finally:
            with self._lock:
                for pair, c in refs.items():
                    left = self._inflight[pair] - c
                    if left > 0:
                        self._inflight[pair] = left
                    else:
                        del self._inflight[pair]
        per_model_s = op.elapsed() / len(specs)
        return [
            SaveReport(
                model_id=model_ids[mi],
                name=names[mi],
                original_bytes=original_bytes[mi],
                page_bytes=len(pages[mi]),
                n_tensors=len(all_items[mi]),
                n_new_bases=n_new_per_model[mi],
                n_deltas=len(all_items[mi]) - n_new_per_model[mi],
                nbits=nbits_per_model[mi],
                seconds=per_model_s,
                explain=explain_per_model[mi],
            )
            for mi in range(len(specs))
        ]

    # -------------------------------------------------------------- lifecycle
    def delete_model(self, name: str) -> None:
        """Drop a model: journal intent → catalog commit → tombstone
        zero-ref vertices → unlink page. Crash-safe at every step.

        Quarantined (corrupt) models can be deleted too — that is the
        repair path for unrecoverable damage; their reference counts come
        from whatever records still verify (see :meth:`_page_refs`)."""
        self._check_writable()
        self._drain_released()
        with trace("engine.delete", model=name) as op, self._lock:
            entry = self.catalog.get(name)
            if entry is None or entry.status not in (
                STATUS_COMMITTED, STATUS_CORRUPT
            ):
                raise KeyError(name)
            refs = self._page_refs(entry.page)
            for dim, _vid in refs:
                self._check_quarantine(dim)
            tx = self.catalog.begin({
                "op": "delete",
                "name": name,
                "id": entry.model_id,
                "page": entry.page,
                "refs": [[d, v, c] for (d, v), c in refs.items()],
            })
            maybe_fail("delete.after_intent")
            del self.catalog.state.models[name]
            for (dim, vid), c in refs.items():
                self.catalog.ref(dim, vid, -c)
            self.catalog.save_snapshot()  # ← commit point
            if self.accounting:
                self._accountant.record_delete(name)
            maybe_fail("delete.after_snapshot")
            self._tombstone_unreferenced(refs)
            self.index_cache.flush()
            maybe_fail("delete.after_index_flush")
            self._unlink(self._page_file(entry.page))
            self._pending_explains.pop(entry.model_id, None)
            self._unlink(self._explain_file(entry.model_id))
            self.page_pool.invalidate(entry.page)
            self._corrupt_reasons.pop(name, None)
            self.catalog.commit_tx(tx)
        _M_OPS.labels("delete").inc()
        _M_OP_SECONDS.labels("delete").observe(op.elapsed())

    def replace_model(
        self,
        name: str,
        architecture: dict,
        tensors: "OrderedDict[str, np.ndarray] | dict[str, np.ndarray]",
        tolerance: float | None = None,
        tau: float | None = None,
    ) -> SaveReport:
        """Save a new version of an existing model and drop the old one
        under a single journal transaction (save-new-then-drop-old)."""
        # Hold the (reentrant) lock across the save so a concurrent delete
        # cannot void the existence check and silently turn the replace
        # into a fresh save.
        with trace("engine.replace", model=name) as op, self._lock:
            if self.catalog.get(name) is None:
                raise KeyError(name)
            report = self.save_model(name, architecture, tensors, tolerance, tau)
        _M_OPS.labels("replace").inc()
        _M_OP_SECONDS.labels("replace").observe(op.elapsed())
        return report

    def vacuum(self, min_dead_fraction: float = 0.0, dims=None) -> dict:
        """Compact indexes whose dead-vertex fraction is ≥ the threshold.

        Copy-on-write per dim: sweep (any vertex with zero catalog
        references becomes a tombstone) → journal intent → compact a
        **clone** of the index (the resident object, shared with snapshot
        readers, is never restructured) → write the compacted index as a
        ``.vac`` side file and every remapped page under a **new page
        name** → journal the switch record (page moves + the full
        post-remap reference table) → switch the catalog (entries point at
        the new pages; refs replaced; atomic snapshot = commit point) →
        install the index file and clone, unlink the old pages → commit.
        Mid-vacuum crashes roll forward from the switch record (all side
        files are durable before it) or roll back by discarding side
        files. Every surviving model materializes bit-identically before
        vs. after (vertex codes are copied verbatim and page payloads are
        untouched), and readers that loaded *before* the vacuum keep
        materializing from their pinned snapshot — old index object, old
        page bytes — also bit-identically.

        Returns a report: per-dim dropped/live counts, pages rewritten,
        and dims skipped because an in-flight save holds references.
        """
        self._check_writable()
        self._drain_released()
        self.flush_explains()
        report: dict = {
            "dims": {},
            "skipped_dims": [],
            "vertices_dropped": 0,
            "pages_rewritten": 0,
        }
        with trace("engine.vacuum") as op, self._lock:
            corrupt = self.catalog.corrupt_names()
            if corrupt:
                # Compaction renumbers vertex ids and rewrites page refs;
                # a quarantined page cannot be remapped, so a vacuum now
                # would strand it pointing at pre-compaction ids forever.
                # Repair or drop the quarantined models first.
                report["skipped_reason"] = (
                    f"{len(corrupt)} quarantined model(s) pin vertex ids: "
                    f"{sorted(corrupt)}"
                )
                return report
            # Lazy, one scan per page for the whole vacuum: which dims each
            # page references never changes (rewrites only renumber
            # vertices, renames are tracked below). Built only when some
            # dim actually passes the dead-fraction threshold, so the
            # maintenance daemon's steady-state no-op steps never pay a
            # store-wide page header sweep under the engine lock.
            dims_by_page_cache: list[dict[str, set[int]]] = []

            def dims_by_page() -> dict[str, set[int]]:
                # STRICT scan: a page this planner cannot read must abort
                # the vacuum. Treating it as reference-free would skip its
                # remap during renumbering and strand live records on
                # stale vertex ids — the unsafe direction.
                if not dims_by_page_cache:
                    by_page: dict[str, set[int]] = {}
                    for entry in (self.catalog.get(n)
                                  for n in self.catalog.names()):
                        try:
                            by_page[entry.page] = {
                                d for d, _ in self._page_refs(
                                    entry.page, strict=True)
                            }
                        except CorruptPageError as exc:
                            self._quarantine_model(
                                entry.name, entry.page,
                                f"vacuum scan: {exc}", persist=False,
                            )
                            raise
                    dims_by_page_cache.append(by_page)
                return dims_by_page_cache[0]

            for dim in (dims if dims is not None else self.index_cache.dims()):
                if (
                    dim in self._quarantined_dims
                    or any(pair[0] == dim for pair in self._inflight)
                ):
                    report["skipped_dims"].append(dim)
                    continue
                idx = self.index_cache.get(dim)
                if idx is None or len(idx) == 0:
                    continue
                self.index_cache.pin(dim)
                try:
                    self._vacuum_dim(dim, idx, min_dead_fraction, report,
                                     dims_by_page)
                except BaseException:
                    # The on-disk state may be half-switched and the journal
                    # still holds the recovery records: drop the resident
                    # object and quarantine the dim until a reopen replays.
                    self.index_cache.drop(dim)
                    self._quarantined_dims.add(dim)
                    raise
                finally:
                    self.index_cache.unpin(dim)
            self.index_cache.flush()
            self.index_cache.trim()
            if self.accounting and report["dims"]:
                # Compaction renumbered vertex ids and renamed pages:
                # the incremental ledger's facts are stale — reseed it
                # from the post-vacuum store (the same full rescan that
                # runs at open).
                self._accountant.reset(self._scan_model_spaces())
        _M_OPS.labels("vacuum").inc()
        _M_OP_SECONDS.labels("vacuum").observe(op.elapsed())
        return report

    def _vacuum_dim(
        self,
        dim: int,
        idx: HNSWIndex,
        min_dead_fraction: float,
        report: dict,
        page_map,
    ) -> None:
        """``page_map`` is a lazy callable → {page_name: dims referenced};
        only invoked past the threshold check so no-op sweeps stay cheap."""
        refs = self.catalog.refs_for_dim(dim)
        # Sweep: liveness is defined by the reference table, so orphan
        # vertices from crashed saves are collected here too.
        for vid in range(len(idx)):
            if refs.get(vid, 0) <= 0 and not idx.is_deleted(vid):
                idx.mark_deleted(vid)
                self.index_cache.mark_dirty(dim)
        dead = idx.dead_count
        if dead == 0 or idx.dead_fraction() < min_dead_fraction:
            return
        dims_by_page = page_map()
        affected = [
            entry
            for entry in (
                self.catalog.get(n) for n in self.catalog.names()
            )
            if dim in dims_by_page.get(entry.page, ())
        ]
        tx = self.catalog.begin({
            "op": "vacuum",
            "dim": dim,
            "pages": [e.page for e in affected],
        })
        maybe_fail("vacuum.after_intent")
        # Copy-on-write: compact a clone. The resident object — shared
        # with every snapshot captured before this point — keeps its rows
        # and numbering, so concurrent readers stay lock-free and valid.
        new_idx = idx.clone()
        remap = new_idx.compact()
        self.fs.write_durable(
            self.index_cache._path(dim) + ".vac",
            frame_index(new_idx.to_bytes()),
            site="index.vac",
        )
        moves: list[tuple[ModelEntry, str, str]] = []
        for entry in affected:
            buf = self.fs.read_bytes(
                self._page_file(entry.page), site="page.vacuum"
            )
            if self.checksums:
                try:
                    verify_page(buf)
                except CorruptPageError as exc:
                    # Never remap a damaged page: quarantine the model and
                    # abort this dim (rolled back at the next reopen).
                    self._quarantine_model(
                        entry.name, entry.page, f"vacuum: {exc}",
                        persist=False,
                    )
                    raise
            new_buf, changed = remap_page_vertices(buf, remap, dim)
            if changed:
                # Generation ids come from the catalog's monotonic counter,
                # but a pre-commit crash loses the allocation — skip any id
                # whose page name already exists (e.g. our own current name
                # after a replayed vacuum) so old and new never collide.
                new_page = entry.page
                while (new_page == entry.page
                       or os.path.exists(self._page_file(new_page))):
                    new_page = (
                        f"model_{entry.model_id}"
                        f".g{self.catalog.allocate_id()}.page"
                    )
                self.fs.write_durable(
                    self._page_file(new_page), new_buf, site="page.write"
                )
                moves.append((entry, entry.page, new_page))
        maybe_fail("vacuum.after_sidefiles")
        new_refs = {str(remap[v]): c for v, c in refs.items() if c > 0}
        self.catalog.log(tx, {
            "op": "vacuum_switch",
            "dim": dim,
            "moves": [[e.name, old, new] for e, old, new in moves],
            "refs": new_refs,
        })
        maybe_fail("vacuum.after_switch_log")
        # Catalog switch: entries point at the rewritten pages, the dim's
        # reference table is renumbered, and the atomic snapshot commits
        # both (bumping the reader-visible epoch).
        for entry, old_page, new_page in moves:
            entry.page = new_page
            if old_page in dims_by_page:
                dims_by_page[new_page] = dims_by_page.pop(old_page)
        self.catalog.set_dim_refs(dim, {int(v): c for v, c in new_refs.items()})
        self.catalog.save_snapshot()  # ← commit point
        maybe_fail("vacuum.mid_switch")
        self.fs.replace(self.index_cache._path(dim) + ".vac",
                        self.index_cache._path(dim), site="index.replace")
        for _entry, old_page, _new_page in moves:
            self._unlink(self._page_file(old_page))
            self.page_pool.invalidate(old_page)
        self.catalog.commit_tx(tx)
        # Future loads see the compacted clone; snapshots keep the old one.
        self.index_cache.replace(dim, new_idx)
        report["dims"][dim] = {
            "dropped": dead,
            "live": len(new_idx),
            "pages_rewritten": len(moves),
        }
        report["vertices_dropped"] += dead
        report["pages_rewritten"] += len(moves)

    # ------------------------------------------------------------------ load
    def _read_page_bytes(self, page_name: str) -> bytes:
        """Read + verify page bytes — the buffer pool's frame loader.

        Verification happens here, at frame *admission*: every reader of a
        cached frame shares one CRC pass instead of re-verifying per load.
        """
        with trace("page.io", page=page_name):
            data = self.fs.read_bytes(
                self._page_file(page_name), site="page.read"
            )
            if self.checksums:
                verify_page(data)
        _M_PAGE_READS.inc()
        _M_PAGE_READ_BYTES.inc(len(data))
        return data

    def _quarantine_model(
        self, name: str, page_name: str, reason: str, persist: bool = True
    ) -> bool:
        """Mark a model corrupt; the store keeps serving healthy models.

        Re-validates that the entry still points at ``page_name`` — a
        racing replace/vacuum may have swapped the page, in which case the
        damage belongs to a dead file, not the live model. The quarantine
        is persisted through a catalog snapshot unless the store is
        read-only (degraded mode never mutates disk).
        """
        with self._lock:
            entry = self.catalog.get(name)
            if (
                entry is None
                or entry.page != page_name
                or entry.status == STATUS_CORRUPT
            ):
                return False
            entry.status = STATUS_CORRUPT
            self._corrupt_reasons[name] = reason
            self.page_pool.invalidate(page_name)
            if self.accounting:
                # A quarantined model is no longer servable (and the
                # rescan skips it), so it leaves the space ledger too.
                self._accountant.record_delete(name)
            _M_QUARANTINES.inc()
            if persist and not self.read_only:
                try:
                    self.catalog.save_snapshot()
                except OSError:
                    pass  # quarantine still holds in memory; next commit persists
            return True

    def _corrupt_error(self, name: str) -> CorruptPageError:
        reason = self._corrupt_reasons.get(name, "failed an integrity check")
        return CorruptPageError(f"model {name!r} is quarantined: {reason}")

    def _parse_frame(self, frame) -> TensorPage:
        """Parsed-header cache on the frame (shared across handles)."""
        page = frame.page
        if page is None:
            with frame.lock:
                page = frame.page
                if page is None:
                    page = frame.page = read_page_header(frame.data)
        return page

    def _drain_released(self) -> None:
        """Apply queued snapshot releases (GC finalizers only enqueue —
        they must not take locks from inside garbage collection)."""
        while True:
            try:
                token, frame = self._released.popleft()
            except IndexError:
                return
            with self._lock:
                self._live_snapshots.pop(token, None)
            if frame is not None:
                self.page_pool.unpin(frame)

    def open_page(self, name: str) -> tuple[TensorPage, ModelEntry]:
        with self._lock:
            entry = self.catalog.get(name)
            if entry is None or entry.status != STATUS_COMMITTED:
                if entry is not None and entry.status == STATUS_CORRUPT:
                    raise self._corrupt_error(name)
                raise KeyError(name)
            page_name = entry.page
        try:
            frame = self.page_pool.get(
                page_name, lambda: self._read_page_bytes(page_name)
            )
        except CorruptPageError as exc:
            self._quarantine_model(name, page_name, str(exc))
            raise
        try:
            page = self._parse_frame(frame)
        except CorruptPageError as exc:
            self._quarantine_model(name, page_name, str(exc))
            raise
        finally:
            self.page_pool.unpin(frame)
        return page, entry

    def load_model(self, name: str, bits: int | None = None, *,
                   shared_cache: bool = True):
        """Compression-aware load — see :mod:`repro.core.loader`.

        Returns a :class:`~repro.core.loader.LoadedModel` backed by an
        epoch-stamped :class:`~repro.core.loader.ModelSnapshot`: after the
        short capture critical section the handle never takes the engine
        lock again, so concurrent writers (save/delete/replace/vacuum)
        cannot stall — or invalidate — this reader. ``shared_cache=False``
        bypasses the buffer pool (private page bytes and decoded payloads
        — the pre-concurrency behaviour; the concurrency benchmark uses it
        as the serialized baseline).
        """
        with trace("engine.load", model=name) as op:
            lm = self._load_model_impl(name, bits, shared_cache)
        _M_OPS.labels("load").inc()
        _M_OP_SECONDS.labels("load").observe(op.elapsed())
        return lm

    def _load_model_impl(self, name: str, bits: int | None,
                         shared_cache: bool):
        from .loader import LoadedModel, ModelSnapshot

        self._drain_released()
        for _attempt in range(64):
            with trace("probe"), self._lock:
                entry = self.catalog.get(name)
                if entry is None or entry.status != STATUS_COMMITTED:
                    if entry is not None and entry.status == STATUS_CORRUPT:
                        raise self._corrupt_error(name)
                    raise KeyError(name)
                page_name = entry.page
            # Page bytes + header parse + payload slicing run outside the
            # engine lock: page files are immutable per *name* (vacuum
            # rewrites copy-on-write under new names), so bytes read here
            # are consistent with whatever entry we re-validate below.
            frame = None
            try:
                with trace("pool", page=page_name):
                    if shared_cache:
                        frame = self.page_pool.get(
                            page_name,
                            lambda: self._read_page_bytes(page_name),
                        )
                        page = self._parse_frame(frame)
                    else:
                        page = read_page_header(
                            self._read_page_bytes(page_name)
                        )
                    dims = page_dim_keys(page)
            except FileNotFoundError as exc:
                # Raced a delete/replace/vacuum: re-read the entry. A frame
                # returned by get() cannot be the raiser (its bytes loaded),
                # but unpin defensively in case the parse path ever throws.
                if frame is not None:
                    self.page_pool.unpin(frame)
                if self.read_only:
                    # No writers exist in a degraded store: the fallback
                    # snapshot predates this page's cleanup and the file
                    # is permanently gone — fail typed, don't spin.
                    self._quarantine_model(
                        name, page_name, f"page file missing: {exc}"
                    )
                    raise self._corrupt_error(name) from exc
                continue
            except CorruptPageError as exc:
                # Contain the damage: quarantine THIS model (the catalog
                # keeps serving every healthy one) and fail typed. Plain
                # I/O errors (EIO) do NOT quarantine — the disk said
                # nothing about the bytes, only about this read.
                if frame is not None:
                    self.page_pool.unpin(frame)
                self._quarantine_model(name, page_name, str(exc))
                raise
            except BaseException:
                if frame is not None:
                    self.page_pool.unpin(frame)  # corrupt page: no pin leak
                raise
            try:
                with trace("snapshot"), self._lock:
                    cur = self.catalog.get(name)
                    if cur is not None and cur.status == STATUS_CORRUPT:
                        raise self._corrupt_error(name)
                    if (cur is None or cur.status != STATUS_COMMITTED
                            or cur.page != page_name):
                        raise _Retry
                    for dim in dims:
                        self._check_quarantine(dim)
                    indexes: dict[int, HNSWIndex] = {}
                    for dim in dims:
                        idx = self.index_cache.get(dim)
                        if idx is None:
                            raise RuntimeError(
                                f"model {name!r} references dim {dim} but no "
                                "index exists for it (corrupt store?)"
                            )
                        indexes[dim] = idx
                    epoch = self.catalog.state.epoch
                    token = self._snap_token
                    self._snap_token += 1
                    self._live_snapshots[token] = epoch
                    # The snapshot owns a COPY of the catalog row: vacuum
                    # re-points the live entry's page at the rewritten
                    # file, and an "immutable view" must keep naming the
                    # page version it actually pinned.
                    cur = dataclasses.replace(cur)
            except _Retry:
                if frame is not None:
                    self.page_pool.unpin(frame)
                continue
            except CorruptIndexError as exc:
                # The page is fine but a referenced index file is not:
                # this model cannot materialize, so quarantine it (other
                # dims' models keep serving).
                if frame is not None:
                    self.page_pool.unpin(frame)
                self._quarantine_model(name, page_name, str(exc))
                raise
            except BaseException:
                if frame is not None:
                    self.page_pool.unpin(frame)
                raise
            snap = ModelSnapshot(
                epoch=epoch, entry=cur, frame=frame, indexes=indexes,
                release=_SnapshotRelease(self._released, token, frame),
            )
            return LoadedModel(engine=self, page=page, info=cur, bits=bits,
                               snapshot=snap)
        raise RuntimeError(
            f"load_model({name!r}): catalog kept changing under the capture "
            "loop (writer livelock?)"
        )

    def load_models(self, names, bits: int | None = None) -> list:
        """Open handles over several models under ONE snapshot epoch.

        Returns one :class:`~repro.core.loader.LoadedModel` per name, in
        order. Unlike a loop of :meth:`load_model` calls — where a writer
        committing between two captures hands the batch a mixed-epoch,
        mutually inconsistent view — the whole set is validated and
        captured inside a single critical section, so every handle shares
        the same epoch. Page I/O and header parsing still run outside the
        lock (the expensive part); the critical section only re-validates
        entries and stamps snapshots, retrying the batch when a writer
        raced the reads. Feed the result to
        :func:`repro.core.loader.materialize_many` to reconstruct with
        each base shared *across* handles de-quantized once.
        """
        names = list(names)
        if not names:
            return []
        with trace("engine.load_batch", n_models=len(names)) as op:
            handles = self._load_models_impl(names, bits)
        _M_OPS.labels("load_batch").inc()
        _M_OP_SECONDS.labels("load_batch").observe(op.elapsed())
        return handles

    def _load_models_impl(self, names: list, bits: int | None) -> list:
        from .loader import LoadedModel, ModelSnapshot
        self._drain_released()
        for _attempt in range(64):
            # Phase 1 (no lock held across I/O): resolve each name to its
            # committed page, pin + parse the frame. Same race handling as
            # load_model — FileNotFoundError means a delete/replace/vacuum
            # won; retry the whole batch so the view stays one-epoch.
            # Entries are mutable lists: once a ModelSnapshot takes
            # ownership of a frame (its finalizer unpins), the slot is
            # nulled so the failure path can't double-unpin it.
            prepared: list = []  # [name, page_name, frame, page, dims]
            corrupt_at: list = []  # (name, page_name) of an index failure

            def _unpin_prepared() -> None:
                for rec in prepared:
                    if rec[2] is not None:
                        self.page_pool.unpin(rec[2])
                        rec[2] = None

            try:
                for name in names:
                    with self._lock:
                        entry = self.catalog.get(name)
                        if entry is None or entry.status != STATUS_COMMITTED:
                            if (entry is not None
                                    and entry.status == STATUS_CORRUPT):
                                raise self._corrupt_error(name)
                            raise KeyError(name)
                        page_name = entry.page
                    frame = None
                    try:
                        frame = self.page_pool.get(
                            page_name,
                            lambda: self._read_page_bytes(page_name),
                        )
                        page = self._parse_frame(frame)
                        dims = page_dim_keys(page)
                    except FileNotFoundError as exc:
                        if frame is not None:
                            self.page_pool.unpin(frame)
                        if self.read_only:
                            self._quarantine_model(
                                name, page_name, f"page file missing: {exc}"
                            )
                            raise self._corrupt_error(name) from exc
                        raise _Retry from exc
                    except CorruptPageError as exc:
                        if frame is not None:
                            self.page_pool.unpin(frame)
                        self._quarantine_model(name, page_name, str(exc))
                        raise
                    except BaseException:
                        if frame is not None:
                            self.page_pool.unpin(frame)
                        raise
                    prepared.append([name, page_name, frame, page, dims])

                # Phase 2: ONE critical section — re-validate every entry
                # against the page version actually pinned, then stamp all
                # snapshots with the same epoch.
                with self._lock:
                    entries = []
                    for name, page_name, _frame, _page, dims in prepared:
                        cur = self.catalog.get(name)
                        if cur is not None and cur.status == STATUS_CORRUPT:
                            raise self._corrupt_error(name)
                        if (cur is None or cur.status != STATUS_COMMITTED
                                or cur.page != page_name):
                            raise _Retry
                        for dim in dims:
                            self._check_quarantine(dim)
                        entries.append(dataclasses.replace(cur))
                    index_sets = []
                    for rec in prepared:
                        name, page_name, _fr, _pg, dims = rec
                        corrupt_at[:] = [(name, page_name)]
                        indexes: dict[int, HNSWIndex] = {}
                        for dim in dims:
                            idx = self.index_cache.get(dim)
                            if idx is None:
                                raise RuntimeError(
                                    f"model {name!r} references dim {dim} "
                                    "but no index exists for it (corrupt "
                                    "store?)"
                                )
                            indexes[dim] = idx
                        index_sets.append(indexes)
                    epoch = self.catalog.state.epoch
                    snaps = []
                    for rec, cur, indexes in zip(
                            prepared, entries, index_sets):
                        frame = rec[2]
                        token = self._snap_token
                        self._snap_token += 1
                        self._live_snapshots[token] = epoch
                        snaps.append(ModelSnapshot(
                            epoch=epoch, entry=cur, frame=frame,
                            indexes=indexes,
                            release=_SnapshotRelease(
                                self._released, token, frame),
                        ))
                        rec[2] = None  # frame now owned by the snapshot
            except _Retry:
                _unpin_prepared()
                continue
            except CorruptIndexError as exc:
                # Index damage discovered during capture: quarantine the
                # model whose dims were being resolved; fail the batch typed
                # (other models stay healthy).
                _unpin_prepared()
                for name, page_name in corrupt_at:
                    self._quarantine_model(name, page_name, str(exc))
                raise
            except BaseException:
                _unpin_prepared()
                raise
            return [
                LoadedModel(engine=self, page=rec[3], info=snap.entry,
                            bits=bits, snapshot=snap)
                for rec, snap in zip(prepared, snaps)
            ]
        raise RuntimeError(
            f"load_models({names!r}): catalog kept changing under the batch "
            "capture loop (writer livelock?)"
        )

    # ------------------------------------------------------------- integrity
    def scrub(self, max_models: int = 1) -> dict:
        """Incremental integrity scrub: verify up to ``max_models`` pages.

        A round-robin cursor walks the committed models so repeated calls
        (one per maintenance-daemon step) cover the whole store, finding
        latent disk corruption and quarantining it *before* a reader trips
        on it. Only page bytes are read — no payload decode, no lock held
        during I/O.
        """
        report: dict = {"scanned": 0, "corrupt": [], "io_errors": 0}
        for _ in range(max(0, int(max_models))):
            with self._lock:
                names = self.catalog.names()
                if not names:
                    break
                self._scrub_cursor %= len(names)
                name = names[self._scrub_cursor]
                self._scrub_cursor += 1
                page_name = self.catalog.get(name).page
            try:
                verify_page(self.fs.read_bytes(
                    self._page_file(page_name), site="page.scrub"
                ))
            except FileNotFoundError:
                continue  # raced a delete/replace/vacuum
            except CorruptPageError as exc:
                if self._quarantine_model(name, page_name, f"scrub: {exc}"):
                    report["corrupt"].append(name)
            except OSError:
                report["io_errors"] += 1
            report["scanned"] += 1
        return report

    def verify_store(self, quarantine: bool = False) -> dict:
        """Full integrity sweep over every page and index file.

        With ``quarantine=True`` (the repair path — ``tools/fsck.py``),
        models whose page fails verification, whose page file is missing,
        or whose referenced index file is corrupt are marked corrupt in
        the catalog (one snapshot at the end persists them all).
        """
        report: dict = {"pages": {}, "indexes": {}, "quarantined": []}
        bad_dims: set[int] = set()
        for dim in self.index_cache.dims():
            path = self.index_cache._path(dim)
            if not os.path.exists(path):
                continue  # resident-only index: consistent by construction
            try:
                payload = unframe_index(
                    self.fs.read_bytes(path, site="index.scrub"), path
                )
                HNSWIndex.from_bytes(payload)
                report["indexes"][dim] = "ok"
            except Exception as exc:
                report["indexes"][dim] = f"corrupt: {exc}"
                bad_dims.add(dim)
        with self._lock:
            names = self.catalog.names(committed_only=False)
        changed = False
        for name in names:
            with self._lock:
                entry = self.catalog.get(name)
                if entry is None:
                    continue
                if entry.status == STATUS_CORRUPT:
                    report["pages"][name] = "quarantined"
                    continue
                page_name = entry.page
            status = "ok"
            reason = None
            try:
                page = verify_page(self.fs.read_bytes(
                    self._page_file(page_name), site="page.scrub"
                ))
                broken = sorted(set(page_dim_keys(page)) & bad_dims)
                if broken:
                    reason = f"references corrupt index dim(s) {broken}"
                    status = f"corrupt: {reason}"
            except FileNotFoundError:
                reason = "page file missing"
                status = f"corrupt: {reason}"
            except CorruptPageError as exc:
                reason = str(exc)
                status = f"corrupt: {reason}"
            if reason is not None and quarantine:
                if self._quarantine_model(
                    name, page_name, reason, persist=False
                ):
                    report["quarantined"].append(name)
                    changed = True
            report["pages"][name] = status
        if changed and not self.read_only:
            with self._lock:
                self.catalog.save_snapshot()
        return report

    def drop_corrupt_models(self) -> list[str]:
        """Delete every quarantined model (the destructive half of repair)."""
        self._check_writable()
        dropped = []
        with self._lock:
            for name in self.catalog.corrupt_names():
                self.delete_model(name)
                dropped.append(name)
        return dropped

    def rebuild_vertex_refs(self) -> dict:
        """Re-derive ``vertex_refs`` wholesale from committed pages.

        The repair path for leaked references (quarantine accounting is
        deliberately conservative — see :meth:`_page_refs`). Requires no
        quarantined models: their unreadable records hold references this
        rebuild cannot see, and dropping those would free live bases.
        Newly unreferenced vertices are tombstoned for a later vacuum.
        """
        self._check_writable()
        with self._lock:
            if self.catalog.corrupt_names():
                raise RuntimeError(
                    "cannot rebuild refs while quarantined models exist — "
                    "repair or drop them first"
                )
            derived: Counter = Counter()
            for n in self.catalog.names():
                derived.update(
                    self._page_refs(self.catalog.get(n).page, strict=True)
                )
            old_keys = set(self.catalog.state.vertex_refs)
            self.catalog.state.vertex_refs = {
                f"{d}:{v}": int(c) for (d, v), c in derived.items()
            }
            pairs = {
                tuple(int(x) for x in k.split(":")) for k in old_keys
            } | set(derived)
            self._tombstone_unreferenced(pairs)
            self.index_cache.flush()
            self.catalog.save_snapshot()
            return {
                "refs": len(derived),
                "dropped": len(
                    old_keys - set(self.catalog.state.vertex_refs)
                ),
            }

    # ----------------------------------------------------------- maintenance
    def start_maintenance(self, **kwargs):
        """Start the background maintenance daemon (idempotent).

        Keyword arguments are forwarded to
        :class:`repro.core.maintenance.MaintenanceDaemon` (thresholds,
        interval). Returns the daemon; ``close()`` stops it.
        """
        from .maintenance import MaintenanceDaemon

        with self._lock:
            if self.maintenance is None:
                self.maintenance = MaintenanceDaemon(self, **kwargs)
                self.maintenance.start()
            return self.maintenance

    def close(self) -> None:
        """Stop background maintenance, flush queued EXPLAIN sidecars,
        and release queued snapshot pins."""
        daemon = self.maintenance
        if daemon is not None:
            daemon.stop()
            self.maintenance = None
        self.flush_explains()
        self._drain_released()

    # ------------------------------------------------------------ accounting
    def stats(self) -> dict:
        """Engine-wide counters — a versioned API, not an internal dump.

        ``schema_version`` stamps the layout (``STATS_SCHEMA_VERSION``);
        every counter is documented in ``docs/serving.md``, and the
        serving admission policy consumes only the documented fields
        (through :class:`repro.store.api.StoreStats`).

        ``buffer_pool``: page-frame hits/misses/evictions, resident and
        pinned bytes, shared-decode hit rate. ``epoch``: the current
        snapshot-isolation epoch (bumped at every writer commit).
        ``snapshots``: live reader snapshots and the oldest epoch still
        pinned. ``index_cache``: the existing HNSW cache counters.
        ``models``: committed (servable) catalog entries.
        """
        self._drain_released()
        with self._lock:
            live = list(self._live_snapshots.values())
            out = {
                "schema_version": STATS_SCHEMA_VERSION,
                "epoch": self.catalog.state.epoch,
                "models": len(self.catalog.names()),
                "snapshots": {
                    "live": len(live),
                    "oldest_epoch": min(live) if live else None,
                },
                "buffer_pool": self.page_pool.stats(),
                "index_cache": self.index_cache.stats(),
                "integrity": {
                    "read_only": self.read_only,
                    "degraded_reason": self.degraded_reason,
                    "checksums": self.checksums,
                    "corrupt_models": sorted(self.catalog.corrupt_names()),
                },
                "accounting": self._accounting_stats(),
            }
            if self.maintenance is not None:
                out["maintenance"] = self.maintenance.stats()
            return out

    def list_models(self) -> list[str]:
        return self.catalog.names()

    def model_info(self, name: str) -> ModelEntry | None:
        return self.catalog.get(name)

    def storage_bytes(self) -> dict:
        """Total storage split: pages vs index (paper Fig. 10a breakdown).

        Takes the engine lock so the flush never serializes an index that a
        concurrent ``save_model`` phase 1 is mutating.
        """
        with self._lock:
            pages = sum(
                os.path.getsize(self._page_file(self.catalog.get(n).page))
                for n in self.catalog.names()
            )
            self.index_cache.flush()
            index = sum(
                os.path.getsize(os.path.join(self.root, "index", f))
                for f in os.listdir(os.path.join(self.root, "index"))
                if f.endswith(".idx")
            )
        return {"pages": pages, "index": index, "total": pages + index}

    def per_model_bytes(self, name: str) -> float:
        """Page bytes + amortized share of referenced base-tensor storage.

        Paper §6.3.2: "evenly distribute the storage cost of each base tensor
        in the index across all tensors that reference it".
        """
        page, entry = self.open_page(name)
        total = float(os.path.getsize(self._page_file(entry.page)))
        for i in range(page.n_records):
            rec = read_record(page, i, with_payload=False)
            share = self.catalog.ref_count(rec.dim_key, rec.vertex_id)
            # 8-bit base codes + graph overhead approximated by codes size.
            total += rec.numel / max(share, 1)
        return total

    def _accounting_stats(self) -> dict:
        """The ``accounting`` section of :meth:`stats` (documented —
        StoreStats projects ``logical_bytes`` / ``physical_bytes`` /
        ``compression_ratio`` out of it)."""
        logical, physical = self._accountant.totals(self.catalog.ref_count)
        return {
            "enabled": self.accounting,
            "logical_bytes": logical,
            "physical_bytes": physical,
            "compression_ratio": (
                physical / logical if logical > 0 else None
            ),
        }

    def _scan_model_spaces(self) -> list[ModelSpace]:
        """Full-rescan ground truth for the space accountant.

        Metadata-only page scans (no payload decode) over every committed
        model; unreadable or damaged pages are skipped — accounting must
        never turn an I/O hiccup into an open failure (fsck owns damage
        reporting). Uses its own fault site (``page.accounting``) so the
        existing fault-campaign schedules are not perturbed.
        """
        spaces: list[ModelSpace] = []
        for name in self.catalog.names():
            entry = self.catalog.get(name)
            path = self._page_file(entry.page)
            try:
                buf = self.fs.read_bytes(path, site="page.accounting")
                page = read_page_header(buf)
                tensors = tuple(
                    TensorSpace(rec.dim_key, rec.vertex_id, rec.numel,
                                rec.payload_nbytes)
                    for rec in (
                        read_record(page, i, with_payload=False)
                        for i in range(page.n_records)
                    )
                )
            except (OSError, CorruptPageError):
                continue
            spaces.append(ModelSpace(
                name=name,
                page=entry.page,
                page_bytes=len(buf),
                logical_bytes=entry.original_bytes,
                tensors=tensors,
            ))
        return spaces

    def accounting_report(self, tenant_of=None) -> dict:
        """Space-attribution report (see ``repro.obs.accounting``).

        With accounting disabled the report is computed from a one-off
        rescan instead of the (empty) incremental ledger, so the surface
        stays queryable either way. ``tenant_of(name)`` optionally maps a
        model name to its tenant for the per-tenant breakdown.
        """
        with self._lock:
            acct = self._accountant
            if not self.accounting:
                acct = SpaceAccountant()
                acct.reset(self._scan_model_spaces())
            return acct.report(self.catalog.ref_count, tenant_of=tenant_of)

    def accounting_drift(self) -> list[str]:
        """Cross-check the incremental ledger against a fresh rescan.

        Returns one human-readable line per discrepancy (empty = clean).
        This is the fsck ``--accounting`` check: any drift means a commit
        point failed to keep the ledger in step with the store.
        """
        if not self.accounting:
            return []
        with self._lock:
            truth = SpaceAccountant()
            truth.reset(self._scan_model_spaces())
            return self._accountant.diff(truth)

    def model_explain(self, name: str) -> dict:
        """The persisted save-EXPLAIN + current space attribution for one
        model (the ``GET …/models/{name}/explain`` body)."""
        with self._lock:
            entry = self.catalog.get(name)
            if entry is None:
                raise KeyError(name)
            if entry.explain is None:
                # Not in memory (engine reopened since the save): pull
                # the persisted sidecar and cache it on the entry.
                entry.explain = self._load_explain_sidecar(entry.model_id)
            explain = list(entry.explain) if entry.explain else []
            out = {
                "name": name,
                "model_id": entry.model_id,
                "n_tensors": entry.n_tensors,
                "explain": explain,
                # True when the save had more tensors than the catalog
                # persists (EXPLAIN_PERSIST_MAX) or predates EXPLAIN.
                "truncated": len(explain) < entry.n_tensors,
            }
        out["accounting"] = self.accounting_report()["per_model"].get(name)
        return out

    def reconstruct_tensor(self, rec: TensorRecord) -> np.ndarray:
        """Full reconstruction: de-quantized base + de-quantized delta."""
        with self._lock:  # atomic vs vacuum's in-place index compaction
            self._check_quarantine(rec.dim_key)
            index = self.index_cache.get(rec.dim_key)
            base = index.dequantize_vertex(rec.vertex_id)
        delta = dequantize_delta(rec.qdelta, rec.meta)
        return (base + delta).reshape(rec.shape).astype(np.float32)
