"""HNSW tensor index (paper §2.3, §4.1).

Faithful multi-layer HNSW (Malkov & Yashunin) specialised the way NeurStore
uses it:

* each vertex stores an **8-bit quantized base tensor** plus its scale /
  zero-point (paper §4.1 "to reduce the index size, each base tensor is
  quantized to 8-bit ... prior to insertion");
* distance between a float32 query and a vertex de-quantizes the vertex on
  the fly — the paper's ``QuantizedL2Space`` (AVX2). Here the hot loop is the
  vectorized :func:`quantized_l2_batch`, mirrored 1:1 by the Pallas TPU
  kernel in ``repro.kernels.quantized_l2``;
* one index per flattened tensor length — the engine keeps a pool keyed by
  ``dim`` (paper §4.2 flattens tensors so (10,10) and (5,20) share an index).

Graph traversal is host-side control flow (as in the paper's CPU extension);
only the distance computation is a dense batched op.
"""

from __future__ import annotations

import math
import pickle

import numpy as np

from .quantize import QuantMeta, quantize_linear

__all__ = ["HNSWIndex", "quantized_l2_batch"]


def quantized_l2_batch(
    query: np.ndarray,
    codes: np.ndarray,
    scales: np.ndarray,
    zero_points: np.ndarray,
    mids: np.ndarray,
) -> np.ndarray:
    """Squared L2 between one f32 query (D,) and N quantized rows (N, D).

    Row i de-quantizes as ``(codes[i] - zp[i]) * scale[i]`` (or the constant
    ``mids[i]`` when ``scale[i] == 0``). This is the oracle the Pallas kernel
    ``repro/kernels/quantized_l2.py`` reproduces on TPU.
    """
    deq = (codes.astype(np.float64) - zero_points[:, None]) * scales[:, None]
    const_rows = scales == 0.0
    if const_rows.any():
        deq[const_rows] = mids[const_rows, None]
    diff = deq - query[None, :].astype(np.float64)
    return np.einsum("nd,nd->n", diff, diff)


class HNSWIndex:
    """Hierarchical navigable small world graph over quantized base tensors."""

    def __init__(self, dim: int, m: int = 16, ef_construction: int = 64, seed: int = 0):
        self.dim = dim
        self.m = m
        self.m0 = 2 * m
        self.ef_construction = ef_construction
        self.ml = 1.0 / math.log(m)
        self._rng = np.random.default_rng(seed)
        # Vertex payloads: quantized codes + per-vertex quant meta arrays.
        self._codes = np.zeros((0, dim), dtype=np.uint8)
        self._scales = np.zeros((0,), dtype=np.float64)
        self._zps = np.zeros((0,), dtype=np.int32)
        self._mids = np.zeros((0,), dtype=np.float64)
        self._levels: list[int] = []
        # neighbors[layer][node] -> list[int]
        self._neighbors: list[dict[int, list[int]]] = []
        self._entry: int | None = None
        self._max_level = -1

    # ------------------------------------------------------------------ size
    def __len__(self) -> int:
        return len(self._levels)

    @property
    def nbytes(self) -> int:
        """Approximate resident size (codes dominate; paper stores 8-bit)."""
        edge_bytes = sum(
            8 * sum(len(v) for v in layer.values()) for layer in self._neighbors
        )
        return self._codes.nbytes + self._scales.nbytes + self._zps.nbytes + edge_bytes

    # ------------------------------------------------------------ vertex I/O
    def vertex_codes(self, vid: int) -> tuple[np.ndarray, QuantMeta]:
        meta = QuantMeta(
            scale=float(self._scales[vid]),
            zero_point=int(self._zps[vid]),
            nbit=8,
            mid=float(self._mids[vid]),
        )
        return self._codes[vid], meta

    def dequantize_vertex(self, vid: int) -> np.ndarray:
        codes, meta = self.vertex_codes(vid)
        if meta.scale == 0.0:
            return np.full(self.dim, meta.mid, dtype=np.float64)
        return (codes.astype(np.float64) - meta.zero_point) * meta.scale

    # ------------------------------------------------------------- distances
    def _distances(self, query: np.ndarray, ids: list[int]) -> np.ndarray:
        idx = np.asarray(ids, dtype=np.int64)
        return quantized_l2_batch(
            query, self._codes[idx], self._scales[idx], self._zps[idx], self._mids[idx]
        )

    # ---------------------------------------------------------------- search
    def _search_layer(
        self, query: np.ndarray, entry: list[int], ef: int, layer: int
    ) -> list[tuple[float, int]]:
        """Best-first search on one layer; returns ef closest (dist, id)."""
        import heapq

        visited = set(entry)
        dists = self._distances(query, entry)
        cand: list[tuple[float, int]] = [(d, v) for d, v in zip(dists, entry)]
        heapq.heapify(cand)
        best: list[tuple[float, int]] = [(-d, v) for d, v in zip(dists, entry)]
        heapq.heapify(best)
        while len(best) > ef:
            heapq.heappop(best)
        adj = self._neighbors[layer]
        while cand:
            d, v = heapq.heappop(cand)
            if best and d > -best[0][0]:
                break
            fresh = [u for u in adj.get(v, ()) if u not in visited]
            if not fresh:
                continue
            visited.update(fresh)
            fd = self._distances(query, fresh)
            bound = -best[0][0]
            for du, u in zip(fd, fresh):
                if len(best) < ef or du < bound:
                    heapq.heappush(cand, (du, u))
                    heapq.heappush(best, (-du, u))
                    if len(best) > ef:
                        heapq.heappop(best)
                    bound = -best[0][0]
        return sorted((-nd, v) for nd, v in best)

    def search(self, query: np.ndarray, k: int = 1, ef: int | None = None) -> list[tuple[float, int]]:
        """Approximate k-NN of a float query; returns [(sq_dist, vertex_id)]."""
        if self._entry is None:
            return []
        ef = max(ef or self.ef_construction, k)
        q = np.asarray(query, dtype=np.float64).ravel()
        entry = [self._entry]
        for layer in range(self._max_level, 0, -1):
            entry = [self._search_layer(q, entry, 1, layer)[0][1]]
        return self._search_layer(q, entry, ef, 0)[:k]

    # ---------------------------------------------------------------- insert
    def _select_neighbors(self, cands: list[tuple[float, int]], m: int) -> list[int]:
        return [v for _, v in sorted(cands)[:m]]

    def insert(self, tensor: np.ndarray) -> int:
        """Quantize ``tensor`` to 8 bits and insert as a new vertex.

        Returns the vertex id. The stored representation is the quantized
        code; callers needing the de-quantized base use
        :meth:`dequantize_vertex`.
        """
        q = np.asarray(tensor, dtype=np.float64).ravel()
        assert q.size == self.dim, (q.size, self.dim)
        codes, meta = quantize_linear(q, nbit=8)
        vid = len(self._levels)
        self._codes = np.concatenate([self._codes, codes.astype(np.uint8)[None, :]])
        self._scales = np.append(self._scales, meta.scale)
        self._zps = np.append(self._zps, meta.zero_point)
        self._mids = np.append(self._mids, meta.mid)
        level = int(-math.log(max(self._rng.random(), 1e-12)) * self.ml)
        self._levels.append(level)
        while len(self._neighbors) <= level:
            self._neighbors.append({})
        for layer in range(level + 1):
            self._neighbors[layer].setdefault(vid, [])

        if self._entry is None:
            self._entry = vid
            self._max_level = level
            return vid

        entry = [self._entry]
        for layer in range(self._max_level, level, -1):
            entry = [self._search_layer(q, entry, 1, layer)[0][1]]
        for layer in range(min(level, self._max_level), -1, -1):
            cands = self._search_layer(q, entry, self.ef_construction, layer)
            m = self.m0 if layer == 0 else self.m
            nbrs = self._select_neighbors(cands, m)
            adj = self._neighbors[layer]
            adj[vid] = list(nbrs)
            for u in nbrs:
                lst = adj.setdefault(u, [])
                lst.append(vid)
                if len(lst) > m:
                    # Shrink: keep the m closest to u.
                    base_u = self.dequantize_vertex(u)
                    du = self._distances(base_u, lst)
                    order = np.argsort(du)[:m]
                    adj[u] = [lst[i] for i in order]
            entry = [v for _, v in cands]
        if level > self._max_level:
            self._max_level = level
            self._entry = vid
        return vid

    # ------------------------------------------------------------- serialize
    def to_bytes(self) -> bytes:
        state = {
            "dim": self.dim,
            "m": self.m,
            "ef_construction": self.ef_construction,
            "codes": self._codes,
            "scales": self._scales,
            "zps": self._zps,
            "mids": self._mids,
            "levels": self._levels,
            "neighbors": self._neighbors,
            "entry": self._entry,
            "max_level": self._max_level,
        }
        return pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def from_bytes(cls, data: bytes) -> "HNSWIndex":
        state = pickle.loads(data)
        idx = cls(state["dim"], state["m"], state["ef_construction"])
        idx._codes = state["codes"]
        idx._scales = state["scales"]
        idx._zps = state["zps"]
        idx._mids = state["mids"]
        idx._levels = state["levels"]
        idx._neighbors = state["neighbors"]
        idx._entry = state["entry"]
        idx._max_level = state["max_level"]
        return idx
