"""HNSW tensor index (paper §2.3, §4.1) — vectorized hot path.

Faithful multi-layer HNSW (Malkov & Yashunin) specialised the way NeurStore
uses it:

* each vertex stores an **8-bit quantized base tensor** plus its scale /
  zero-point (paper §4.1 "to reduce the index size, each base tensor is
  quantized to 8-bit ... prior to insertion");
* distance between a float32 query and a vertex de-quantizes the vertex on
  the fly — the paper's ``QuantizedL2Space`` (AVX2). Here the hot loop is the
  vectorized :func:`quantized_l2_batch`, mirrored by the Pallas TPU kernel in
  ``repro.kernels.quantized_l2``;
* one index per flattened tensor length — the engine keeps a pool keyed by
  ``dim`` (paper §4.2 flattens tensors so (10,10) and (5,20) share an index).

Graph traversal is host-side control flow (as in the paper's CPU extension);
only the distance computation is a dense batched op.

Hot-path design (vs the seed implementation, frozen in
``repro.core.hnsw_ref`` as the parity oracle):

* **Amortized vertex storage** — codes/scales/zero-points/mids/norms live in
  capacity-doubling preallocated arrays; insert is O(1) amortized instead of
  the seed's per-insert ``np.concatenate`` (O(n·D) copy per insert).
* **Decomposed quantized L2** — with ``deq_i = (c_i − z_i)·s_i`` the squared
  distance to query ``q`` expands to

      ‖q − deq_i‖² = ‖q‖² − 2·s_i·(q·c_i) + 2·s_i·z_i·Σq + ‖deq_i‖²

  where ``‖deq_i‖² = s_i²·(Σc_i² − 2·z_i·Σc_i + D·z_i²)`` is cached per
  vertex at insert (computed from exact integer sums of the uint8 codes).
  Constant rows (``s_i == 0``) use ``‖q‖² − 2·mid_i·Σq + D·mid_i²``; both
  cases collapse into one branch-free form via the per-vertex cache
  ``cross_i = s_i·z_i`` (normal) / ``−mid_i`` (constant):

      dist_i = ‖q‖² + ‖deq_i‖² + 2·(Σq·cross_i − s_i·(q·c_i))

  A search therefore costs one gemv over the candidate codes plus O(B)
  scalar work — no per-call (B, D) dequantize/subtract/square temporaries.
  The in-index gemv runs in float32 (codes are ≤ 255, exactly
  representable; measured max relative deviation from the float64 oracle
  is ~8e-8 at D=4096, an order of magnitude inside the 1e-6 parity
  budget) with the O(B) combination kept in float64.
* **Epoch visited tracking** — layer search stamps visited vertices into a
  reused int64 epoch array (hnswlib's VisitedListPool pattern: bump the
  epoch instead of re-zeroing) and filters neighbor expansions vectorized,
  replacing the seed's per-int Python ``set`` hashing without O(N) memset
  per layer call.

Precision note: the decomposed form has *absolute* error ~``s·‖q‖·ε₃₂·√D``
from the float32 gemv. For queries far from every vertex (the parity
workloads) that is ≤1e-6 relative; for a query next to a stored vertex the
distance itself approaches zero so the *relative* error can reach ~1e-2 —
but the absolute error stays ~1e-3 while competing candidates sit orders
of magnitude away, so nearest-base ranking (the engine's only use) is
unaffected, and the engine recomputes the delta exactly in float64 against
whichever base wins.
* Adjacency lists are int64 numpy arrays so the visited filter and the
  shrink step stay in numpy.

The traversal order and neighbor-selection logic are unchanged from the
seed, so on fixed-seed workloads the rebuilt index returns the same
neighbor ids (distances agree to fp rounding; see
``tests/test_hotpath.py``).

Lifecycle support (model delete/replace → vertex GC):

* **Tombstones** — :meth:`HNSWIndex.mark_deleted` excludes a vertex from
  search *results* while keeping it as a graph waypoint (hnswlib's
  deleted-markers): layer search still traverses dead vertices, it just
  never admits them to the result heap. With no deletions the filtered
  loop is behaviorally identical to the seed loop (the ``len(best) >= ef``
  stop condition cannot bind earlier than the seed's non-empty check while
  nothing is filtered), preserving oracle parity.
* **Compaction** — :meth:`HNSWIndex.compact` drops dead vertices from the
  vertex arrays and adjacency, first reconnecting each dead vertex's live
  neighbors to each other (bounded edge contraction, shrink-by-distance)
  so the graph stays navigable, and returns the old→new vertex-id remap
  the engine applies to surviving page records. Vertex codes are copied
  verbatim, so ``dequantize_vertex`` output for every surviving vertex is
  bit-identical across compaction.
"""

from __future__ import annotations

import heapq
import math
import pickle

import numpy as np

from .quantize import QuantMeta, quantize_linear, quantize_linear_batch
from ..obs.metrics import default_registry

__all__ = ["HNSWIndex", "quantized_l2_batch", "KERNEL_DISPATCH_MIN_ELEMS"]

# Process-wide HNSW counters (docs/observability.md), summed over every
# index in the process. Increments are batched (one .inc(n) per distance
# call / per search) so the hot loops pay one counter bump, not one per
# vertex.
_REG = default_registry()
_M_DIST_EVALS = _REG.counter(
    "neurstore_hnsw_distance_evals_total",
    "Vertex distance evaluations (rows of decomposed quantized-L2).",
)
_M_VISITED = _REG.counter(
    "neurstore_hnsw_visited_total",
    "Vertices visited during layer searches.",
)
_M_SEARCHES = _REG.counter(
    "neurstore_hnsw_searches_total", "Graph k-NN searches."
)
_M_INSERTS = _REG.counter(
    "neurstore_hnsw_inserts_total", "Vertices inserted."
)

_EMPTY_IDS = np.empty(0, dtype=np.int64)

# Dispatch seam: (N, D) code blocks with at least this many elements are
# offered to the Pallas quantized_l2 kernel before falling back to the
# numpy decomposed-gemm form. The kernel only engages on a TPU backend —
# interpret mode would be strictly slower than the gemm fallback on CPU.
KERNEL_DISPATCH_MIN_ELEMS = 4 << 20


def _offload_distances(queries, codes, scales, zps, mids):
    """Offer one (B, D)-vs-(N, D) distance block to the TPU kernel.

    Returns the (B, N) distances, or ``None`` when the kernel path is
    unavailable (no jax, no TPU backend, block too small) — callers fall
    back to the numpy decomposed form. Kept as a module-level hook so
    tests can stub it to verify the seam is consulted.
    """
    try:
        from repro.kernels import ops
    except Exception:  # jax missing/broken: numpy fallback is fully featured
        return None
    # This module's constant is the single size gate for the index path —
    # forwarded so ops' own default cannot silently re-gate behind it.
    return ops.quantized_l2_auto(queries, codes, scales, zps, mids,
                                 min_elems=KERNEL_DISPATCH_MIN_ELEMS)


def _code_norms(codes, scales, zero_points, mids, dim: int) -> np.ndarray:
    """Cached ``‖deq‖²`` per row: ``s²·(Σc² − 2·z·Σc + D·z²)``, or
    ``D·mid²`` for constant rows — computed from exact integer code sums
    (uint8 codes: both sums fit int64 for any realistic D)."""
    c64 = np.atleast_2d(codes).astype(np.int64, copy=False)
    csum = c64.sum(axis=1)
    csq = np.einsum("nd,nd->n", c64, c64)
    s = np.atleast_1d(np.asarray(scales, dtype=np.float64))
    z = np.atleast_1d(np.asarray(zero_points, dtype=np.float64))
    norms = s * s * (csq - 2.0 * z * csum + dim * z * z)
    const = s == 0.0
    if const.any():
        m = np.atleast_1d(np.asarray(mids, dtype=np.float64))
        norms = np.where(const, dim * m * m, norms)
    return norms


def quantized_l2_batch(
    query: np.ndarray,
    codes: np.ndarray,
    scales: np.ndarray,
    zero_points: np.ndarray,
    mids: np.ndarray,
) -> np.ndarray:
    """Squared L2 between one f32 query (D,) and N quantized rows (N, D).

    Row i de-quantizes as ``(codes[i] - zp[i]) * scale[i]`` (or the constant
    ``mids[i]`` when ``scale[i] == 0``). Computed in the decomposed form
    documented in the module docstring; the seed's dense dequantize-and-
    einsum oracle survives as ``repro.kernels.ref.quantized_l2_batch_ref``
    and the Pallas kernel ``repro/kernels/quantized_l2.py`` mirrors this
    decomposition on TPU.
    """
    q = np.asarray(query, dtype=np.float64).ravel()
    qsq = float(np.dot(q, q))
    qsum = float(q.sum())
    dim = q.size
    s = np.asarray(scales, dtype=np.float64)
    z = np.asarray(zero_points, dtype=np.float64)
    norms = _code_norms(codes, s, z, mids, dim)
    dot = codes.astype(np.float64) @ q
    dist = (qsq - 2.0 * (s * dot - s * z * qsum)) + norms
    const = s == 0.0
    if const.any():
        m = np.asarray(mids, dtype=np.float64)[const]
        dist[const] = (qsq - 2.0 * m * qsum) + norms[const]
    return np.maximum(dist, 0.0, out=dist)


class HNSWIndex:
    """Hierarchical navigable small world graph over quantized base tensors."""

    def __init__(self, dim: int, m: int = 16, ef_construction: int = 64, seed: int = 0):
        self.dim = dim
        self.m = m
        self.m0 = 2 * m
        self.ef_construction = ef_construction
        self.ml = 1.0 / math.log(m)
        self._rng = np.random.default_rng(seed)
        # Vertex payloads in capacity-doubling arrays; rows [0, _n) are live.
        self._n = 0
        self._cap = 0
        self._codes = np.empty((0, dim), dtype=np.uint8)
        self._scales = np.empty((0,), dtype=np.float64)
        self._zps = np.empty((0,), dtype=np.int32)
        self._mids = np.empty((0,), dtype=np.float64)
        # Cached ‖deq_i‖² and cross_i per vertex (see module docstring).
        self._norms = np.empty((0,), dtype=np.float64)
        self._cross = np.empty((0,), dtype=np.float64)
        # Visited-epoch array reused across layer searches (no per-call
        # O(N) zeroing); a vertex is visited iff _vepoch[v] == _epoch.
        self._vepoch = np.zeros((0,), dtype=np.int64)
        self._epoch = 0
        # Tombstones: dead vertices stay as graph waypoints but are
        # excluded from search results until compact() drops them.
        self._deleted = np.zeros((0,), dtype=bool)
        self._levels: list[int] = []
        # neighbors[layer][node] -> int64 ndarray of neighbor ids
        self._neighbors: list[dict[int, np.ndarray]] = []
        self._entry: int | None = None
        self._max_level = -1

    # ------------------------------------------------------------------ size
    def __len__(self) -> int:
        return self._n

    @property
    def nbytes(self) -> int:
        """Approximate resident size: allocated vertex arrays + graph edges."""
        edge_bytes = sum(
            8 * sum(v.size for v in layer.values()) for layer in self._neighbors
        )
        return (
            self._codes.nbytes
            + self._scales.nbytes
            + self._zps.nbytes
            + self._mids.nbytes
            + self._norms.nbytes
            + self._cross.nbytes
            + self._deleted.nbytes
            + edge_bytes
        )

    def _grow(self, needed: int) -> None:
        """Double capacity until ``needed`` rows fit (O(1) amortized insert)."""
        if needed <= self._cap:
            return
        cap = max(self._cap, 8)
        while cap < needed:
            cap *= 2
        for name in ("_codes", "_scales", "_zps", "_mids", "_norms", "_cross",
                     "_vepoch", "_deleted"):
            old = getattr(self, name)
            shape = (cap, self.dim) if old.ndim == 2 else (cap,)
            # _vepoch must be zero-filled (epoch stamps start at 1) and
            # _deleted false-filled (new rows are live).
            alloc = np.zeros if name in ("_vepoch", "_deleted") else np.empty
            new = alloc(shape, dtype=old.dtype)
            new[: self._n] = old[: self._n]
            setattr(self, name, new)
        self._cap = cap

    # ------------------------------------------------------------ vertex I/O
    def vertex_codes(self, vid: int) -> tuple[np.ndarray, QuantMeta]:
        meta = QuantMeta(
            scale=float(self._scales[vid]),
            zero_point=int(self._zps[vid]),
            nbit=8,
            mid=float(self._mids[vid]),
        )
        return self._codes[vid], meta

    def dequantize_vertex(self, vid: int) -> np.ndarray:
        codes, meta = self.vertex_codes(vid)
        if meta.scale == 0.0:
            return np.full(self.dim, meta.mid, dtype=np.float64)
        return (codes.astype(np.float64) - meta.zero_point) * meta.scale

    # ------------------------------------------------------------- tombstones
    def mark_deleted(self, vid: int) -> None:
        """Tombstone a vertex: excluded from search results, kept as waypoint."""
        if not 0 <= vid < self._n:
            raise IndexError(f"vertex {vid} out of range [0, {self._n})")
        self._deleted[vid] = True

    def is_deleted(self, vid: int) -> bool:
        return bool(self._deleted[vid])

    @property
    def dead_count(self) -> int:
        return int(self._deleted[: self._n].sum())

    @property
    def live_count(self) -> int:
        return self._n - self.dead_count

    def dead_fraction(self) -> float:
        return self.dead_count / self._n if self._n else 0.0

    # ------------------------------------------------------------- distances
    def _distances(
        self, q32: np.ndarray, qsq: float, qsum: float, ids: np.ndarray
    ) -> np.ndarray:
        """Decomposed quantized L2 over a candidate batch (see module doc).

        ``q32`` is the float32 query; ``qsq``/``qsum`` are its float64
        squared norm and element sum.
        """
        idx = np.asarray(ids, dtype=np.int64)
        _M_DIST_EVALS.inc(idx.size)
        dot = self._codes[idx].astype(np.float32) @ q32
        s = self._scales[idx]
        dist = (qsq + self._norms[idx]) + 2.0 * (qsum * self._cross[idx] - s * dot)
        return np.maximum(dist, 0.0, out=dist)

    def _distance_block(self, queries: np.ndarray, n: int) -> np.ndarray:
        """(B, n) float64 distance matrix: query rows vs the first ``n`` codes.

        The decomposed form as one float32 gemm plus O(B·n) float64 combine
        against the cached per-vertex norms. Blocks of at least
        ``KERNEL_DISPATCH_MIN_ELEMS`` code elements are first offered to the
        Pallas ``quantized_l2`` kernel via :func:`_offload_distances` (TPU
        only; the numpy path below is the CPU fast path).
        """
        q2 = np.atleast_2d(np.asarray(queries, dtype=np.float64))
        if n == 0:
            return np.zeros((q2.shape[0], 0), dtype=np.float64)
        _M_DIST_EVALS.inc(q2.shape[0] * n)
        if n * self.dim >= KERNEL_DISPATCH_MIN_ELEMS:
            out = _offload_distances(
                q2, self._codes[:n], self._scales[:n], self._zps[:n],
                self._mids[:n],
            )
            if out is not None:
                out = np.asarray(out, dtype=np.float64)
                return np.maximum(out, 0.0, out=out)
        qsq = np.einsum("bd,bd->b", q2, q2)
        qsum = q2.sum(axis=1)
        dot = q2.astype(np.float32) @ self._codes[:n].astype(np.float32).T
        s = self._scales[:n]
        dist = (qsq[:, None] + self._norms[None, :n]) + 2.0 * (
            qsum[:, None] * self._cross[None, :n] - s[None, :] * dot
        )
        return np.maximum(dist, 0.0, out=dist)

    def batch_distances(self, query: np.ndarray) -> np.ndarray:
        """Distances from one or many queries to every vertex — the hot loop.

        A 1-D ``query`` returns the (N,) distances exactly as before; a
        (B, D) block returns the (B, N) matrix computed as one gemm through
        the kernel dispatch seam (see :meth:`_distance_block`). This matrix
        is what :meth:`insert_batch` reuses for candidate-vs-resident
        lookups during batched ingestion.
        """
        q = np.asarray(query, dtype=np.float64)
        if q.ndim <= 1:
            return self._distance_block(q.ravel(), self._n)[0]
        return self._distance_block(q, self._n)

    def nearest_live_batch(
        self, queries: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact nearest *live* vertex per query row (brute-force scan).

        Returns ``(vids, dists)``; ``vid == -1`` where the index holds no
        live vertex. The batched save path uses this instead of per-tensor
        graph walks: one (B, N) distance block through the dispatch seam
        replaces B independent HNSW descents (tombstoned vertices are
        masked, matching ``search``'s ``exclude_deleted`` contract).
        """
        q2 = np.atleast_2d(np.asarray(queries, dtype=np.float64))
        b = q2.shape[0]
        n = self._n
        if n == 0 or self.live_count == 0:
            return (
                np.full(b, -1, dtype=np.int64),
                np.full(b, np.inf, dtype=np.float64),
            )
        dist = self._distance_block(q2, n)
        dead = self._deleted[:n]
        if dead.any():
            dist = np.where(dead[None, :], np.inf, dist)
        vids = np.argmin(dist, axis=1).astype(np.int64)
        return vids, dist[np.arange(b), vids]

    # ---------------------------------------------------------------- search
    def _search_layer(
        self,
        q32: np.ndarray,
        qsq: float,
        qsum: float,
        entry: list[int],
        ef: int,
        layer: int,
        exclude_deleted: bool = False,
        drow: np.ndarray | None = None,
    ) -> list[tuple[float, int]]:
        """Best-first search on one layer; returns ef closest (dist, id).

        With ``exclude_deleted`` tombstoned vertices are traversed as
        waypoints but never admitted to the result heap (hnswlib's
        deleted-marker search). With the flag off — and whenever nothing
        is filtered — the loop is behaviorally identical to the seed
        implementation: until ``best`` holds ``ef`` elements it contains
        every accepted candidate, so no remaining candidate can exceed its
        maximum and the stop test cannot fire earlier than the seed's
        ``best and d > -best[0][0]``.

        ``drow`` is a precomputed distance row indexed by vertex id (the
        batched-ingest matrix): when given, every candidate distance is a
        lookup instead of a gemv and ``q32``/``qsq``/``qsum`` are unused.
        """
        self._epoch += 1
        epoch = self._epoch
        visited = self._vepoch
        dead = self._deleted
        entry_ids = np.asarray(entry, dtype=np.int64)
        visited[entry_ids] = epoch
        n_visited = entry_ids.size
        dists = (
            drow[entry_ids] if drow is not None
            else self._distances(q32, qsq, qsum, entry_ids)
        )
        cand: list[tuple[float, int]] = [(d, v) for d, v in zip(dists, entry)]
        heapq.heapify(cand)
        best: list[tuple[float, int]] = [
            (-d, v) for d, v in zip(dists, entry)
            if not (exclude_deleted and dead[v])
        ]
        heapq.heapify(best)
        while len(best) > ef:
            heapq.heappop(best)
        adj = self._neighbors[layer]
        while cand:
            d, v = heapq.heappop(cand)
            if len(best) >= ef and d > -best[0][0]:
                break
            nbrs = adj.get(v)
            if nbrs is None or nbrs.size == 0:
                continue
            fresh = nbrs[visited[nbrs] != epoch]
            if fresh.size == 0:
                continue
            visited[fresh] = epoch
            n_visited += fresh.size
            if drow is not None:
                # Batched-ingest fast path: lookup + vectorized bound filter.
                # The filter uses the bound at expansion start, so it admits
                # a superset of the sequential loop's pushes — the final
                # ``best`` (ef smallest of everything pushed) is identical;
                # only the exploration frontier can be marginally larger.
                fd = drow[fresh]
                if len(best) >= ef:
                    keep = fd < -best[0][0]
                    if not keep.all():
                        fresh = fresh[keep]
                        fd = fd[keep]
                for du, u in zip(fd.tolist(), fresh.tolist()):
                    heapq.heappush(cand, (du, u))
                    if not (exclude_deleted and dead[u]):
                        heapq.heappush(best, (-du, u))
                while len(best) > ef:
                    heapq.heappop(best)
                continue
            fd = self._distances(q32, qsq, qsum, fresh)
            bound = -best[0][0] if best else math.inf
            for du, u in zip(fd, fresh):
                if len(best) < ef or du < bound:
                    heapq.heappush(cand, (du, u))
                    if not (exclude_deleted and dead[u]):
                        heapq.heappush(best, (-du, u))
                        if len(best) > ef:
                            heapq.heappop(best)
                        bound = -best[0][0]
        _M_VISITED.inc(n_visited)
        return sorted((-nd, int(v)) for nd, v in best)

    def search(
        self,
        query: np.ndarray,
        k: int = 1,
        ef: int | None = None,
        exclude_deleted: bool = True,
    ) -> list[tuple[float, int]]:
        """Approximate k-NN of a float query; returns [(sq_dist, vertex_id)].

        Tombstoned vertices are excluded from the results (but still guide
        the descent); pass ``exclude_deleted=False`` to search the raw
        graph. Returns ``[]`` when every reachable vertex is dead.
        """
        _M_SEARCHES.inc()
        if self._entry is None:
            return []
        ef = max(ef or self.ef_construction, k)
        q = np.asarray(query, dtype=np.float64).ravel()
        q32 = q.astype(np.float32)
        qsq = float(np.dot(q, q))
        qsum = float(q.sum())
        entry = [self._entry]
        for layer in range(self._max_level, 0, -1):
            # Upper-layer descent keeps dead vertices: they are waypoints.
            entry = [self._search_layer(q32, qsq, qsum, entry, 1, layer)[0][1]]
        return self._search_layer(
            q32, qsq, qsum, entry, ef, 0, exclude_deleted=exclude_deleted
        )[:k]

    # ---------------------------------------------------------------- insert
    def _select_neighbors(self, cands: list[tuple[float, int]], m: int) -> list[int]:
        return [v for _, v in sorted(cands)[:m]]

    def insert(self, tensor: np.ndarray) -> int:
        """Quantize ``tensor`` to 8 bits and insert as a new vertex.

        Returns the vertex id. The stored representation is the quantized
        code; callers needing the de-quantized base use
        :meth:`dequantize_vertex`.
        """
        q = np.asarray(tensor, dtype=np.float64).ravel()
        assert q.size == self.dim, (q.size, self.dim)
        codes, meta = quantize_linear(q, nbit=8)
        vid = self._n
        self._grow(vid + 1)
        self._codes[vid] = codes
        self._scales[vid] = meta.scale
        self._zps[vid] = meta.zero_point
        self._mids[vid] = meta.mid
        self._norms[vid] = _code_norms(
            codes, meta.scale, meta.zero_point, meta.mid, self.dim
        )[0]
        self._cross[vid] = (
            -meta.mid if meta.scale == 0.0 else meta.scale * meta.zero_point
        )
        self._n = vid + 1
        _M_INSERTS.inc()
        level = self._draw_level()
        self._register_level(vid, level)

        if self._entry is None:
            self._entry = vid
            self._max_level = level
            return vid
        self._link(vid, level, q)
        return vid

    def _draw_level(self) -> int:
        return int(-math.log(max(self._rng.random(), 1e-12)) * self.ml)

    def _register_level(self, vid: int, level: int) -> None:
        self._levels.append(level)
        while len(self._neighbors) <= level:
            self._neighbors.append({})
        for layer in range(level + 1):
            self._neighbors[layer].setdefault(vid, _EMPTY_IDS)

    def _shrink_query(self, u: int, shared: dict | None):
        """(q32, qsq, qsum) for vertex ``u``'s dequantized base, cached per
        batch: many batch members backlink into the same hub vertices, so
        the O(D) dequantize is paid once per hub per ``insert_batch``."""
        if shared is not None:
            hit = shared["deq"].get(u)
            if hit is not None:
                return hit
        base_u = self.dequantize_vertex(u)
        stats = (
            base_u.astype(np.float32),
            float(np.dot(base_u, base_u)),
            float(base_u.sum()),
        )
        if shared is not None:
            shared["deq"][u] = stats
        return stats

    @staticmethod
    def _append_id(cur: np.ndarray, vid: int) -> np.ndarray:
        lst = np.empty(cur.size + 1, dtype=np.int64)
        lst[:-1] = cur
        lst[-1] = vid
        return lst

    def _backlink_batch(
        self, layer: int, vid: int, nbrs, adj: dict, m: int, shared: dict
    ) -> None:
        """Backlink ``vid`` into its selected neighbors — batched shrink.

        Once a vertex has been shrunk its list sits exactly at the degree
        cap, so every later backlink appends one id; the cached post-shrink
        distances (``shared['nbr']``) are extended with a single new pair
        distance instead of recomputing the whole deq(u)-vs-list row — and
        those pair distances are computed for ALL cache-hit neighbors of
        this link in one (k, D) gemv against ``vid``'s codes. This was the
        dominant cost of naive batched linking (every backlink paid a full
        gather + gemv, ~half the insert_batch wall time).
        """
        nbr_cache = shared["nbr"]
        deq = shared["deq"]
        hits: list[tuple[int, np.ndarray, np.ndarray]] = []
        for u in nbrs:
            cur = adj.get(u, _EMPTY_IDS)
            if cur.size < m:  # under cap: plain append, no shrink
                adj[u] = self._append_id(cur, vid)
                continue
            hit = nbr_cache.get((layer, u))
            if hit is not None and hit[0] is cur:
                hits.append((u, cur, hit[1]))
                continue
            # First shrink of u this batch: full row, seeds both caches.
            lst = self._append_id(cur, vid)
            u32, usq, usum = self._shrink_query(u, shared)
            du = self._distances(u32, usq, usum, lst)
            order = np.argsort(du)[:m]
            lst = lst[order]
            nbr_cache[(layer, u)] = (lst, du[order])
            adj[u] = lst
        if not hits:
            return
        cv = self._codes[vid].astype(np.float32)
        u32s = np.stack([deq[u][0] for u, _, _ in hits])
        dots = u32s @ cv  # (k,) — one gemv for every cache-hit shrink
        nv = float(self._norms[vid])
        crv = float(self._cross[vid])
        sv = float(self._scales[vid])
        for (u, cur, cached), dot in zip(hits, dots.tolist()):
            _u32, usq, usum = deq[u]
            d = (usq + nv) + 2.0 * (usum * crv - sv * dot)
            du = np.empty(cached.size + 1)
            du[:-1] = cached
            du[-1] = d if d > 0.0 else 0.0
            lst = self._append_id(cur, vid)
            order = np.argsort(du)[:m]
            lst = lst[order]
            nbr_cache[(layer, u)] = (lst, du[order])
            adj[u] = lst

    def _link(
        self,
        vid: int,
        level: int,
        q: np.ndarray,
        drow: np.ndarray | None = None,
        shared: dict | None = None,
    ) -> None:
        """Wire ``vid`` into the graph (the second half of ``insert``).

        Sequential path (``shared is None``): per-item greedy descent from
        the global entry through the upper layers — behaviorally identical
        to the seed insert. Batched path: the upper-layer descent is shared
        across the batch (:meth:`_batch_chain`) and every candidate
        distance is a lookup into ``drow``, the batch-wide matrix from
        :meth:`_distance_block`.
        """
        q32 = q.astype(np.float32)
        qsq = float(np.dot(q, q))
        qsum = float(q.sum())
        if shared is None:
            entry = [self._entry]
            for layer in range(self._max_level, level, -1):
                entry = [
                    self._search_layer(q32, qsq, qsum, entry, 1, layer,
                                       drow=drow)[0][1]
                ]
        else:
            entry = [self._batch_chain(shared)[min(level, self._max_level)]]
        for layer in range(min(level, self._max_level), -1, -1):
            cands = self._search_layer(
                q32, qsq, qsum, entry, self.ef_construction, layer, drow=drow
            )
            m = self.m0 if layer == 0 else self.m
            nbrs = self._select_neighbors(cands, m)
            adj = self._neighbors[layer]
            adj[vid] = np.asarray(nbrs, dtype=np.int64)
            if shared is not None:
                self._backlink_batch(layer, vid, nbrs, adj, m, shared)
            else:
                for u in nbrs:
                    lst = np.append(adj.get(u, _EMPTY_IDS), vid)
                    if lst.size > m:
                        # Shrink: keep the m closest to u.
                        u32, usq, usum = self._shrink_query(u, None)
                        du = self._distances(u32, usq, usum, lst)
                        lst = lst[np.argsort(du)[:m]]
                    adj[u] = lst
            entry = [v for _, v in cands]
        if level > self._max_level:
            self._max_level = level
            self._entry = vid

    def _batch_chain(self, shared: dict) -> dict[int, int]:
        """Per-layer entry points from ONE shared descent over the batch
        centroid. ``chain[L]`` is the vertex a layer-``L`` search starts
        from: the greedy nearest to the centroid on layer ``L+1`` (the
        global entry at the top) — the batched stand-in for the per-item
        upper-layer descent. Recomputed only when the graph's entry point
        or max level moves mid-batch (a batch member drew a higher level).
        """
        key = (self._entry, self._max_level)
        if shared.get("key") != key:
            c32, csq, csum = shared["centroid"]
            chain = {self._max_level: self._entry}
            e = [self._entry]
            for layer in range(self._max_level, 0, -1):
                e = [self._search_layer(c32, csq, csum, e, 1, layer)[0][1]]
                chain[layer - 1] = e[0]
            shared["chain"] = chain
            shared["key"] = key
        return shared["chain"]

    def insert_batch(
        self,
        tensors,
        quantized: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray] | None = None,
        max_matrix_elems: int = 1 << 24,
    ) -> list[int]:
        """Insert a batch of same-dim tensors; returns their vertex ids.

        The batched ingest path (ISSUE 3 tentpole):

        1. **one quantization sweep** — all candidates go through
           ``quantize_linear_batch`` (bit-exact with per-tensor
           ``quantize_linear``), or arrive pre-quantized via ``quantized``
           when the engine already swept the group;
        2. **bulk vertex append** — one ``_grow`` + vectorized norm/cross
           computation for the whole batch;
        3. **one shared entry-point descent** per batch at the upper layers
           (:meth:`_batch_chain`, recomputed only when the entry moves);
        4. **sequential layer-0 linking** that reuses a batch-wide
           ``batch_distances`` matrix of candidate-vs-resident codes: every
           per-candidate distance in the layer searches is an O(1) lookup
           into one (B, N) gemm computed through the kernel dispatch seam
           (Pallas ``quantized_l2`` on TPU, decomposed numpy gemm on CPU).

        The graph that results is *not* edge-identical to sequential
        ``insert`` (the shared descent starts items from the centroid's
        entry chain), but recall parity is held within tolerance —
        ``tests/test_batch_ingest.py::test_insert_batch_recall_parity``.
        Level draws consume the RNG in the same per-item order as
        sequential inserts.

        ``max_matrix_elems`` bounds the resident distance matrix: batches
        are chunked so no (rows × cols) block exceeds it (~128 MB float64
        at the default), keeping memory flat for large ingests.
        """
        if isinstance(tensors, np.ndarray) and tensors.ndim == 2:
            q_all = np.asarray(tensors, dtype=np.float64)
        else:
            rows = [np.asarray(t, dtype=np.float64).ravel() for t in tensors]
            if not rows:
                return []
            q_all = np.stack(rows)
        b = q_all.shape[0]
        if b == 0:
            return []
        assert q_all.shape[1] == self.dim, (q_all.shape, self.dim)

        if quantized is None:
            codes, scales, zps, mids = quantize_linear_batch(q_all, nbit=8)
        else:
            codes, scales, zps, mids = quantized
        n0 = self._n
        _M_INSERTS.inc(b)
        self._grow(n0 + b)
        self._codes[n0:n0 + b] = codes
        self._scales[n0:n0 + b] = scales
        self._zps[n0:n0 + b] = zps
        self._mids[n0:n0 + b] = mids
        self._norms[n0:n0 + b] = _code_norms(codes, scales, zps, mids, self.dim)
        cross = scales * np.asarray(zps, dtype=np.float64)
        const = scales == 0.0
        if const.any():
            cross = np.where(const, -np.asarray(mids, dtype=np.float64), cross)
        self._cross[n0:n0 + b] = cross
        self._n = n0 + b

        levels = [self._draw_level() for _ in range(b)]
        for i, level in enumerate(levels):
            self._register_level(n0 + i, level)

        centroid = q_all.mean(axis=0)
        shared = {
            "deq": {},
            "nbr": {},
            "centroid": (
                centroid.astype(np.float32),
                float(np.dot(centroid, centroid)),
                float(centroid.sum()),
            ),
        }
        # Chunked batch-wide distance matrix: chunk rows are sized so the
        # (rows, n0 + chunk_end) block stays under max_matrix_elems. During
        # item i's linking every candidate id is < n0 + i (links to a batch
        # member only exist once it has been linked), so a chunk's columns
        # only need to reach its own end.
        start = 0
        while start < b:
            # Chunk rows sized against the chunk's OWN column count
            # (n0 + start + rows): rows² + (n0+start)·rows ≤ budget.
            base_cols = n0 + start
            rows_per_chunk = int(
                (math.sqrt(base_cols * base_cols + 4.0 * max_matrix_elems)
                 - base_cols) / 2.0
            )
            end = min(b, start + max(1, rows_per_chunk))
            ncols = n0 + end
            dmat = self._distance_block(q_all[start:end], ncols)
            for i in range(start, end):
                vid = n0 + i
                if self._entry is None:
                    self._entry = vid
                    self._max_level = levels[i]
                    continue
                self._link(vid, levels[i], q_all[i],
                           drow=dmat[i - start], shared=shared)
            start = end
        return list(range(n0, n0 + b))

    # ------------------------------------------------------------ compaction
    def clone(self) -> "HNSWIndex":
        """Deep copy for copy-on-write compaction.

        Vacuum compacts the clone and installs it as the resident index;
        the original object — shared with snapshot readers that captured
        it at load time — is never restructured, so their
        :meth:`vertex_codes` reads stay valid without any lock. (Like
        eviction+reload, the clone restarts the level RNG; graph shape
        after later inserts may differ, data never does.)
        """
        return HNSWIndex.from_bytes(self.to_bytes())

    def compact(self) -> dict[int, int]:
        """Drop tombstoned vertices; returns the old→new vertex-id remap.

        Before any vertex is removed, the live neighbors of each dead
        vertex are cross-linked (edge contraction, shrunk back to the
        layer's degree cap by distance-to-endpoint) so that deleting a
        waypoint does not disconnect the survivors. Vertex codes and
        quantization metadata rows are copied verbatim, so
        :meth:`dequantize_vertex` output for every surviving vertex is
        bit-identical across compaction — the engine relies on this for
        its vacuum parity bar.
        """
        n = self._n
        dead = self._deleted[:n]
        live_old = np.flatnonzero(~dead)
        remap = {int(o): i for i, o in enumerate(live_old.tolist())}
        if live_old.size == n:
            return remap  # no tombstones — identity remap, nothing rebuilt

        # 1) Edge contraction: connected components of dead vertices are
        #    collapsed at once, so live regions bridged only by a *chain*
        #    of dead waypoints stay connected (single-hop contraction
        #    would strand them). Each component's full live boundary is
        #    cross-linked, shrunk back to the degree cap by distance.
        for layer, adj in enumerate(self._neighbors):
            cap = self.m0 if layer == 0 else self.m
            parent: dict[int, int] = {}

            def _find(x: int) -> int:
                while parent[x] != x:
                    parent[x] = parent[parent[x]]
                    x = parent[x]
                return x

            boundary: dict[int, set[int]] = {}
            for v, nbrs in adj.items():
                if not dead[v]:
                    continue
                parent.setdefault(v, v)
                if not nbrs.size:
                    continue
                for u in nbrs[dead[nbrs]].tolist():
                    parent.setdefault(u, u)
                    ru, rv = _find(u), _find(v)
                    if ru != rv:
                        parent[ru] = rv
            for v, nbrs in adj.items():
                if not dead[v] or not nbrs.size:
                    continue
                live = nbrs[~dead[nbrs]]
                if live.size:
                    boundary.setdefault(_find(v), set()).update(live.tolist())
            for live_set in boundary.values():
                if len(live_set) < 2:
                    continue
                live_arr = np.fromiter(live_set, dtype=np.int64)
                for u in live_set:
                    extra = live_arr[live_arr != u]
                    cur = adj.get(u, _EMPTY_IDS)
                    if cur.size:
                        cur = cur[~dead[cur]]
                    merged = np.unique(np.concatenate([cur, extra]))
                    merged = merged[merged != u]
                    if merged.size > cap:
                        base_u = self.dequantize_vertex(u)
                        du = self._distances(
                            base_u.astype(np.float32),
                            float(np.dot(base_u, base_u)),
                            float(base_u.sum()),
                            merged,
                        )
                        merged = merged[np.argsort(du)[:cap]]
                    adj[u] = merged.astype(np.int64)

        # 2) Rebuild vertex arrays: copy surviving rows (codes verbatim).
        nlive = int(live_old.size)
        self._codes = self._codes[live_old]
        self._scales = self._scales[live_old]
        self._zps = self._zps[live_old]
        self._mids = self._mids[live_old]
        self._norms = self._norms[live_old]
        self._cross = self._cross[live_old]
        self._vepoch = np.zeros(nlive, dtype=np.int64)
        self._epoch = 0
        self._deleted = np.zeros(nlive, dtype=bool)
        self._levels = [self._levels[int(o)] for o in live_old]
        self._n = nlive
        self._cap = nlive

        # 3) Rebuild adjacency in the new id space, dropping dead vertices.
        lut = np.full(n, -1, dtype=np.int64)
        lut[live_old] = np.arange(nlive, dtype=np.int64)
        new_layers: list[dict[int, np.ndarray]] = []
        for adj in self._neighbors:
            nl: dict[int, np.ndarray] = {}
            for v, nbrs in adj.items():
                if dead[v]:
                    continue
                if nbrs.size:
                    mapped = lut[nbrs[~dead[nbrs]]].astype(np.int64)
                else:
                    mapped = _EMPTY_IDS
                nl[int(lut[v])] = mapped
            new_layers.append(nl)
        while new_layers and not new_layers[-1]:
            new_layers.pop()
        self._neighbors = new_layers

        # 4) New entry point: lowest-id survivor on the highest level.
        if nlive == 0:
            self._entry = None
            self._max_level = -1
            self._neighbors = []
        else:
            self._max_level = max(self._levels)
            self._entry = self._levels.index(self._max_level)
        return remap

    # ------------------------------------------------------------- serialize
    def to_bytes(self) -> bytes:
        n = self._n
        state = {
            "dim": self.dim,
            "m": self.m,
            "ef_construction": self.ef_construction,
            "codes": self._codes[:n].copy(),
            "scales": self._scales[:n].copy(),
            "zps": self._zps[:n].copy(),
            "mids": self._mids[:n].copy(),
            "norms": self._norms[:n].copy(),
            "deleted": self._deleted[:n].copy(),
            "levels": self._levels,
            "neighbors": [
                {int(k): v.tolist() for k, v in layer.items()}
                for layer in self._neighbors
            ],
            "entry": self._entry,
            "max_level": self._max_level,
        }
        return pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def from_bytes(cls, data: bytes) -> "HNSWIndex":
        state = pickle.loads(data)
        idx = cls(state["dim"], state["m"], state["ef_construction"])
        n = len(state["levels"])
        idx._grow(n)
        idx._codes[:n] = state["codes"]
        idx._scales[:n] = state["scales"]
        idx._zps[:n] = state["zps"]
        idx._mids[:n] = state["mids"]
        idx._n = n
        norms = state.get("norms")
        if norms is not None:
            idx._norms[:n] = norms
        elif n:
            # Seed-format pickle: rebuild the cached norms from the codes.
            idx._norms[:n] = _code_norms(
                state["codes"], idx._scales[:n], idx._zps[:n],
                idx._mids[:n], idx.dim,
            )
        deleted = state.get("deleted")
        if deleted is not None:
            # Pre-tombstone pickles carry no flags: every vertex is live.
            idx._deleted[:n] = deleted
        # cross_i is derived (never serialized): s·z, or −mid on const rows.
        s = idx._scales[:n]
        cross = s * idx._zps[:n].astype(np.float64)
        const = s == 0.0
        cross[const] = -idx._mids[:n][const]
        idx._cross[:n] = cross
        idx._levels = state["levels"]
        idx._neighbors = [
            {int(k): np.asarray(v, dtype=np.int64) for k, v in layer.items()}
            for layer in state["neighbors"]
        ]
        idx._entry = state["entry"]
        idx._max_level = state["max_level"]
        return idx
