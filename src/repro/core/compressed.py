"""Compressed-domain runtime adapter: serve matmuls straight off a snapshot.

:class:`CompressedModel` maps :meth:`LoadedModel.compressed_params` output
(int8-recentred base codes + quantized deltas, including the int4-packed
flexible-loading form at ``bits=4``) directly into the layout the fused
``dequant_matmul`` kernels expect — the full-precision weight is never
materialized. The handle's buffer-pool frame stays pinned for the life of
the serving session (snapshot semantics, see ``docs/concurrency.md``), so
repeated decode steps read codes zero-copy from the pool.

Operand-normalization details the kernels don't know about live here:

* **constant base** (``base_scale == 0``): the stored codes are all zero
  (recentred: −128) and the value lives in ``base_mid``. The kernel
  formula ``(c − bz)·bs`` reproduces it exactly with ``bz = −129``,
  ``bs = mid``.
* **zero-bit delta** (``nbit == 0``, range ≤ 2p): bin-centre dequant
  ``(q − dz + 0.5)·ds`` must yield ``delta_mid``; with all-zero codes
  that is ``dz = code_value, ds = 2·mid``.
* **int4 packing**: deltas at ``nbit <= 4`` with even K pack two unsigned
  nibble codes per byte (``kernels.ops.pack_int4`` layout) — 1.5 HBM
  bytes per weight element on TPU instead of 2.0.

Bytes-moved accounting (``counters``) charges each matmul its *weight
operand* traffic — the quantity the compressed path exists to shrink —
plus per-row traffic for embedding gathers.
"""

from __future__ import annotations

import math

import numpy as np

from ..kernels.ops import KERNEL_DISPATCH_MIN_ELEMS, dequant_matmul_auto, pack_int4
from .loader import KernelNotReady, LoadedModel

__all__ = ["CompressedModel", "CompressedTensor", "KernelNotReady"]


class CompressedTensor:
    """One weight's kernel-ready operands, built once per serving session."""

    __slots__ = ("name", "shape", "k", "n", "packed", "base", "delta",
                 "base_scale", "base_zp", "delta_scale", "delta_zp",
                 "operand_nbytes", "scratch")

    def __init__(self, name: str, entry: dict):
        shape = entry["shape"]
        if len(shape) < 2:
            raise ValueError(
                f"tensor {name!r}: matmul weights need >= 2 dims, got {shape}")
        self.name = name
        self.shape = tuple(shape)
        self.k = shape[0]
        self.n = int(math.prod(shape[1:]))
        self.base = entry["base_codes"].reshape(self.k, self.n)
        if entry["base_scale"] == 0.0:
            self.base_scale = float(entry["base_mid"])
            self.base_zp = -129.0
        else:
            self.base_scale = float(entry["base_scale"])
            self.base_zp = float(entry["base_zp"])
        nbit = entry["nbit"]
        self.packed = bool(nbit <= 4 and self.k % 2 == 0)
        if self.packed:
            # Unsigned nibble codes + unsigned zero-point (int4 kernel form).
            self.delta = pack_int4(entry["qdelta"].reshape(self.k, self.n))
            if nbit == 0:
                self.delta_scale = 2.0 * float(entry["delta_mid"])
                self.delta_zp = 0.0
            else:
                self.delta_scale = float(entry["delta_scale"])
                self.delta_zp = float(entry["delta_zp"])
        else:
            self.delta = entry["qdelta_i8"].reshape(self.k, self.n)
            if nbit == 0:
                self.delta_scale = 2.0 * float(entry["delta_mid"])
                self.delta_zp = -128.0
            else:
                self.delta_scale = float(entry["delta_scale"])
                self.delta_zp = float(entry["delta_zp_i8"])
        self.operand_nbytes = self.base.nbytes + self.delta.nbytes
        self.scratch: dict = {}


class CompressedModel:
    """Serve a :class:`LoadedModel` without materializing float weights.

    ``matmul(x, name)`` routes through ``kernels.ops.dequant_matmul_auto``
    (Pallas on TPU, decomposed gemm on CPU); ``gather_rows`` dequantizes
    only the requested embedding rows; ``vector`` reconstructs small
    tensors (norm gains) via ``tensor(name)``. Requires a kernel-ready
    handle — open it with ``load_model(name, bits=8)`` (or ``bits=4``);
    full-precision handles raise :class:`KernelNotReady` on first use.
    """

    def __init__(self, lm: LoadedModel, *,
                 min_elems: int = KERNEL_DISPATCH_MIN_ELEMS,
                 force: str | None = None):
        self.lm = lm
        self.params = lm.compressed_params()
        self.min_elems = min_elems
        self.force = force
        self._weights: dict[str, CompressedTensor] = {}
        self._vectors: dict[str, np.ndarray] = {}
        self.counters = {"matmul_calls": 0, "gather_calls": 0,
                         "bytes_moved": 0, "fused_elems": 0}
        #: Names whose bytes were served through the kernel seam — the
        #: zero-materialize acceptance test asserts ``materialize()`` /
        #: ``tensor()`` are never called for these.
        self.kernel_served: set[str] = set()

    # ------------------------------------------------------------- weights
    def weight(self, name: str) -> CompressedTensor:
        w = self._weights.get(name)
        if w is None:
            entry = self.params.kernel_operands(name)
            w = self._weights[name] = CompressedTensor(name, entry)
            self.kernel_served.add(name)
        return w

    def matmul(self, x: np.ndarray, name: str) -> np.ndarray:
        """``x @ dq(weight)`` on compressed operands; (M, K) → (M, N)."""
        w = self.weight(name)
        y = dequant_matmul_auto(
            x, w.base, w.base_scale, w.base_zp, w.delta, w.delta_scale,
            w.delta_zp, packed=w.packed, min_elems=self.min_elems,
            force=self.force, scratch=w.scratch)
        c = self.counters
        c["matmul_calls"] += 1
        c["bytes_moved"] += w.operand_nbytes
        c["fused_elems"] += w.k * w.n
        return y

    def bytes_per_weight(self, name: str) -> float:
        """Kernel-operand bytes per weight element (2.0 int8, 1.5 int4)."""
        w = self.weight(name)
        return w.operand_nbytes / (w.k * w.n)

    # ------------------------------------------------- row-wise access
    def gather_rows(self, name: str, ids: np.ndarray) -> np.ndarray:
        """Dequantize only the gathered rows (compressed-domain embedding
        lookup) — never the full (V, d) table."""
        entry = self.params[name]
        ids = np.asarray(ids)
        codes = entry["base_codes"].reshape(entry["shape"][0], -1)[ids]
        if entry["base_scale"] == 0.0:
            base = np.full(codes.shape, float(entry["base_mid"]), np.float32)
        else:
            base = ((codes.astype(np.float32) - entry["base_zp"])
                    * entry["base_scale"])
        q = entry["qdelta"].reshape(entry["shape"][0], -1)[ids]
        nbit = entry["nbit"]
        if nbit == 0:
            delta = np.full(q.shape, float(entry["delta_mid"]), np.float32)
        else:
            delta = ((q.astype(np.float32) - entry["delta_zp"] + 0.5)
                     * entry["delta_scale"])
        self.kernel_served.add(name)
        c = self.counters
        c["gather_calls"] += 1
        c["bytes_moved"] += codes.nbytes + int(q.size * nbit / 8)
        return (base + delta).astype(np.float32)

    def vector(self, name: str) -> np.ndarray:
        """Small tensors (norm gains, biases): full reconstruct, cached."""
        v = self._vectors.get(name)
        if v is None:
            v = self._vectors[name] = self.lm.tensor(name)
        return v

    # ------------------------------------------------------------ lifecycle
    def reset_counters(self) -> None:
        for key in self.counters:
            self.counters[key] = 0

    def close(self) -> None:
        self.lm.close()
