"""Fault-injection I/O shim — every engine file access goes through here.

PR 2's failpoints simulate crashes *between* protocol steps; this module
generalizes them to the I/O layer itself: EIO, short (torn) writes, silent
bit flips, and crash-at-fsync, injectable at any individual I/O call the
storage engine makes. The engine, catalog, index cache and buffer-pool
loader all route their file access through a :class:`FaultFS` instance, so
a deterministic :class:`FaultPlan` can damage exactly the n-th I/O call of
a workload — the randomized campaign in ``tests/test_faultfs.py`` sweeps
hundreds of (call, fault-kind) schedules and asserts the store always
reopens consistent or quarantines, never serves silently wrong bytes.

Call sites are tagged (``site="page.write"``, ``"journal.append"``,
``"meta.replace"``, …) so plans can target one subsystem; with
``site=None`` every faultable call counts. A plain ``FaultFS()`` injects
nothing and adds one integer compare per call — production overhead is
noise (the durability benchmark measures the whole stack).

Durability discipline lives here too: :meth:`FaultFS.write_durable` is
write → flush → fsync(file) → fsync(directory), and :meth:`replace` fsyncs
the destination directory, so a committed rename survives a power cut of
the directory inode as well as the file (the classic "fsync the parent"
rule; see ``docs/durability.md``).
"""

from __future__ import annotations

import dataclasses
import errno
import os

__all__ = ["FaultFS", "FaultPlan", "FaultCrash", "FaultInjected", "FAULT_KINDS"]

# Injectable fault kinds (see FaultFS method docstrings for per-op mapping):
#   eio          — the call fails with OSError(EIO); process keeps running
#   short_write  — half the bytes land durably, then the process "crashes"
#   bitflip      — one bit of the data is flipped silently (call succeeds)
#   crash        — the process "crashes" before the call does anything
#   crash_fsync  — data is written but the crash lands at the fsync
FAULT_KINDS = ("eio", "short_write", "bitflip", "crash", "crash_fsync")


class FaultInjected(OSError):
    """An injected I/O error (EIO). The process survives; the op fails."""

    def __init__(self, site: str, op: str):
        super().__init__(errno.EIO, f"injected EIO at {op} [{site}]")
        self.site = site


class FaultCrash(RuntimeError):
    """A simulated process crash mid-I/O.

    Tests treat this like :class:`~repro.core.catalog.InjectedCrash`:
    abandon the engine object and reopen the store from disk.
    """


@dataclasses.dataclass
class FaultPlan:
    """Deterministic schedule: inject ``kind`` at the ``at_call``-th call.

    ``at_call`` is 1-based over the faultable calls a :class:`FaultFS`
    sees; when ``site`` is set, only calls whose site starts with it are
    counted (and faulted). ``bit`` picks which bit a ``bitflip`` damages
    (taken modulo the data length at injection time).
    """

    at_call: int
    kind: str
    site: str | None = None
    bit: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


def _flip_bit(data: bytes, bit: int) -> bytes:
    if not data:
        return data
    out = bytearray(data)
    i = (bit // 8) % len(out)
    out[i] ^= 1 << (bit % 8)
    return bytes(out)


def _fsync_dir(path: str) -> None:
    """fsync the directory containing ``path`` (best-effort off-POSIX)."""
    try:
        fd = os.open(os.path.dirname(os.path.abspath(path)) or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class FaultFS:
    """File-access shim with deterministic fault injection.

    With no plan it is a transparent passthrough that also *counts* calls
    — the campaign first runs a workload fault-free to learn how many
    faultable I/O calls it makes, then sweeps plans over that range.
    ``log`` records ``(op, site)`` per call when ``record=True``.
    """

    def __init__(self, plan: FaultPlan | None = None, record: bool = False):
        self.plan = plan
        self.calls = 0
        self.injected: tuple[str, str, str] | None = None  # (kind, op, site)
        self.log: list[tuple[str, str]] = [] if record else None

    # ------------------------------------------------------------- schedule
    def _tick(self, op: str, site: str) -> str | None:
        """Count one faultable call; return a fault kind to inject, if any."""
        if self.log is not None:
            self.log.append((op, site))
        plan = self.plan
        if plan is not None and plan.site is not None \
                and not site.startswith(plan.site):
            return None
        self.calls += 1
        if plan is not None and self.calls == plan.at_call:
            self.injected = (plan.kind, op, site)
            return plan.kind
        return None

    # ----------------------------------------------------------------- read
    def read_bytes(self, path: str, site: str = "read") -> bytes:
        """Read a whole file. Faults: eio, bitflip (transient, in-memory),
        crash; short_write degrades to eio (a read cannot tear the disk)."""
        kind = self._tick("read", site)
        if kind in ("eio", "short_write"):
            raise FaultInjected(site, "read")
        if kind == "crash":
            raise FaultCrash(f"injected crash before read [{site}]")
        with open(path, "rb") as f:
            data = f.read()
        if kind == "bitflip":
            data = _flip_bit(data, self.plan.bit)
        return data

    def read_text(self, path: str, site: str = "read") -> str:
        return self.read_bytes(path, site).decode("utf-8")

    def open(self, path: str, mode: str = "rb", site: str = "open"):
        """Open for streaming access (header-only page scans). Faults: eio
        and crash only — streamed bytes are not individually damaged."""
        kind = self._tick("open", site)
        if kind == "eio":
            raise FaultInjected(site, "open")
        if kind == "crash":
            raise FaultCrash(f"injected crash before open [{site}]")
        return open(path, mode)

    # ---------------------------------------------------------------- write
    def _write(self, path: str, data: bytes, mode: str, site: str) -> None:
        kind = self._tick("write", site)
        if kind == "eio":
            raise FaultInjected(site, "write")
        if kind == "crash":
            raise FaultCrash(f"injected crash before write [{site}]")
        if kind == "bitflip":
            data = _flip_bit(data, self.plan.bit)
        with open(path, mode) as f:
            if kind == "short_write":
                f.write(data[: max(1, len(data) // 2)])
                f.flush()
                os.fsync(f.fileno())
                raise FaultCrash(f"injected crash after short write [{site}]")
            f.write(data)
            f.flush()
            if kind == "crash_fsync":
                raise FaultCrash(f"injected crash at fsync [{site}]")
            os.fsync(f.fileno())
        _fsync_dir(path)

    def write_durable(self, path: str, data: bytes, site: str = "write") -> None:
        """Overwrite ``path`` durably (write → fsync file → fsync dir)."""
        self._write(path, data, "wb", site)

    def append_durable(self, path: str, text: str, site: str = "append") -> None:
        """Append ``text`` durably (the journal's fsync'd record append)."""
        self._write(path, text.encode("utf-8"), "ab", site)

    # ------------------------------------------------------------- metadata
    def replace(self, src: str, dst: str, site: str = "replace") -> None:
        """Atomic rename + destination-directory fsync. Faults: eio;
        crash/short_write before the rename; crash_fsync/bitflip *after*
        it (the rename happened but the crash preempts what follows)."""
        kind = self._tick("replace", site)
        if kind == "eio":
            raise FaultInjected(site, "replace")
        if kind in ("crash", "short_write"):
            raise FaultCrash(f"injected crash before replace [{site}]")
        os.replace(src, dst)
        if kind in ("crash_fsync", "bitflip"):
            raise FaultCrash(f"injected crash after replace [{site}]")
        _fsync_dir(dst)

    def unlink(self, path: str, site: str = "unlink") -> None:
        kind = self._tick("unlink", site)
        if kind == "eio":
            raise FaultInjected(site, "unlink")
        if kind in ("crash", "short_write"):
            raise FaultCrash(f"injected crash before unlink [{site}]")
        os.unlink(path)

    def truncate(self, path: str, size: int, site: str = "truncate") -> None:
        """Truncate ``path`` to ``size`` bytes durably (torn-tail repair)."""
        kind = self._tick("truncate", site)
        if kind == "eio":
            raise FaultInjected(site, "truncate")
        if kind in ("crash", "short_write"):
            raise FaultCrash(f"injected crash before truncate [{site}]")
        with open(path, "r+b") as f:
            f.truncate(size)
            f.flush()
            if kind == "crash_fsync":
                raise FaultCrash(f"injected crash at fsync [{site}]")
            os.fsync(f.fileno())
