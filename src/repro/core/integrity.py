"""Storage integrity primitives: typed corruption errors + checksum framing.

NeurStore's pitch is that the database — not the filesystem — owns model
weights, which makes integrity table stakes: one flipped bit in a shared
base tensor silently corrupts every fine-tune that references it. This
module centralizes the on-disk integrity vocabulary the whole stack uses
(see ``docs/durability.md`` for the end-to-end contract):

* **Typed errors.** Every detected-corruption path raises a subclass of
  :class:`IntegrityError`, never a bare ``ValueError``/``struct.error``,
  so callers can distinguish "bad bytes on disk" from programming errors.
  :class:`CorruptPageError` additionally subclasses ``ValueError`` for
  backward compatibility with pre-integrity callers that caught that.
* **CRC32 checksums** (``zlib.crc32`` — detects all single-bit flips and
  any burst ≤ 32 bits). Tensor pages carry per-record checksums plus a
  header-table checksum (``repro.core.pages`` format v3); journal records
  and the ``meta.json`` snapshot embed a ``crc`` field over their own
  canonical JSON; HNSW index files are wrapped in the framed envelope
  below.
* **Index framing.** ``HNSWIndex.to_bytes`` is a pickle — a flipped bit
  can make ``pickle.loads`` return silently wrong vertex codes, which is
  the worst possible failure (every delta decoded against a wrong base).
  :func:`frame_index` prefixes magic + length + CRC so the payload is
  verified *before* it ever reaches the unpickler; legacy unframed files
  (pickle protocol-2 ``b"\\x80"`` prefix) pass through unverified.

The checksum write side is cheap (one CRC pass at memory bandwidth); the
read side is gated by ``StorageEngine(checksums=...)`` so the durability
benchmark can measure the verify overhead honestly.
"""

from __future__ import annotations

import json
import struct
import zlib

__all__ = [
    "IntegrityError",
    "CorruptPageError",
    "CorruptIndexError",
    "CorruptJournalError",
    "CorruptMetaError",
    "ReadOnlyStoreError",
    "crc32",
    "frame_index",
    "unframe_index",
    "journal_line",
    "parse_journal_record",
    "meta_payload",
    "parse_meta",
]


class IntegrityError(RuntimeError):
    """Base for every detected storage-corruption / degraded-store error."""


class CorruptPageError(IntegrityError, ValueError):
    """A tensor page failed its checksum, framing, or bounds checks.

    Also raised when loading a model the catalog has quarantined
    (``status="corrupt"``). Subclasses ``ValueError`` so pre-integrity
    callers that caught the old parse errors keep working.
    """


class CorruptIndexError(IntegrityError):
    """An HNSW index file failed its frame checksum or did not parse."""


class CorruptJournalError(IntegrityError):
    """The write-ahead journal is corrupt *before* its tail.

    A torn final record is normal (crash mid-append) and is truncated
    silently; a bad record followed by a good one means the journal body
    itself is damaged and replay would be unsound — the engine degrades
    to read-only instead of guessing.
    """


class CorruptMetaError(IntegrityError):
    """``meta.json`` (and its ``.prev`` fallback, if any) failed its CRC."""


class ReadOnlyStoreError(IntegrityError):
    """A write was attempted on a store degraded to read-only mode."""


def crc32(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


# ------------------------------------------------------------- index framing
_INDEX_MAGIC = b"NSIX"
_INDEX_HDR = struct.Struct("<4sHQI")  # magic, version, payload_len, crc32
_INDEX_VERSION = 1


def frame_index(payload: bytes) -> bytes:
    """Wrap serialized index bytes in a magic + length + CRC envelope."""
    return _INDEX_HDR.pack(
        _INDEX_MAGIC, _INDEX_VERSION, len(payload), crc32(payload)
    ) + payload


def unframe_index(buf: bytes, path: str = "<index>") -> bytes:
    """Verify and strip an index frame; legacy raw pickles pass through.

    Raises :class:`CorruptIndexError` on a bad frame, truncated payload,
    or CRC mismatch — the payload never reaches ``pickle.loads`` unless
    it is byte-exact what was written.
    """
    if not buf.startswith(_INDEX_MAGIC):
        if buf[:1] == b"\x80":  # legacy unframed pickle (pre-integrity store)
            return buf
        raise CorruptIndexError(f"{path}: not a NeurStore index file")
    try:
        _magic, version, length, crc = _INDEX_HDR.unpack_from(buf, 0)
    except struct.error as exc:
        raise CorruptIndexError(f"{path}: truncated index frame") from exc
    if version != _INDEX_VERSION:
        raise CorruptIndexError(f"{path}: unsupported index frame v{version}")
    payload = buf[_INDEX_HDR.size:]
    if len(payload) != length:
        raise CorruptIndexError(
            f"{path}: torn index file ({len(payload)} of {length} payload bytes)"
        )
    if crc32(payload) != crc:
        raise CorruptIndexError(f"{path}: index payload checksum mismatch")
    return payload


# ----------------------------------------------------------- journal records
def _record_crc(record: dict) -> str:
    body = {k: v for k, v in record.items() if k != "crc"}
    return f"{crc32(json.dumps(body, sort_keys=True).encode()):08x}"


def journal_line(record: dict) -> str:
    """Serialize one journal record with an embedded self-CRC.

    The ``crc`` field covers the canonical (sorted-keys) JSON of every
    other field, so the line stays plain parseable JSONL — tools and
    tests that ``json.loads`` each line keep working unchanged.
    """
    rec = {k: v for k, v in record.items() if k != "crc"}
    rec["crc"] = _record_crc(rec)
    return json.dumps(rec, sort_keys=True) + "\n"


def parse_journal_record(line: str) -> dict:
    """Parse + verify one journal line; raises ``ValueError`` on any damage.

    Legacy records without a ``crc`` field (pre-integrity stores) are
    accepted unverified. Callers decide torn-tail-vs-corrupt-body from
    *where* the bad line sits, so this deliberately raises plain
    ``ValueError`` (which ``json.JSONDecodeError`` already subclasses).
    """
    rec = json.loads(line)
    if not isinstance(rec, dict):
        raise ValueError("journal record is not an object")
    if "crc" in rec and rec["crc"] != _record_crc(rec):
        raise ValueError("journal record checksum mismatch")
    return rec


# ------------------------------------------------------------- meta snapshot
_META_FORMAT = 3


def meta_payload(state: dict) -> str:
    """Serialize the catalog snapshot with an embedded integrity stamp.

    The stamp rides as a top-level ``integrity`` key so the file stays a
    plain state dict (``meta["models"]`` etc. work as before); its CRC
    covers the canonical JSON of everything else.
    """
    body = {k: v for k, v in state.items() if k != "integrity"}
    crc = f"{crc32(json.dumps(body, sort_keys=True).encode()):08x}"
    body["integrity"] = {"format": _META_FORMAT, "crc": crc}
    return json.dumps(body)


def parse_meta(text: str, path: str = "meta.json") -> dict:
    """Parse + verify a catalog snapshot; legacy unstamped files pass.

    Raises :class:`CorruptMetaError` on JSON damage or CRC mismatch.
    """
    try:
        state = json.loads(text)
    except json.JSONDecodeError as exc:
        raise CorruptMetaError(f"{path}: does not parse: {exc}") from exc
    if not isinstance(state, dict):
        raise CorruptMetaError(f"{path}: not a snapshot object")
    stamp = state.pop("integrity", None)
    if stamp is not None:
        crc = f"{crc32(json.dumps(state, sort_keys=True).encode()):08x}"
        if stamp.get("crc") != crc:
            raise CorruptMetaError(f"{path}: snapshot checksum mismatch")
    return state
