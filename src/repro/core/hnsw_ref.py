"""Frozen seed HNSW implementation — the parity oracle for the fast index.

This module preserves the original (pre-vectorization) tensor index exactly
as it shipped in the seed: per-insert ``np.concatenate`` growth, Python-set
visited tracking, and a dense dequantize-then-einsum distance. It exists so
that

* ``tests/test_hotpath.py`` can assert the rewritten
  :class:`repro.core.hnsw.HNSWIndex` returns identical neighbor ids (and
  distances within fp tolerance) on fixed-seed workloads, and
* ``benchmarks/hnsw_bench.py`` can measure the speedup of the vectorized
  hot path against the true seed baseline rather than a synthetic stand-in.

Do not optimize this file — its value is being slow in exactly the way the
seed was.
"""

from __future__ import annotations

import math

import numpy as np

from .quantize import QuantMeta, quantize_linear

__all__ = ["quantized_l2_batch_dense", "SeedHNSWIndex"]


def quantized_l2_batch_dense(
    query: np.ndarray,
    codes: np.ndarray,
    scales: np.ndarray,
    zero_points: np.ndarray,
    mids: np.ndarray,
) -> np.ndarray:
    """Seed oracle: squared L2 via explicit dequantization of every row.

    Materializes the full (N, D) float64 dequantized matrix — the exact
    computation the decomposed form in ``repro.core.hnsw`` must reproduce.
    """
    deq = (codes.astype(np.float64) - zero_points[:, None]) * scales[:, None]
    const_rows = scales == 0.0
    if const_rows.any():
        deq[const_rows] = mids[const_rows, None]
    diff = deq - query[None, :].astype(np.float64)
    return np.einsum("nd,nd->n", diff, diff)


class SeedHNSWIndex:
    """The seed multi-layer HNSW, verbatim (O(n) copy per insert)."""

    def __init__(self, dim: int, m: int = 16, ef_construction: int = 64, seed: int = 0):
        self.dim = dim
        self.m = m
        self.m0 = 2 * m
        self.ef_construction = ef_construction
        self.ml = 1.0 / math.log(m)
        self._rng = np.random.default_rng(seed)
        self._codes = np.zeros((0, dim), dtype=np.uint8)
        self._scales = np.zeros((0,), dtype=np.float64)
        self._zps = np.zeros((0,), dtype=np.int32)
        self._mids = np.zeros((0,), dtype=np.float64)
        self._levels: list[int] = []
        self._neighbors: list[dict[int, list[int]]] = []
        self._entry: int | None = None
        self._max_level = -1

    def __len__(self) -> int:
        return len(self._levels)

    def vertex_codes(self, vid: int) -> tuple[np.ndarray, QuantMeta]:
        meta = QuantMeta(
            scale=float(self._scales[vid]),
            zero_point=int(self._zps[vid]),
            nbit=8,
            mid=float(self._mids[vid]),
        )
        return self._codes[vid], meta

    def dequantize_vertex(self, vid: int) -> np.ndarray:
        codes, meta = self.vertex_codes(vid)
        if meta.scale == 0.0:
            return np.full(self.dim, meta.mid, dtype=np.float64)
        return (codes.astype(np.float64) - meta.zero_point) * meta.scale

    def _distances(self, query: np.ndarray, ids: list[int]) -> np.ndarray:
        idx = np.asarray(ids, dtype=np.int64)
        return quantized_l2_batch_dense(
            query, self._codes[idx], self._scales[idx], self._zps[idx], self._mids[idx]
        )

    def _search_layer(
        self, query: np.ndarray, entry: list[int], ef: int, layer: int
    ) -> list[tuple[float, int]]:
        import heapq

        visited = set(entry)
        dists = self._distances(query, entry)
        cand: list[tuple[float, int]] = [(d, v) for d, v in zip(dists, entry)]
        heapq.heapify(cand)
        best: list[tuple[float, int]] = [(-d, v) for d, v in zip(dists, entry)]
        heapq.heapify(best)
        while len(best) > ef:
            heapq.heappop(best)
        adj = self._neighbors[layer]
        while cand:
            d, v = heapq.heappop(cand)
            if best and d > -best[0][0]:
                break
            fresh = [u for u in adj.get(v, ()) if u not in visited]
            if not fresh:
                continue
            visited.update(fresh)
            fd = self._distances(query, fresh)
            bound = -best[0][0]
            for du, u in zip(fd, fresh):
                if len(best) < ef or du < bound:
                    heapq.heappush(cand, (du, u))
                    heapq.heappush(best, (-du, u))
                    if len(best) > ef:
                        heapq.heappop(best)
                    bound = -best[0][0]
        return sorted((-nd, v) for nd, v in best)

    def search(self, query: np.ndarray, k: int = 1, ef: int | None = None) -> list[tuple[float, int]]:
        if self._entry is None:
            return []
        ef = max(ef or self.ef_construction, k)
        q = np.asarray(query, dtype=np.float64).ravel()
        entry = [self._entry]
        for layer in range(self._max_level, 0, -1):
            entry = [self._search_layer(q, entry, 1, layer)[0][1]]
        return self._search_layer(q, entry, ef, 0)[:k]

    def _select_neighbors(self, cands: list[tuple[float, int]], m: int) -> list[int]:
        return [v for _, v in sorted(cands)[:m]]

    def insert(self, tensor: np.ndarray) -> int:
        q = np.asarray(tensor, dtype=np.float64).ravel()
        assert q.size == self.dim, (q.size, self.dim)
        codes, meta = quantize_linear(q, nbit=8)
        vid = len(self._levels)
        self._codes = np.concatenate([self._codes, codes.astype(np.uint8)[None, :]])
        self._scales = np.append(self._scales, meta.scale)
        self._zps = np.append(self._zps, meta.zero_point)
        self._mids = np.append(self._mids, meta.mid)
        level = int(-math.log(max(self._rng.random(), 1e-12)) * self.ml)
        self._levels.append(level)
        while len(self._neighbors) <= level:
            self._neighbors.append({})
        for layer in range(level + 1):
            self._neighbors[layer].setdefault(vid, [])

        if self._entry is None:
            self._entry = vid
            self._max_level = level
            return vid

        entry = [self._entry]
        for layer in range(self._max_level, level, -1):
            entry = [self._search_layer(q, entry, 1, layer)[0][1]]
        for layer in range(min(level, self._max_level), -1, -1):
            cands = self._search_layer(q, entry, self.ef_construction, layer)
            m = self.m0 if layer == 0 else self.m
            nbrs = self._select_neighbors(cands, m)
            adj = self._neighbors[layer]
            adj[vid] = list(nbrs)
            for u in nbrs:
                lst = adj.setdefault(u, [])
                lst.append(vid)
                if len(lst) > m:
                    base_u = self.dequantize_vertex(u)
                    du = self._distances(base_u, lst)
                    order = np.argsort(du)[:m]
                    adj[u] = [lst[i] for i in order]
            entry = [v for _, v in cands]
        if level > self._max_level:
            self._max_level = level
            self._entry = vid
        return vid
