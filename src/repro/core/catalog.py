"""Transactional model catalog — typed metadata + write-ahead journal.

The catalog is the database half of the storage engine: it owns the model
table (name → :class:`ModelEntry`), the monotonic model-id counter, and the
``vertex_refs`` reference counts that tie tensor-page records to HNSW base
vertices. It replaces the seed's untyped ``_meta`` dict-poking with typed
records plus a crash-recovery protocol (DLRDB/MorphingDB treat model
insert/update/drop as first-class transactional operations; so do we).

Durability model
----------------

* **Snapshot** — ``meta.json`` is the authoritative catalog state, written
  atomically via ``os.replace``. A model exists iff its committed entry is
  in the snapshot; ``vertex_refs`` live in the same snapshot, so a model's
  entry and its reference counts commit in one atomic step. The file format
  is a superset of the seed's ``meta.json`` (old stores load unchanged).
* **Journal** — ``journal.jsonl`` is a write-ahead intent log. Every
  lifecycle operation appends an *intent* record (fsync'd) **before** any
  page/index side effect, and a ``commit`` record after the snapshot has
  been replaced and all side effects are durable. On open,
  :meth:`Catalog.pending` returns intents with no commit record; the engine
  replays them — rolling an interrupted operation forward (snapshot already
  switched) or back (snapshot untouched), so a crash at any point leaves no
  orphan pages and no dangling ``vertex_refs``. Commits remove only their
  own transaction's records, so an operation that failed *in-process*
  (exception, not crash) keeps its recovery records pending until the next
  open replays them.

Record shapes (all JSON, one object per line; see ``docs/lifecycle.md``):

* ``{"tx", "op": "save",    "name", "id", "page", "new_vertices"}``
* ``{"tx", "op": "delete",  "name", "id", "page", "refs"}``
* ``{"tx", "op": "replace", "name", "id", "page", "new_vertices",
  "old_id", "old_page", "old_refs"}``
* ``{"tx", "op": "save_batch", "models": [{"name", "id", "page"[,
  "old_id", "old_page", "old_refs"]}, …], "new_vertices"}``
* ``{"tx", "op": "vacuum",        "dim", "pages"}``
* ``{"tx", "op": "vacuum_switch", "dim", "index", "pages", "refs"}``
* ``{"tx", "op": "commit"}``

``refs``/``old_refs`` are ``[[dim, vertex_id, count], …]`` (the references
the model held); ``new_vertices`` is ``[[dim, vertex_id], …]`` (vertices
first created by the interrupted save). ``vacuum_switch.refs`` is the full
post-remap ``{vertex_id: count}`` map for the dim, recorded wholesale so
roll-forward replay is idempotent. ``save_batch`` (``save_models``) commits
every listed model through ONE snapshot replace — replay is all-or-nothing
across the batch, keyed off the first member's presence in the snapshot.

Fault injection: tests add point names to :data:`FAILPOINTS`;
:func:`maybe_fail` raises :class:`InjectedCrash` at matching points inside
the engine's lifecycle operations, simulating a crash between any two
protocol steps. The I/O layer below those steps is additionally faultable
through :class:`~repro.core.faultfs.FaultFS` — all catalog file access
routes through the engine's shim instance.

Integrity (this layer's additions — see ``docs/durability.md``):

* every journal record embeds a self-CRC (``integrity.journal_line``) and
  ``meta.json`` carries a whole-snapshot CRC stamp, both verified on read;
* a contiguous *suffix* of damaged journal lines is a torn tail (crash
  mid-append) — tolerated by :meth:`Catalog.pending` and physically
  truncated by :meth:`Catalog.recover_journal` at open; a damaged record
  *followed by a valid one* means the journal body is corrupt and raises
  :class:`~repro.core.integrity.CorruptJournalError` (the engine degrades
  to read-only rather than replay guesses);
* :meth:`Catalog.save_snapshot` first copies the current ``meta.json`` to
  ``meta.json.prev`` (durable) before replacing it, so a snapshot that is
  later found corrupt can fall back to the last good one (read-only).
"""

from __future__ import annotations

import dataclasses
import json
import os

from .faultfs import FaultFS
from .integrity import (
    CorruptJournalError,
    CorruptMetaError,
    journal_line,
    meta_payload,
    parse_journal_record,
    parse_meta,
)

__all__ = [
    "Catalog",
    "CatalogState",
    "EXPLAIN_FIELDS",
    "InjectedCrash",
    "ModelEntry",
    "explain_pack",
    "explain_unpack",
    "STATUS_COMMITTED",
    "STATUS_PENDING",
    "STATUS_CORRUPT",
    "FAILPOINTS",
    "maybe_fail",
    "read_journal",
]

STATUS_COMMITTED = "committed"
STATUS_PENDING = "pending"
STATUS_CORRUPT = "corrupt"

# ------------------------------------------------------------ fault injection
FAILPOINTS: set[str] = set()


class InjectedCrash(RuntimeError):
    """Raised by :func:`maybe_fail` to simulate a crash mid-transaction."""


def maybe_fail(point: str) -> None:
    if point in FAILPOINTS:
        raise InjectedCrash(point)


# Persisted EXPLAIN row layout (the engine's per-model sidecar files —
# deliberately NOT part of meta.json, whose snapshot is re-serialized
# and fsynced at every commit and must not grow with EXPLAIN). Entries
# are stored as fixed-order rows (no repeated keys) with floats trimmed
# to 6 significant digits — about 3x smaller/faster to dump than the
# verbose per-tensor dicts the engine hands out.
EXPLAIN_FIELDS = (
    "tensor", "dim", "vertex_id", "outcome", "probe_distance",
    "delta_range", "tau", "nbit", "delta_bytes", "error_bound",
)


def _trim(v):
    return float(f"{v:.6g}") if isinstance(v, float) else v


def explain_pack(entries: list) -> list:
    return [[_trim(e.get(k)) for k in EXPLAIN_FIELDS] for e in entries]


def explain_unpack(rows) -> list | None:
    if rows is None:
        return None
    return [dict(zip(EXPLAIN_FIELDS, row)) for row in rows]


# ------------------------------------------------------------- typed records
@dataclasses.dataclass
class ModelEntry:
    """One catalog row: a stored model and where its page lives."""

    model_id: int
    name: str
    architecture: dict
    page: str
    n_tensors: int
    original_bytes: int
    status: str = STATUS_COMMITTED
    # Bounded per-tensor save EXPLAIN (first EXPLAIN_PERSIST_MAX tensors,
    # see engine.py): how each tensor was stored — matched vertex, probe
    # distance vs tau, dedup outcome, delta bit-width/bytes. In-memory
    # only: the durable copy lives in the engine's per-model sidecar
    # file (explain/model_<id>.json), never in the snapshot — meta.json
    # is rewritten+fsynced per commit and must stay O(models), not
    # O(models × tensors). None when accounting is disabled and on
    # entries loaded from disk until the sidecar is read.
    explain: list | None = None

    def __getitem__(self, key: str):
        # Legacy dict-style access ("id", "page", ...) for pre-catalog callers.
        if key == "id":
            return self.model_id
        return getattr(self, key)

    def to_dict(self) -> dict:
        out = {
            "id": self.model_id,
            "architecture": self.architecture,
            "page": self.page,
            "n_tensors": self.n_tensors,
            "original_bytes": self.original_bytes,
            "status": self.status,
        }
        return out

    @classmethod
    def from_dict(cls, name: str, d: dict) -> "ModelEntry":
        return cls(
            model_id=int(d["id"]),
            name=name,
            architecture=d.get("architecture", {}),
            page=d["page"],
            n_tensors=int(d.get("n_tensors", 0)),
            original_bytes=int(d.get("original_bytes", 0)),
            # Seed-format entries carry no status: they were only ever
            # written after a completed save, i.e. committed.
            status=d.get("status", STATUS_COMMITTED),
        )


@dataclasses.dataclass
class CatalogState:
    """In-memory catalog: model table, id counter, vertex reference counts.

    ``epoch`` is the snapshot-isolation clock: every committed snapshot
    carries a strictly increasing epoch, bumped by :meth:`Catalog.save_snapshot`
    at the writer's atomic ``meta.json`` commit point. Readers stamp the
    epoch into their :class:`~repro.core.loader.ModelSnapshot` at load time
    and never consult shared catalog state again (seed-format stores load
    at epoch 0).
    """

    models: dict[str, ModelEntry] = dataclasses.field(default_factory=dict)
    next_id: int = 0
    vertex_refs: dict[str, int] = dataclasses.field(default_factory=dict)
    epoch: int = 0

    def to_dict(self) -> dict:
        return {
            "models": {n: e.to_dict() for n, e in self.models.items()},
            "next_id": self.next_id,
            "vertex_refs": self.vertex_refs,
            "epoch": self.epoch,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CatalogState":
        return cls(
            models={
                n: ModelEntry.from_dict(n, e)
                for n, e in d.get("models", {}).items()
            },
            next_id=int(d.get("next_id", 0)),
            vertex_refs={k: int(v) for k, v in d.get("vertex_refs", {}).items()},
            epoch=int(d.get("epoch", 0)),
        )


def _ref_key(dim: int, vid: int) -> str:
    return f"{dim}:{vid}"


def read_journal(path: str) -> tuple[list[dict], int, int | None, str | None]:
    """Parse + verify a journal file without mutating anything.

    Returns ``(records, max_tx, torn_offset, corrupt_reason)``: the valid
    records in file order, the highest tx id seen, the byte offset where a
    torn tail starts (``None`` if the file ends cleanly), and a reason
    string when a damaged record *precedes* a valid one (body corruption —
    replay would be unsound). Appends only ever damage the tail, so any
    contiguous damaged suffix is classified as torn.
    """
    if not os.path.exists(path):
        return [], 0, None, None
    with open(path, "rb") as f:
        raw = f.read()
    records: list[dict] = []
    max_tx = 0
    bad_offset: int | None = None
    corrupt: str | None = None
    pos = 0
    for chunk in raw.split(b"\n"):
        start = pos
        pos += len(chunk) + 1
        if not chunk.strip():
            continue
        try:
            rec = parse_journal_record(chunk.decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            if bad_offset is None:
                bad_offset = start
            continue
        if bad_offset is not None and corrupt is None:
            corrupt = (
                f"damaged record at byte {bad_offset} precedes a valid record"
            )
        records.append(rec)
        max_tx = max(max_tx, int(rec.get("tx", 0)))
    return records, max_tx, bad_offset, corrupt


def _group_pending(records: list[dict]) -> list[list[dict]]:
    groups: dict[int, list[dict]] = {}
    committed: set[int] = set()
    for rec in records:
        tx = int(rec.get("tx", 0))
        if rec.get("op") == "commit":
            committed.add(tx)
        else:
            groups.setdefault(tx, []).append(rec)
    return [recs for tx, recs in sorted(groups.items()) if tx not in committed]


class Catalog:
    """Snapshot + journal manager. All mutation goes through the engine lock."""

    def __init__(self, root: str, fs: FaultFS | None = None):
        self.root = root
        self.fs = fs if fs is not None else FaultFS()
        self.meta_path = os.path.join(root, "meta.json")
        self.prev_path = self.meta_path + ".prev"
        self.journal_path = os.path.join(root, "journal.jsonl")
        self.state = CatalogState()
        # Set when meta.json was corrupt and state came from meta.json.prev
        # — the engine must degrade to read-only (the view may be stale).
        self.meta_fallback: str | None = None
        self._load_state()
        self._next_tx = 1

    def _load_state(self) -> None:
        if os.path.exists(self.meta_path):
            try:
                self.state = CatalogState.from_dict(parse_meta(
                    self.fs.read_text(self.meta_path, site="meta.read"),
                    self.meta_path,
                ))
                return
            except (CorruptMetaError, UnicodeDecodeError) as exc:
                # A bit flip can damage the UTF-8 encoding itself before
                # the CRC is even consulted — same corruption, same path.
                primary: Exception = exc
        elif os.path.exists(self.prev_path):
            primary = CorruptMetaError(
                f"{self.meta_path}: missing, but a prev snapshot exists"
            )
        else:
            return  # fresh store
        try:
            self.state = CatalogState.from_dict(parse_meta(
                self.fs.read_text(self.prev_path, site="meta.read_prev"),
                self.prev_path,
            ))
        except (CorruptMetaError, UnicodeDecodeError, OSError) as exc:
            raise CorruptMetaError(
                f"catalog unrecoverable: {primary}; fallback failed: {exc}"
            ) from exc
        self.meta_fallback = str(primary)

    # ----------------------------------------------------------- model table
    def get(self, name: str) -> ModelEntry | None:
        return self.state.models.get(name)

    def names(self, committed_only: bool = True) -> list[str]:
        if not committed_only:
            return list(self.state.models)
        return [
            n for n, e in self.state.models.items()
            if e.status == STATUS_COMMITTED
        ]

    def corrupt_names(self) -> list[str]:
        """Models quarantined after failing an integrity check."""
        return [
            n for n, e in self.state.models.items()
            if e.status == STATUS_CORRUPT
        ]

    def allocate_id(self) -> int:
        mid = self.state.next_id
        self.state.next_id = mid + 1
        return mid

    # ------------------------------------------------------- reference counts
    def ref_count(self, dim: int, vid: int) -> int:
        return self.state.vertex_refs.get(_ref_key(dim, vid), 0)

    def ref(self, dim: int, vid: int, delta: int = 1) -> int:
        key = _ref_key(dim, vid)
        refs = self.state.vertex_refs
        n = refs.get(key, 0) + delta
        if n > 0:
            refs[key] = n
        else:
            refs.pop(key, None)
        return n

    def refs_for_dim(self, dim: int) -> dict[int, int]:
        prefix = f"{dim}:"
        return {
            int(k[len(prefix):]): v
            for k, v in self.state.vertex_refs.items()
            if k.startswith(prefix)
        }

    def set_dim_refs(self, dim: int, refs: dict[int, int]) -> None:
        """Replace every ref for ``dim`` wholesale (idempotent vacuum replay)."""
        prefix = f"{dim}:"
        table = self.state.vertex_refs
        for k in [k for k in table if k.startswith(prefix)]:
            del table[k]
        for vid, count in refs.items():
            if int(count) > 0:
                table[_ref_key(dim, int(vid))] = int(count)

    # --------------------------------------------------------------- snapshot
    def save_snapshot(self) -> None:
        """Atomically persist the catalog state — the transaction commit point.

        Bumps the snapshot-isolation epoch: every commit is a new epoch,
        so a reader that captured its view before this call is observably
        older than one opened after it.

        Before replacing ``meta.json`` the current bytes are copied to
        ``meta.json.prev`` (durably, best-effort), so a later corruption
        of the live snapshot degrades to last-good read-only instead of
        an unopenable store. The new snapshot carries a whole-file CRC
        stamp (``integrity.meta_payload``).
        """
        self.state.epoch += 1
        if os.path.exists(self.meta_path):
            try:
                prev = self.fs.read_bytes(self.meta_path, site="meta.read")
            except OSError:
                prev = None
            if prev is not None:
                try:
                    self.fs.write_durable(self.prev_path, prev, site="meta.prev")
                except OSError:
                    pass  # fallback copy is best-effort; the commit is not
        tmp = self.meta_path + ".tmp"
        payload = meta_payload(self.state.to_dict()).encode("utf-8")
        self.fs.write_durable(tmp, payload, site="meta.tmp")
        self.fs.replace(tmp, self.meta_path, site="meta.replace")

    def snapshot_dict(self) -> dict:
        """Legacy ``_meta``-shaped read-only view of the catalog state."""
        return self.state.to_dict()

    # ---------------------------------------------------------------- journal
    def _append(self, record: dict) -> None:
        self.fs.append_durable(
            self.journal_path, journal_line(record), site="journal.append"
        )

    def begin(self, record: dict) -> int:
        """Append a write-intent record; returns its transaction id."""
        tx = self._next_tx
        self._next_tx += 1
        self._append({"tx": tx, **record})
        return tx

    def log(self, tx: int, record: dict) -> None:
        """Append a follow-up record (e.g. ``vacuum_switch``) for ``tx``."""
        self._append({"tx": tx, **record})

    def commit_tx(self, tx: int) -> None:
        """Mark ``tx`` durable and drop its records from the journal.

        Only committed transactions are removed: an earlier transaction
        that *failed in-process* (exception, not crash) can leave a
        pending intent — or a pending ``vacuum_switch`` roll-forward
        record — that must survive until the next open replays it.
        Truncating the whole file here would erase that recovery state.
        """
        self._append({"tx": tx, "op": "commit"})
        remaining = self.pending()
        if not remaining:
            self.truncate_journal()
            return
        tmp = self.journal_path + ".tmp"
        buf = "".join(
            journal_line(rec) for group in remaining for rec in group
        )
        self.fs.write_durable(tmp, buf.encode("utf-8"), site="journal.rewrite")
        self.fs.replace(tmp, self.journal_path, site="journal.replace")

    def truncate_journal(self) -> None:
        self.fs.write_durable(self.journal_path, b"", site="journal.clear")

    def pending(self) -> list[list[dict]]:
        """Uncommitted transactions from the journal, oldest first.

        Each element is the ordered list of records sharing one ``tx`` (a
        vacuum contributes up to two: intent + switch). A torn tail (crash
        mid-append) is tolerated — the damaged intent never became durable,
        so by protocol nothing after it happened; :meth:`recover_journal`
        additionally truncates it at open. Damage *before* a valid record
        raises :class:`CorruptJournalError`.
        """
        records, max_tx, _torn, corrupt = read_journal(self.journal_path)
        if corrupt is not None:
            raise CorruptJournalError(f"{self.journal_path}: {corrupt}")
        self._next_tx = max(self._next_tx, max_tx + 1)
        return _group_pending(records)

    def recover_journal(self) -> list[list[dict]]:
        """Open-time journal read: truncate any torn tail, return pending.

        The physical truncation keeps a later reader (or a tool reading
        the raw file) from re-classifying the same damage, and is safe by
        protocol: a record that never fully hit disk never had durable
        side effects. Body corruption raises :class:`CorruptJournalError`
        — the caller must degrade to read-only, not replay.
        """
        records, max_tx, torn, corrupt = read_journal(self.journal_path)
        if corrupt is not None:
            raise CorruptJournalError(f"{self.journal_path}: {corrupt}")
        if torn is not None:
            self.fs.truncate(self.journal_path, torn, site="journal.repair")
        self._next_tx = max(self._next_tx, max_tx + 1)
        return _group_pending(records)
