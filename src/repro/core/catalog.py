"""Transactional model catalog — typed metadata + write-ahead journal.

The catalog is the database half of the storage engine: it owns the model
table (name → :class:`ModelEntry`), the monotonic model-id counter, and the
``vertex_refs`` reference counts that tie tensor-page records to HNSW base
vertices. It replaces the seed's untyped ``_meta`` dict-poking with typed
records plus a crash-recovery protocol (DLRDB/MorphingDB treat model
insert/update/drop as first-class transactional operations; so do we).

Durability model
----------------

* **Snapshot** — ``meta.json`` is the authoritative catalog state, written
  atomically via ``os.replace``. A model exists iff its committed entry is
  in the snapshot; ``vertex_refs`` live in the same snapshot, so a model's
  entry and its reference counts commit in one atomic step. The file format
  is a superset of the seed's ``meta.json`` (old stores load unchanged).
* **Journal** — ``journal.jsonl`` is a write-ahead intent log. Every
  lifecycle operation appends an *intent* record (fsync'd) **before** any
  page/index side effect, and a ``commit`` record after the snapshot has
  been replaced and all side effects are durable. On open,
  :meth:`Catalog.pending` returns intents with no commit record; the engine
  replays them — rolling an interrupted operation forward (snapshot already
  switched) or back (snapshot untouched), so a crash at any point leaves no
  orphan pages and no dangling ``vertex_refs``. Commits remove only their
  own transaction's records, so an operation that failed *in-process*
  (exception, not crash) keeps its recovery records pending until the next
  open replays them.

Record shapes (all JSON, one object per line; see ``docs/lifecycle.md``):

* ``{"tx", "op": "save",    "name", "id", "page", "new_vertices"}``
* ``{"tx", "op": "delete",  "name", "id", "page", "refs"}``
* ``{"tx", "op": "replace", "name", "id", "page", "new_vertices",
  "old_id", "old_page", "old_refs"}``
* ``{"tx", "op": "save_batch", "models": [{"name", "id", "page"[,
  "old_id", "old_page", "old_refs"]}, …], "new_vertices"}``
* ``{"tx", "op": "vacuum",        "dim", "pages"}``
* ``{"tx", "op": "vacuum_switch", "dim", "index", "pages", "refs"}``
* ``{"tx", "op": "commit"}``

``refs``/``old_refs`` are ``[[dim, vertex_id, count], …]`` (the references
the model held); ``new_vertices`` is ``[[dim, vertex_id], …]`` (vertices
first created by the interrupted save). ``vacuum_switch.refs`` is the full
post-remap ``{vertex_id: count}`` map for the dim, recorded wholesale so
roll-forward replay is idempotent. ``save_batch`` (``save_models``) commits
every listed model through ONE snapshot replace — replay is all-or-nothing
across the batch, keyed off the first member's presence in the snapshot.

Fault injection: tests add point names to :data:`FAILPOINTS`;
:func:`maybe_fail` raises :class:`InjectedCrash` at matching points inside
the engine's lifecycle operations, simulating a crash between any two
protocol steps.
"""

from __future__ import annotations

import dataclasses
import json
import os

__all__ = [
    "Catalog",
    "CatalogState",
    "InjectedCrash",
    "ModelEntry",
    "STATUS_COMMITTED",
    "STATUS_PENDING",
    "FAILPOINTS",
    "maybe_fail",
]

STATUS_COMMITTED = "committed"
STATUS_PENDING = "pending"

# ------------------------------------------------------------ fault injection
FAILPOINTS: set[str] = set()


class InjectedCrash(RuntimeError):
    """Raised by :func:`maybe_fail` to simulate a crash mid-transaction."""


def maybe_fail(point: str) -> None:
    if point in FAILPOINTS:
        raise InjectedCrash(point)


# ------------------------------------------------------------- typed records
@dataclasses.dataclass
class ModelEntry:
    """One catalog row: a stored model and where its page lives."""

    model_id: int
    name: str
    architecture: dict
    page: str
    n_tensors: int
    original_bytes: int
    status: str = STATUS_COMMITTED

    def __getitem__(self, key: str):
        # Legacy dict-style access ("id", "page", ...) for pre-catalog callers.
        if key == "id":
            return self.model_id
        return getattr(self, key)

    def to_dict(self) -> dict:
        return {
            "id": self.model_id,
            "architecture": self.architecture,
            "page": self.page,
            "n_tensors": self.n_tensors,
            "original_bytes": self.original_bytes,
            "status": self.status,
        }

    @classmethod
    def from_dict(cls, name: str, d: dict) -> "ModelEntry":
        return cls(
            model_id=int(d["id"]),
            name=name,
            architecture=d.get("architecture", {}),
            page=d["page"],
            n_tensors=int(d.get("n_tensors", 0)),
            original_bytes=int(d.get("original_bytes", 0)),
            # Seed-format entries carry no status: they were only ever
            # written after a completed save, i.e. committed.
            status=d.get("status", STATUS_COMMITTED),
        )


@dataclasses.dataclass
class CatalogState:
    """In-memory catalog: model table, id counter, vertex reference counts.

    ``epoch`` is the snapshot-isolation clock: every committed snapshot
    carries a strictly increasing epoch, bumped by :meth:`Catalog.save_snapshot`
    at the writer's atomic ``meta.json`` commit point. Readers stamp the
    epoch into their :class:`~repro.core.loader.ModelSnapshot` at load time
    and never consult shared catalog state again (seed-format stores load
    at epoch 0).
    """

    models: dict[str, ModelEntry] = dataclasses.field(default_factory=dict)
    next_id: int = 0
    vertex_refs: dict[str, int] = dataclasses.field(default_factory=dict)
    epoch: int = 0

    def to_dict(self) -> dict:
        return {
            "models": {n: e.to_dict() for n, e in self.models.items()},
            "next_id": self.next_id,
            "vertex_refs": self.vertex_refs,
            "epoch": self.epoch,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CatalogState":
        return cls(
            models={
                n: ModelEntry.from_dict(n, e)
                for n, e in d.get("models", {}).items()
            },
            next_id=int(d.get("next_id", 0)),
            vertex_refs={k: int(v) for k, v in d.get("vertex_refs", {}).items()},
            epoch=int(d.get("epoch", 0)),
        )


def _ref_key(dim: int, vid: int) -> str:
    return f"{dim}:{vid}"


class Catalog:
    """Snapshot + journal manager. All mutation goes through the engine lock."""

    def __init__(self, root: str):
        self.root = root
        self.meta_path = os.path.join(root, "meta.json")
        self.journal_path = os.path.join(root, "journal.jsonl")
        self.state = CatalogState()
        if os.path.exists(self.meta_path):
            with open(self.meta_path) as f:
                self.state = CatalogState.from_dict(json.load(f))
        self._next_tx = 1

    # ----------------------------------------------------------- model table
    def get(self, name: str) -> ModelEntry | None:
        return self.state.models.get(name)

    def names(self, committed_only: bool = True) -> list[str]:
        if not committed_only:
            return list(self.state.models)
        return [
            n for n, e in self.state.models.items()
            if e.status == STATUS_COMMITTED
        ]

    def allocate_id(self) -> int:
        mid = self.state.next_id
        self.state.next_id = mid + 1
        return mid

    # ------------------------------------------------------- reference counts
    def ref_count(self, dim: int, vid: int) -> int:
        return self.state.vertex_refs.get(_ref_key(dim, vid), 0)

    def ref(self, dim: int, vid: int, delta: int = 1) -> int:
        key = _ref_key(dim, vid)
        refs = self.state.vertex_refs
        n = refs.get(key, 0) + delta
        if n > 0:
            refs[key] = n
        else:
            refs.pop(key, None)
        return n

    def refs_for_dim(self, dim: int) -> dict[int, int]:
        prefix = f"{dim}:"
        return {
            int(k[len(prefix):]): v
            for k, v in self.state.vertex_refs.items()
            if k.startswith(prefix)
        }

    def set_dim_refs(self, dim: int, refs: dict[int, int]) -> None:
        """Replace every ref for ``dim`` wholesale (idempotent vacuum replay)."""
        prefix = f"{dim}:"
        table = self.state.vertex_refs
        for k in [k for k in table if k.startswith(prefix)]:
            del table[k]
        for vid, count in refs.items():
            if int(count) > 0:
                table[_ref_key(dim, int(vid))] = int(count)

    # --------------------------------------------------------------- snapshot
    def save_snapshot(self) -> None:
        """Atomically persist the catalog state — the transaction commit point.

        Bumps the snapshot-isolation epoch: every commit is a new epoch,
        so a reader that captured its view before this call is observably
        older than one opened after it.
        """
        self.state.epoch += 1
        tmp = self.meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.state.to_dict(), f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.meta_path)

    def snapshot_dict(self) -> dict:
        """Legacy ``_meta``-shaped read-only view of the catalog state."""
        return self.state.to_dict()

    # ---------------------------------------------------------------- journal
    def _append(self, record: dict) -> None:
        line = json.dumps(record, sort_keys=True) + "\n"
        with open(self.journal_path, "a") as f:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())

    def begin(self, record: dict) -> int:
        """Append a write-intent record; returns its transaction id."""
        tx = self._next_tx
        self._next_tx += 1
        self._append({"tx": tx, **record})
        return tx

    def log(self, tx: int, record: dict) -> None:
        """Append a follow-up record (e.g. ``vacuum_switch``) for ``tx``."""
        self._append({"tx": tx, **record})

    def commit_tx(self, tx: int) -> None:
        """Mark ``tx`` durable and drop its records from the journal.

        Only committed transactions are removed: an earlier transaction
        that *failed in-process* (exception, not crash) can leave a
        pending intent — or a pending ``vacuum_switch`` roll-forward
        record — that must survive until the next open replays it.
        Truncating the whole file here would erase that recovery state.
        """
        self._append({"tx": tx, "op": "commit"})
        remaining = self.pending()
        if not remaining:
            self.truncate_journal()
            return
        tmp = self.journal_path + ".tmp"
        with open(tmp, "w") as f:
            for group in remaining:
                for rec in group:
                    f.write(json.dumps(rec, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.journal_path)

    def truncate_journal(self) -> None:
        with open(self.journal_path, "w") as f:
            f.flush()
            os.fsync(f.fileno())

    def pending(self) -> list[list[dict]]:
        """Uncommitted transactions from the journal, oldest first.

        Each element is the ordered list of records sharing one ``tx`` (a
        vacuum contributes up to two: intent + switch). A torn final line
        (crash mid-append) is ignored: the intent never became durable, so
        by protocol nothing after it happened.
        """
        if not os.path.exists(self.journal_path):
            return []
        with open(self.journal_path) as f:
            lines = f.read().splitlines()
        groups: dict[int, list[dict]] = {}
        committed: set[int] = set()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break  # torn tail — never became durable
                raise ValueError(f"corrupt catalog journal at line {i + 1}")
            tx = int(rec.get("tx", 0))
            self._next_tx = max(self._next_tx, tx + 1)
            if rec.get("op") == "commit":
                committed.add(tx)
            else:
                groups.setdefault(tx, []).append(rec)
        return [recs for tx, recs in sorted(groups.items()) if tx not in committed]
