"""Compression-aware model loading (paper §4.3 / Algorithm 2).

Three paper mechanisms, adapted from ONNX-graph surgery to JAX:

* **Augmented computation graph** — instead of inserting ``DequantizeLinear``
  + ``Add`` ONNX nodes, :meth:`LoadedModel.compressed_params` exposes each
  tensor as its quantized base + quantized delta with quant metadata, and
  :func:`reconstruct_jnp` is the jittable dequant+add subgraph. Downstream,
  ``repro.kernels.dequant_matmul`` fuses that subgraph *into* the consuming
  matmul so the full-precision weight never materializes in HBM (the TPU
  upgrade of on-demand decompression).
* **Flexible loading** (§4.3.1) — ``bits=b`` reads only the top ``b``
  bit-planes of each delta payload from the page (true partial I/O) and
  widens the scale by ``2^(nbit-b)`` (Alg. 2 lines 6-8).
* **Share-counted de-quantization** (§4.3.2) — base tensors referenced by
  multiple records are de-quantized once per materialization pass; a
  per-pass countdown (reset when it drains, so repeated ``tensor()`` calls
  or a second ``materialize()`` never go negative) frees the de-quantized
  copy once every sharing record has consumed it.
* **Pipelining** (§4.3.3) — :class:`PipelineLoader` overlaps page I/O,
  de-quantization and consumption in a 3-stage thread pipeline.
"""

from __future__ import annotations

import queue
import threading
from collections import Counter

import numpy as np

from .pages import TensorPage, TensorRecord, decode_payload, read_record, read_record_partial
from .quantize import dequantize_delta, dequantize_linear

__all__ = ["LoadedModel", "PipelineLoader", "materialize_many", "reconstruct_jnp"]


def reconstruct_jnp(base_codes, base_scale, base_zp, qdelta, delta_scale, delta_zp):
    """The augmented-graph subgraph: Dequant(base) + Dequant(delta) → Add.

    Pure-jnp, jit/pjit-compatible; bin-centre delta dequant matches
    ``quantize.dequantize_delta``. Shapes: any; dtypes: int8/int32 codes.
    """
    import jax.numpy as jnp

    base = (base_codes.astype(jnp.float32) - base_zp) * base_scale
    delta = (qdelta.astype(jnp.float32) - delta_zp + 0.5) * delta_scale
    return base + delta


class LoadedModel:
    """Handle over one stored model, loaded without full decompression."""

    def __init__(self, engine, page: TensorPage, info: dict, bits: int | None = None):
        self.engine = engine
        self.page = page
        self.info = info
        self.bits = bits
        self._records: dict[str, TensorRecord] = {}
        self._order: list[str] = []
        # Records are read with packed payloads only (decode=False): the
        # vectorized planar bit-unpack runs lazily on first tensor access,
        # so open-time cost is header parsing + payload slicing and the
        # pipeline's dequant stage does the unpack work (paper §4.3.3).
        for i in range(page.n_records):
            rec = (
                read_record_partial(page, i, bits, decode=False)
                if bits is not None
                else read_record(page, i, decode=False)
            )
            self._records[rec.name] = rec
            self._order.append(rec.name)
        # Share counts: how many records reference each base vertex. The
        # immutable counts stay in _share; _remaining is the per-pass
        # countdown that controls the cached de-quantized copy's lifetime.
        self._share = Counter((r.dim_key, r.vertex_id) for r in self._records.values())
        self._remaining: dict[tuple[int, int], int] = dict(self._share)
        self._deq_base: dict[tuple[int, int], np.ndarray] = {}

    # ------------------------------------------------------------- metadata
    @property
    def architecture(self) -> dict:
        return self.info["architecture"]  # ModelEntry supports item access

    def tensor_names(self) -> list[str]:
        return list(self._order)

    def _ensure_decoded(self, rec: TensorRecord) -> TensorRecord:
        if rec.qdelta is None:
            rec.qdelta = decode_payload(rec)
        return rec

    def record(self, name: str) -> TensorRecord:
        return self._ensure_decoded(self._records[name])

    def _apply_vertex_remap(self, dim: int, remap: dict[int, int]) -> None:
        """Engine callback after index compaction (vacuum): renumber this
        handle's base references so it stays valid across the remap. A
        record whose base was dropped — its model was deleted while this
        handle stayed open — is poisoned with id -1 and raises on access.
        """
        changed = False
        for rec in self._records.values():
            if rec.dim_key == dim:
                rec.vertex_id = remap.get(rec.vertex_id, -1)
                changed = True
        if not changed:
            return

        def rekey(d):
            return {
                (k if k[0] != dim else (dim, remap.get(k[1], -1))): v
                for k, v in d.items()
            }

        self._share = Counter(rekey(self._share))
        self._remaining = rekey(self._remaining)
        self._deq_base = rekey(self._deq_base)

    # ------------------------------------------------- on-demand decompress
    def _base(self, rec: TensorRecord) -> np.ndarray:
        """De-quantize a base once per pass; free when every sharer has read it.

        The countdown resets to the full share count when it drains, so the
        cache is correct across repeated ``tensor(name)`` calls and multiple
        ``materialize()`` passes (the seed's one-shot drain counter went
        negative and re-dequantized shared bases on every later access).
        """
        # The engine lock makes the id-read + codes-row fetch atomic
        # against vacuum's in-place compaction (which moves rows and
        # renumbers this handle's records); the O(dim) de-quantization
        # itself runs outside the lock on a private copy of the row.
        with self.engine._lock:
            self.engine._check_quarantine(rec.dim_key)
            if rec.vertex_id < 0:
                raise KeyError(
                    f"base of tensor {rec.name!r} was vacuumed away: the "
                    "model was deleted while this handle was open"
                )
            base = self._deq_base.get((rec.dim_key, rec.vertex_id))
            codes = meta = None
            if base is None:
                index = self.engine.index_cache.get(rec.dim_key)
                codes, meta = index.vertex_codes(rec.vertex_id)
                codes = codes.copy()  # row view into arrays compact() moves
        if base is None:
            base = dequantize_linear(codes, meta)
        with self.engine._lock:
            # Re-derive the key: a vacuum between the two critical sections
            # may have renumbered the record (the base bytes are unchanged).
            key = (rec.dim_key, rec.vertex_id)
            if key not in self._deq_base and self._share.get(key, 0) > 1:
                self._deq_base[key] = base
            left = self._remaining.get(key, 1) - 1
            if left <= 0:
                self._deq_base.pop(key, None)
                self._remaining[key] = self._share.get(key, 1)  # rearm
            else:
                self._remaining[key] = left
        return base

    def tensor(self, name: str) -> np.ndarray:
        """Reconstruct one tensor to float32 (base + delta, on demand)."""
        rec = self._ensure_decoded(self._records[name])
        base = self._base(rec)
        delta = dequantize_delta(rec.qdelta, rec.meta)
        return (base + delta).reshape(rec.shape).astype(np.float32)

    def materialize(self) -> dict[str, np.ndarray]:
        """Full reconstruction of every tensor (the non-compression-aware path)."""
        return {name: self.tensor(name) for name in list(self._order)}

    # ------------------------------------------ compressed (augmented graph)
    def compressed_params(self) -> dict[str, dict]:
        """Per-tensor quantized components for compute-on-compressed.

        Each entry carries exactly what Alg. 2 retrieves (lines 4-5): the
        int8 base codes + (scale, zp), the quantized delta codes + (scale,
        zp, nbit). Feed these to ``reconstruct_jnp`` or to the fused
        ``dequant_matmul`` kernel.
        """
        out = {}
        for name in self._order:
            rec = self._ensure_decoded(self._records[name])
            with self.engine._lock:  # atomic vs vacuum's in-place compact
                self.engine._check_quarantine(rec.dim_key)
                if rec.vertex_id < 0:
                    raise KeyError(
                        f"base of tensor {rec.name!r} was vacuumed away: "
                        "the model was deleted while this handle was open"
                    )
                index = self.engine.index_cache.get(rec.dim_key)
                codes, bmeta = index.vertex_codes(rec.vertex_id)
                codes = codes.copy()
            # int8-safe recentring for the TPU kernels: uint8 codes c with
            # zero-point z dequantize identically as (c-128) with (z-128),
            # and (c-128) fits int8 exactly. Only valid when nbit <= 8 —
            # use flexible loading (bits=8) for kernel-ready params.
            kernel_ready = rec.meta.nbit <= 8
            out[name] = {
                "shape": rec.shape,
                "base_codes": (codes.astype(np.int16) - 128)
                .astype(np.int8).reshape(rec.shape),
                "base_scale": np.float32(bmeta.scale),
                "base_zp": np.float32(bmeta.zero_point - 128),
                "base_mid": np.float32(bmeta.mid),
                "qdelta": rec.qdelta.reshape(rec.shape),
                "qdelta_i8": ((rec.qdelta - 128).astype(np.int8)
                              .reshape(rec.shape) if kernel_ready else None),
                "delta_scale": np.float32(rec.meta.scale),
                "delta_zp": np.float32(rec.meta.zero_point),
                "delta_zp_i8": np.float32(rec.meta.zero_point - 128),
                "nbit": rec.meta.nbit,
            }
        return out


def materialize_many(models: list["LoadedModel"]) -> list[dict[str, np.ndarray]]:
    """Materialize several handles, de-quantizing each base once per batch.

    The load-side counterpart of ``StorageEngine.save_models``: a base
    vertex referenced by records in *different* handles (a checkpoint sweep
    loading a family of fine-tunes) is de-quantized once and seeded into
    every holder's per-pass cache, instead of once per handle. Per-handle
    share accounting is untouched — the seeded copy drains through the
    normal countdown, so repeated materialize passes behave exactly as
    before. Returns one ``{name: tensor}`` dict per handle, in order.
    """
    # Group by live record objects, not snapshotted (dim, vid) keys: a
    # concurrent vacuum renumbers vertex ids in place via
    # _apply_vertex_remap, so every id read AND the codes fetch must share
    # one critical section, and the seed below re-derives each key from
    # the record at seed time (the same two-phase discipline as
    # LoadedModel._base — base *bytes* are invariant across compaction,
    # only the numbering moves).
    by_engine: dict[int, list[LoadedModel]] = {}
    for lm in models:
        by_engine.setdefault(id(lm.engine), []).append(lm)
    for lms in by_engine.values():
        engine = lms[0].engine
        with engine._lock:
            groups: dict[tuple[int, int], list[tuple[LoadedModel, TensorRecord]]] = {}
            for lm in lms:
                seen: set[tuple[int, int]] = set()
                for rec in lm._records.values():
                    key = (rec.dim_key, rec.vertex_id)
                    if rec.vertex_id >= 0 and key not in seen:
                        seen.add(key)
                        groups.setdefault(key, []).append((lm, rec))
            fetched = []
            for (dim, vid), holders in groups.items():
                if len(holders) < 2:
                    continue  # shared within one handle only: _base caches it
                engine._check_quarantine(dim)
                index = engine.index_cache.get(dim)
                codes, meta = index.vertex_codes(vid)
                fetched.append((holders, codes.copy(), meta))
        for holders, codes, meta in fetched:
            base = dequantize_linear(codes, meta)
            with engine._lock:
                for lm, rec in holders:
                    if rec.vertex_id >= 0:  # key re-derived post-any-remap
                        lm._deq_base.setdefault(
                            (rec.dim_key, rec.vertex_id), base
                        )
    return [lm.materialize() for lm in models]


class PipelineLoader:
    """3-stage pipeline: page I/O → de-quantization → consumer (paper §4.3.3).

    Stage i loads tensor i while stage i-1's tensor de-quantizes and the
    consumer computes on tensor i-2. ``run`` returns per-stage busy seconds
    so benchmarks can show the overlap win.
    """

    def __init__(self, model: LoadedModel, depth: int = 4):
        self.model = model
        self.depth = depth

    def run(self, consume) -> dict:
        import time

        names = self.model.tensor_names()
        q_io: "queue.Queue" = queue.Queue(maxsize=self.depth)
        q_deq: "queue.Queue" = queue.Queue(maxsize=self.depth)
        busy = {"io": 0.0, "dequant": 0.0, "compute": 0.0}

        def stage_io():
            for name in names:
                t0 = time.perf_counter()
                # record() triggers the lazy planar bit-unpack, so this
                # stage does the real payload-decode work while the dequant
                # stage reconstructs the previous tensor.
                rec = self.model.record(name)
                busy["io"] += time.perf_counter() - t0
                q_io.put((name, rec))
            q_io.put(None)

        def stage_dequant():
            while True:
                item = q_io.get()
                if item is None:
                    q_deq.put(None)
                    return
                name, rec = item
                t0 = time.perf_counter()
                tensor = self.model.tensor(name)
                busy["dequant"] += time.perf_counter() - t0
                q_deq.put((name, tensor))

        t_start = time.perf_counter()
        threads = [threading.Thread(target=stage_io), threading.Thread(target=stage_dequant)]
        for t in threads:
            t.start()
        while True:
            item = q_deq.get()
            if item is None:
                break
            name, tensor = item
            t0 = time.perf_counter()
            consume(name, tensor)
            busy["compute"] += time.perf_counter() - t0
        for t in threads:
            t.join()
        busy["wall"] = time.perf_counter() - t_start
        return busy
