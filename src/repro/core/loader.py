"""Compression-aware model loading (paper §4.3 / Algorithm 2).

Three paper mechanisms, adapted from ONNX-graph surgery to JAX:

* **Augmented computation graph** — instead of inserting ``DequantizeLinear``
  + ``Add`` ONNX nodes, :meth:`LoadedModel.compressed_params` exposes each
  tensor as its quantized base + quantized delta with quant metadata, and
  :func:`reconstruct_jnp` is the jittable dequant+add subgraph. Downstream,
  ``repro.kernels.dequant_matmul`` fuses that subgraph *into* the consuming
  matmul so the full-precision weight never materializes in HBM (the TPU
  upgrade of on-demand decompression).
* **Flexible loading** (§4.3.1) — ``bits=b`` reads only the top ``b``
  bit-planes of each delta payload from the page (true partial I/O) and
  widens the scale by ``2^(nbit-b)`` (Alg. 2 lines 6-8).
* **Share-counted de-quantization** (§4.3.2) — base tensors referenced by
  multiple records are de-quantized once per materialization pass; a
  per-pass countdown (reset when it drains, so repeated ``tensor()`` calls
  or a second ``materialize()`` never go negative) frees the de-quantized
  copy once every sharing record has consumed it.
* **Pipelining** (§4.3.3) — :class:`PipelineLoader` overlaps page I/O,
  de-quantization and consumption in a 3-stage thread pipeline.

Concurrency model (the snapshot-isolation PR; see ``docs/concurrency.md``):

Every handle is backed by a :class:`ModelSnapshot` — an epoch-stamped
immutable view (catalog entry, pinned buffer-pool page frame, per-dim
HNSW index references) captured under the engine lock at ``load_model``
time. After capture, **no read path takes the engine lock**: page bytes
are pinned and immutable, decoded payloads live in the frame's shared
cache, and base codes come from the snapshot's index objects, whose
existing rows are never restructured (vacuum compacts copy-on-write
clones; saves only append). A handle opened before a concurrent
``replace_model``/``delete_model``/``vacuum`` therefore keeps
materializing its weights bit-identically from the snapshot; a handle
opened after the writer's commit sees the new state.
"""

from __future__ import annotations

import queue
import threading
import weakref
from collections import Counter
from collections.abc import Mapping

import numpy as np

from .integrity import CorruptPageError
from .pages import TensorPage, TensorRecord, decode_payload, read_record, read_record_partial
from .quantize import dequantize_delta, dequantize_linear

__all__ = [
    "CompressedParams", "KernelNotReady", "LoadedModel", "ModelSnapshot",
    "PipelineLoader", "materialize_many", "reconstruct_jnp",
]


class KernelNotReady(RuntimeError):
    """A tensor's delta codes exceed the fused kernels' 8-bit operand width.

    Full-precision loads keep ~17-bit deltas; the int8/int4 compute-on-
    compressed kernels need ``nbit <= 8``. Reload the model with flexible
    loading (``load_model(name, bits=8)`` or ``bits=4``) for kernel-ready
    parameters (paper §4.3.1).
    """


def reconstruct_jnp(base_codes, base_scale, base_zp, qdelta, delta_scale, delta_zp):
    """The augmented-graph subgraph: Dequant(base) + Dequant(delta) → Add.

    Pure-jnp, jit/pjit-compatible; bin-centre delta dequant matches
    ``quantize.dequantize_delta``. Shapes: any; dtypes: int8/int32 codes.
    """
    import jax.numpy as jnp

    base = (base_codes.astype(jnp.float32) - base_zp) * base_scale
    delta = (qdelta.astype(jnp.float32) - delta_zp + 0.5) * delta_scale
    return base + delta


class ModelSnapshot:
    """Epoch-stamped immutable view of one model, captured at load time.

    Holds everything a reader needs so that no later access touches shared
    mutable engine state: the committed catalog entry, the pinned page
    frame (``None`` for ``shared_cache=False`` loads, whose bytes are
    private), and strong references to the HNSW index objects for every
    dim the model's records use. Released explicitly via :meth:`close` or
    automatically when the handle is garbage collected (a ``weakref``
    finalizer enqueues the release; the engine drains the queue at its
    next operation boundary — never from inside GC, where lock state is
    unknowable).
    """

    __slots__ = ("epoch", "entry", "frame", "indexes", "_finalizer", "__weakref__")

    def __init__(self, epoch, entry, frame, indexes, release):
        self.epoch = epoch
        self.entry = entry
        self.frame = frame
        self.indexes = indexes
        # release() must not reference self (it would keep the snapshot
        # alive); it enqueues (token, frame) on the engine's release queue.
        self._finalizer = weakref.finalize(self, release)

    def close(self) -> None:
        """Release the snapshot's pins (idempotent)."""
        self._finalizer()


class LoadedModel:
    """Handle over one stored model, loaded without full decompression."""

    def __init__(self, engine, page: TensorPage, info: dict,
                 bits: int | None = None,
                 snapshot: ModelSnapshot | None = None):
        self.engine = engine
        self.page = page
        self.info = info
        self.bits = bits
        self.snapshot = snapshot
        self._records: dict[str, TensorRecord] = {}
        self._order: list[str] = []
        self._index_of: dict[str, int] = {}
        # Records are read with packed payloads only (decode=False): the
        # vectorized planar bit-unpack runs lazily on first tensor access,
        # so open-time cost is header parsing + payload slicing and the
        # pipeline's dequant stage does the unpack work (paper §4.3.3).
        for i in range(page.n_records):
            rec = (
                read_record_partial(page, i, bits, decode=False)
                if bits is not None
                else read_record(page, i, decode=False)
            )
            self._records[rec.name] = rec
            self._index_of[rec.name] = i
            self._order.append(rec.name)
        # Share counts: how many records reference each base vertex. The
        # immutable counts stay in _share; _remaining is the per-pass
        # countdown that controls the cached de-quantized copy's lifetime.
        self._share = Counter((r.dim_key, r.vertex_id) for r in self._records.values())
        self._remaining: dict[tuple[int, int], int] = dict(self._share)
        self._deq_base: dict[tuple[int, int], np.ndarray] = {}
        # Guards the handle-local caches above when one handle is shared
        # across threads. Never held around O(dim) work.
        self._cache_lock = threading.Lock()

    # ------------------------------------------------------------- metadata
    @property
    def architecture(self) -> dict:
        return self.info["architecture"]  # ModelEntry supports item access

    def tensor_names(self) -> list[str]:
        return list(self._order)

    def close(self) -> None:
        """Release the underlying snapshot (pins drop immediately)."""
        if self.snapshot is not None:
            self.snapshot.close()
            self.engine._drain_released()

    def _ensure_decoded(self, rec: TensorRecord) -> TensorRecord:
        """Unpack a record's payload, sharing the decoded codes across every
        handle over the same page version through the frame cache."""
        if rec.qdelta is not None:
            return rec
        # Defense in depth for unverified paths (legacy v2 pages, engines
        # opened with checksums=False): a payload shorter than its metadata
        # claims must fail typed, never decode into silently wrong codes.
        if len(rec.payload) < rec.payload_nbytes:
            raise CorruptPageError(
                f"tensor {rec.name!r}: truncated payload "
                f"({len(rec.payload)} of {rec.payload_nbytes} bytes)"
            )
        frame = self.snapshot.frame if self.snapshot is not None else None
        if frame is None:
            rec.qdelta = decode_payload(rec)
            return rec
        key = (self._index_of[rec.name], self.bits)
        arr = frame.decoded.get(key)  # lock-free read (GIL-atomic dict get)
        if arr is None:
            fresh = decode_payload(rec)
            fresh.setflags(write=False)  # shared across handles: never mutated
            inserted = False
            with frame.lock:
                arr = frame.decoded.get(key)
                if arr is None:
                    frame.decoded[key] = arr = fresh
                    inserted = True
            self.engine.page_pool.count_decoded(hit=False)
            if inserted:
                self.engine.page_pool.note_extra(frame, arr.nbytes)
        else:
            self.engine.page_pool.count_decoded(hit=True)
        rec.qdelta = arr
        return rec

    def record(self, name: str) -> TensorRecord:
        return self._ensure_decoded(self._records[name])

    # ------------------------------------------------- on-demand decompress
    def _index_for(self, rec: TensorRecord):
        if self.snapshot is not None:
            return self.snapshot.indexes[rec.dim_key]
        # Legacy path (no snapshot): consult the live cache under the lock.
        with self.engine._lock:
            self.engine._check_quarantine(rec.dim_key)
            return self.engine.index_cache.get(rec.dim_key)

    def _base(self, rec: TensorRecord) -> np.ndarray:
        """De-quantize a base once per pass; free when every sharer has read it.

        Lock-free against the engine: base codes come from the snapshot's
        index object, whose rows [0, n) are never moved or renumbered
        (saves append; vacuum compacts a copy-on-write clone and installs
        it for *future* snapshots). The countdown resets to the full share
        count when it drains, so the cache is correct across repeated
        ``tensor(name)`` calls and multiple ``materialize()`` passes.
        """
        key = (rec.dim_key, rec.vertex_id)
        with self._cache_lock:
            base = self._deq_base.get(key)
        if base is None:
            index = self._index_for(rec)
            codes, meta = index.vertex_codes(rec.vertex_id)
            base = dequantize_linear(codes, meta)  # O(dim), outside all locks
        with self._cache_lock:
            if key not in self._deq_base and self._share.get(key, 0) > 1:
                self._deq_base[key] = base
            left = self._remaining.get(key, 1) - 1
            if left <= 0:
                self._deq_base.pop(key, None)
                self._remaining[key] = self._share.get(key, 1)  # rearm
            else:
                self._remaining[key] = left
        return base

    def tensor(self, name: str) -> np.ndarray:
        """Reconstruct one tensor to float32 (base + delta, on demand)."""
        rec = self._ensure_decoded(self._records[name])
        base = self._base(rec)
        delta = dequantize_delta(rec.qdelta, rec.meta)
        return (base + delta).reshape(rec.shape).astype(np.float32)

    def materialize(self) -> dict[str, np.ndarray]:
        """Full reconstruction of every tensor (the non-compression-aware path)."""
        return {name: self.tensor(name) for name in list(self._order)}

    def iter_tensors(self):
        """Stream ``(name, tensor)`` record-by-record, in page order.

        The bounded-memory reconstruction path: one tensor is resident
        at a time (plus the shared de-quantized base cache), so a
        consumer that forwards each tensor — the serving layer's chunked
        download — never holds the whole model as one buffer. Entirely
        lock-free off the snapshot, like :meth:`materialize`.
        """
        for name in list(self._order):
            yield name, self.tensor(name)

    # ------------------------------------------ compressed (augmented graph)
    def _compressed_entry(self, name: str) -> dict:
        """Build one tensor's quantized-component entry (Alg. 2 lines 4-5)."""
        rec = self._ensure_decoded(self._records[name])
        index = self._index_for(rec)
        codes, bmeta = index.vertex_codes(rec.vertex_id)
        # int8-safe recentring for the TPU kernels: uint8 codes c with
        # zero-point z dequantize identically as (c-128) with (z-128),
        # and (c-128) fits int8 exactly. Only valid when nbit <= 8 —
        # use flexible loading (bits=8) for kernel-ready params.
        entry = {
            "shape": rec.shape,
            "base_codes": (codes.astype(np.int16) - 128)
            .astype(np.int8).reshape(rec.shape),
            "base_scale": np.float32(bmeta.scale),
            "base_zp": np.float32(bmeta.zero_point - 128),
            "base_mid": np.float32(bmeta.mid),
            "qdelta": rec.qdelta.reshape(rec.shape),
            "delta_scale": np.float32(rec.meta.scale),
            "delta_zp": np.float32(rec.meta.zero_point),
            "delta_mid": np.float32(rec.meta.mid),
            "nbit": rec.meta.nbit,
        }
        if rec.meta.nbit <= 8:
            entry["qdelta_i8"] = ((rec.qdelta - 128).astype(np.int8)
                                  .reshape(rec.shape))
            entry["delta_zp_i8"] = np.float32(rec.meta.zero_point - 128)
        return entry

    def compressed_params(self) -> "CompressedParams":
        """Lazy per-tensor quantized components for compute-on-compressed.

        Returns a mapping whose entries are built on first access — a
        caller serving a subset of tensors (the common case: a decoder's
        matmul weights, not its norm vectors) pays payload decode and
        reshape cost only for what it touches, with the same laziness
        contract as ``tensor(name)``. Each entry carries exactly what
        Alg. 2 retrieves (lines 4-5): the int8 base codes + (scale, zp),
        the quantized delta codes + (scale, zp, nbit). Feed entries to
        ``reconstruct_jnp``, or :meth:`CompressedParams.kernel_operands`
        for the fused ``dequant_matmul`` kernels.
        """
        return CompressedParams(self)


class CompressedParams(Mapping):
    """Lazy name → quantized-components view over a :class:`LoadedModel`.

    Dict-compatible (iteration, ``len``, ``in``, ``.values()``...), but
    entries decode on first ``[name]`` access and are cached. Kernel-ready
    int8 recentrings (``qdelta_i8``/``delta_zp_i8``) are present only when
    the record's delta fits 8 bits; :meth:`kernel_operands` converts their
    absence into a typed :class:`KernelNotReady` instead of a KeyError.
    """

    def __init__(self, lm: "LoadedModel"):
        self._lm = lm
        self._entries: dict[str, dict] = {}

    def __iter__(self):
        return iter(self._lm._order)

    def __len__(self) -> int:
        return len(self._lm._order)

    def __contains__(self, name) -> bool:
        return name in self._lm._records

    def __getitem__(self, name: str) -> dict:
        entry = self._entries.get(name)  # GIL-atomic; duplicate builds benign
        if entry is None:
            entry = self._entries.setdefault(name, self._lm._compressed_entry(name))
        return entry

    def kernel_operands(self, name: str) -> dict:
        """The entry, guaranteed kernel-ready — or :class:`KernelNotReady`."""
        entry = self[name]
        if entry["nbit"] > 8:
            raise KernelNotReady(
                f"tensor {name!r}: delta quantized at {entry['nbit']} bits "
                "> 8; reload with load_model(..., bits=8) for the fused "
                "kernels")
        return entry


def materialize_many(models: list["LoadedModel"]) -> list[dict[str, np.ndarray]]:
    """Materialize several handles, de-quantizing each base once per batch.

    The load-side counterpart of ``StorageEngine.save_models``: a base
    vertex referenced by records in *different* handles (a checkpoint sweep
    loading a family of fine-tunes) is de-quantized once and seeded into
    every holder's per-pass cache, instead of once per handle. Per-handle
    share accounting is untouched — the seeded copy drains through the
    normal countdown, so repeated materialize passes behave exactly as
    before. Returns one ``{name: tensor}`` dict per handle, in order.

    Entirely lock-free against the engine: each handle's snapshot pins its
    index objects, so two handles share a base iff they reference the same
    vertex id in the *same index object* (handles that straddle a vacuum
    hold different index versions and correctly do not share).
    """
    groups: dict[tuple, list[tuple[LoadedModel, TensorRecord]]] = {}
    for lm in models:
        seen: set[tuple] = set()
        for rec in lm._records.values():
            if rec.vertex_id < 0:
                continue
            idx = (lm.snapshot.indexes.get(rec.dim_key)
                   if lm.snapshot is not None else None)
            if idx is None:
                continue
            key = (id(idx), rec.vertex_id)
            if key not in seen:
                seen.add(key)
                groups.setdefault(key, []).append((lm, rec))
    for holders in groups.values():
        if len(holders) < 2:
            continue  # shared within one handle only: _base caches it
        lm0, rec0 = holders[0]
        index = lm0.snapshot.indexes[rec0.dim_key]
        codes, meta = index.vertex_codes(rec0.vertex_id)
        base = dequantize_linear(codes, meta)
        for lm, rec in holders:
            with lm._cache_lock:
                lm._deq_base.setdefault((rec.dim_key, rec.vertex_id), base)
    return [lm.materialize() for lm in models]


class PipelineLoader:
    """3-stage pipeline: page I/O → de-quantization → consumer (paper §4.3.3).

    Stage i loads tensor i while stage i-1's tensor de-quantizes and the
    consumer computes on tensor i-2. ``run`` returns per-stage busy seconds
    so benchmarks can show the overlap win.
    """

    def __init__(self, model: LoadedModel, depth: int = 4):
        self.model = model
        self.depth = depth

    def run(self, consume) -> dict:
        import time

        names = self.model.tensor_names()
        q_io: "queue.Queue" = queue.Queue(maxsize=self.depth)
        q_deq: "queue.Queue" = queue.Queue(maxsize=self.depth)
        busy = {"io": 0.0, "dequant": 0.0, "compute": 0.0}

        def stage_io():
            for name in names:
                t0 = time.perf_counter()
                # record() triggers the lazy planar bit-unpack, so this
                # stage does the real payload-decode work while the dequant
                # stage reconstructs the previous tensor.
                rec = self.model.record(name)
                busy["io"] += time.perf_counter() - t0
                q_io.put((name, rec))
            q_io.put(None)

        def stage_dequant():
            while True:
                item = q_io.get()
                if item is None:
                    q_deq.put(None)
                    return
                name, rec = item
                t0 = time.perf_counter()
                tensor = self.model.tensor(name)
                busy["dequant"] += time.perf_counter() - t0
                q_deq.put((name, tensor))

        t_start = time.perf_counter()
        threads = [threading.Thread(target=stage_io), threading.Thread(target=stage_dequant)]
        for t in threads:
            t.start()
        while True:
            item = q_deq.get()
            if item is None:
                break
            name, tensor = item
            t0 = time.perf_counter()
            consume(name, tensor)
            busy["compute"] += time.perf_counter() - t0
        for t in threads:
            t.join()
        busy["wall"] = time.perf_counter() - t_start
        return busy
