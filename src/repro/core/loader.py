"""Compression-aware model loading (paper §4.3 / Algorithm 2).

Three paper mechanisms, adapted from ONNX-graph surgery to JAX:

* **Augmented computation graph** — instead of inserting ``DequantizeLinear``
  + ``Add`` ONNX nodes, :meth:`LoadedModel.compressed_params` exposes each
  tensor as its quantized base + quantized delta with quant metadata, and
  :func:`reconstruct_jnp` is the jittable dequant+add subgraph. Downstream,
  ``repro.kernels.dequant_matmul`` fuses that subgraph *into* the consuming
  matmul so the full-precision weight never materializes in HBM (the TPU
  upgrade of on-demand decompression).
* **Flexible loading** (§4.3.1) — ``bits=b`` reads only the top ``b``
  bit-planes of each delta payload from the page (true partial I/O) and
  widens the scale by ``2^(nbit-b)`` (Alg. 2 lines 6-8).
* **Share-counted de-quantization** (§4.3.2) — base tensors referenced by
  multiple records are de-quantized once; the share count drops per use and
  the de-quantized copy is freed at zero.
* **Pipelining** (§4.3.3) — :class:`PipelineLoader` overlaps page I/O,
  de-quantization and consumption in a 3-stage thread pipeline.
"""

from __future__ import annotations

import queue
import threading
from collections import Counter

import numpy as np

from .pages import TensorPage, TensorRecord, decode_payload, read_record, read_record_partial
from .quantize import dequantize_delta

__all__ = ["LoadedModel", "PipelineLoader", "reconstruct_jnp"]


def reconstruct_jnp(base_codes, base_scale, base_zp, qdelta, delta_scale, delta_zp):
    """The augmented-graph subgraph: Dequant(base) + Dequant(delta) → Add.

    Pure-jnp, jit/pjit-compatible; bin-centre delta dequant matches
    ``quantize.dequantize_delta``. Shapes: any; dtypes: int8/int32 codes.
    """
    import jax.numpy as jnp

    base = (base_codes.astype(jnp.float32) - base_zp) * base_scale
    delta = (qdelta.astype(jnp.float32) - delta_zp + 0.5) * delta_scale
    return base + delta


class LoadedModel:
    """Handle over one stored model, loaded without full decompression."""

    def __init__(self, engine, page: TensorPage, info: dict, bits: int | None = None):
        self.engine = engine
        self.page = page
        self.info = info
        self.bits = bits
        self._records: dict[str, TensorRecord] = {}
        self._order: list[str] = []
        # Records are read with packed payloads only (decode=False): the
        # vectorized planar bit-unpack runs lazily on first tensor access,
        # so open-time cost is header parsing + payload slicing and the
        # pipeline's dequant stage does the unpack work (paper §4.3.3).
        for i in range(page.n_records):
            rec = (
                read_record_partial(page, i, bits, decode=False)
                if bits is not None
                else read_record(page, i, decode=False)
            )
            self._records[rec.name] = rec
            self._order.append(rec.name)
        # Share counts: how many records reference each base vertex.
        self._share = Counter((r.dim_key, r.vertex_id) for r in self._records.values())
        self._deq_base: dict[tuple[int, int], np.ndarray] = {}

    # ------------------------------------------------------------- metadata
    @property
    def architecture(self) -> dict:
        return self.info["architecture"]

    def tensor_names(self) -> list[str]:
        return list(self._order)

    def _ensure_decoded(self, rec: TensorRecord) -> TensorRecord:
        if rec.qdelta is None:
            rec.qdelta = decode_payload(rec)
        return rec

    def record(self, name: str) -> TensorRecord:
        return self._ensure_decoded(self._records[name])

    # ------------------------------------------------- on-demand decompress
    def _base(self, rec: TensorRecord) -> np.ndarray:
        """De-quantize a base tensor once; free when its share count drains."""
        key = (rec.dim_key, rec.vertex_id)
        if key in self._deq_base:
            base = self._deq_base[key]
        else:
            index = self.engine.index_cache.get(rec.dim_key)
            base = index.dequantize_vertex(rec.vertex_id)
            if self._share[key] > 1:
                self._deq_base[key] = base
        self._share[key] -= 1
        if self._share[key] <= 0:
            self._deq_base.pop(key, None)
        return base

    def tensor(self, name: str) -> np.ndarray:
        """Reconstruct one tensor to float32 (base + delta, on demand)."""
        rec = self._ensure_decoded(self._records[name])
        base = self._base(rec)
        delta = dequantize_delta(rec.qdelta, rec.meta)
        return (base + delta).reshape(rec.shape).astype(np.float32)

    def materialize(self) -> dict[str, np.ndarray]:
        """Full reconstruction of every tensor (the non-compression-aware path)."""
        return {name: self.tensor(name) for name in list(self._order)}

    # ------------------------------------------ compressed (augmented graph)
    def compressed_params(self) -> dict[str, dict]:
        """Per-tensor quantized components for compute-on-compressed.

        Each entry carries exactly what Alg. 2 retrieves (lines 4-5): the
        int8 base codes + (scale, zp), the quantized delta codes + (scale,
        zp, nbit). Feed these to ``reconstruct_jnp`` or to the fused
        ``dequant_matmul`` kernel.
        """
        out = {}
        for name in self._order:
            rec = self._ensure_decoded(self._records[name])
            index = self.engine.index_cache.get(rec.dim_key)
            codes, bmeta = index.vertex_codes(rec.vertex_id)
            # int8-safe recentring for the TPU kernels: uint8 codes c with
            # zero-point z dequantize identically as (c-128) with (z-128),
            # and (c-128) fits int8 exactly. Only valid when nbit <= 8 —
            # use flexible loading (bits=8) for kernel-ready params.
            kernel_ready = rec.meta.nbit <= 8
            out[name] = {
                "shape": rec.shape,
                "base_codes": (codes.astype(np.int16) - 128)
                .astype(np.int8).reshape(rec.shape),
                "base_scale": np.float32(bmeta.scale),
                "base_zp": np.float32(bmeta.zero_point - 128),
                "base_mid": np.float32(bmeta.mid),
                "qdelta": rec.qdelta.reshape(rec.shape),
                "qdelta_i8": ((rec.qdelta - 128).astype(np.int8)
                              .reshape(rec.shape) if kernel_ready else None),
                "delta_scale": np.float32(rec.meta.scale),
                "delta_zp": np.float32(rec.meta.zero_point),
                "delta_zp_i8": np.float32(rec.meta.zero_point - 128),
                "nbit": rec.meta.nbit,
            }
        return out


class PipelineLoader:
    """3-stage pipeline: page I/O → de-quantization → consumer (paper §4.3.3).

    Stage i loads tensor i while stage i-1's tensor de-quantizes and the
    consumer computes on tensor i-2. ``run`` returns per-stage busy seconds
    so benchmarks can show the overlap win.
    """

    def __init__(self, model: LoadedModel, depth: int = 4):
        self.model = model
        self.depth = depth

    def run(self, consume) -> dict:
        import time

        names = self.model.tensor_names()
        q_io: "queue.Queue" = queue.Queue(maxsize=self.depth)
        q_deq: "queue.Queue" = queue.Queue(maxsize=self.depth)
        busy = {"io": 0.0, "dequant": 0.0, "compute": 0.0}

        def stage_io():
            for name in names:
                t0 = time.perf_counter()
                # record() triggers the lazy planar bit-unpack, so this
                # stage does the real payload-decode work while the dequant
                # stage reconstructs the previous tensor.
                rec = self.model.record(name)
                busy["io"] += time.perf_counter() - t0
                q_io.put((name, rec))
            q_io.put(None)

        def stage_dequant():
            while True:
                item = q_io.get()
                if item is None:
                    q_deq.put(None)
                    return
                name, rec = item
                t0 = time.perf_counter()
                tensor = self.model.tensor(name)
                busy["dequant"] += time.perf_counter() - t0
                q_deq.put((name, tensor))

        t_start = time.perf_counter()
        threads = [threading.Thread(target=stage_io), threading.Thread(target=stage_dequant)]
        for t in threads:
            t.start()
        while True:
            item = q_deq.get()
            if item is None:
                break
            name, tensor = item
            t0 = time.perf_counter()
            consume(name, tensor)
            busy["compute"] += time.perf_counter() - t0
        for t in threads:
            t.join()
        busy["wall"] = time.perf_counter() - t_start
        return busy
