"""NeurStore core: tensor-based storage engine, delta quantization, loader."""

from .bufferpool import BufferPool, PageFrame
from .catalog import Catalog, CatalogState, ModelEntry
from .compressed import CompressedModel, CompressedTensor
from .engine import DEFAULT_TAU, DEFAULT_TOLERANCE, SaveReport, StorageEngine
from .faultfs import FaultCrash, FaultFS, FaultInjected, FaultPlan
from .hnsw import HNSWIndex, quantized_l2_batch
from .integrity import (
    CorruptIndexError,
    CorruptJournalError,
    CorruptMetaError,
    CorruptPageError,
    IntegrityError,
    ReadOnlyStoreError,
)
from .loader import (
    CompressedParams,
    KernelNotReady,
    LoadedModel,
    ModelSnapshot,
    PipelineLoader,
    materialize_many,
    reconstruct_jnp,
)
from .maintenance import MaintenanceDaemon
from .quantize import (
    QuantMeta,
    delta_nbit,
    dequantize_delta,
    dequantize_linear,
    dequantize_linear_batch,
    extract_msb,
    quantize_delta,
    quantize_linear,
    quantize_linear_batch,
)

__all__ = [
    "BufferPool",
    "Catalog",
    "CatalogState",
    "CompressedModel",
    "CompressedParams",
    "CompressedTensor",
    "CorruptIndexError",
    "CorruptJournalError",
    "CorruptMetaError",
    "CorruptPageError",
    "DEFAULT_TAU",
    "DEFAULT_TOLERANCE",
    "FaultCrash",
    "FaultFS",
    "FaultInjected",
    "FaultPlan",
    "HNSWIndex",
    "IntegrityError",
    "KernelNotReady",
    "ReadOnlyStoreError",
    "MaintenanceDaemon",
    "ModelEntry",
    "ModelSnapshot",
    "LoadedModel",
    "PageFrame",
    "PipelineLoader",
    "QuantMeta",
    "SaveReport",
    "StorageEngine",
    "delta_nbit",
    "dequantize_delta",
    "dequantize_linear",
    "dequantize_linear_batch",
    "extract_msb",
    "materialize_many",
    "quantize_delta",
    "quantize_linear",
    "quantize_linear_batch",
    "quantized_l2_batch",
    "reconstruct_jnp",
]
