"""NeurStore core: tensor-based storage engine, delta quantization, loader."""

from .catalog import Catalog, CatalogState, ModelEntry
from .engine import DEFAULT_TAU, DEFAULT_TOLERANCE, SaveReport, StorageEngine
from .hnsw import HNSWIndex, quantized_l2_batch
from .loader import LoadedModel, PipelineLoader, materialize_many, reconstruct_jnp
from .quantize import (
    QuantMeta,
    delta_nbit,
    dequantize_delta,
    dequantize_linear,
    dequantize_linear_batch,
    extract_msb,
    quantize_delta,
    quantize_linear,
    quantize_linear_batch,
)

__all__ = [
    "Catalog",
    "CatalogState",
    "DEFAULT_TAU",
    "DEFAULT_TOLERANCE",
    "HNSWIndex",
    "ModelEntry",
    "LoadedModel",
    "PipelineLoader",
    "QuantMeta",
    "SaveReport",
    "StorageEngine",
    "delta_nbit",
    "dequantize_delta",
    "dequantize_linear",
    "dequantize_linear_batch",
    "extract_msb",
    "materialize_many",
    "quantize_delta",
    "quantize_linear",
    "quantize_linear_batch",
    "quantized_l2_batch",
    "reconstruct_jnp",
]
