"""Tensor pages — NeurStore's on-disk unit for compressed tensors (paper §5).

Layout (paper §5, kept byte-faithful in spirit):

* a tensor page holds the complete set of compressed tensors of one model;
* a fixed-length header records offsets and lengths of all delta tensors;
* each record keeps metadata — name, shape, reference to its base tensor
  (index dim + HNSW vertex id), quantization parameters (scale, zero point),
  single-element bit width — followed by a bit-packed payload.

Payloads are stored **planar MSB-first** (see ``bitpack.pack_bits_planar``)
so flexible loading can read only the top ``b`` bit-planes of each tensor —
the storage-level realization of paper §4.3.1.

Pages are read-only once written (paper §5) and addressed by ``bytes`` /
``memoryview`` slicing, the library analogue of the paper's ``mmap``.

Format v3 (this layer) adds integrity framing: each offset-table entry
carries a CRC32 of its record bytes, and the header + table are sealed by
a table CRC, so a single flipped bit or torn write anywhere in the page is
detected (:func:`verify_page`) instead of decoding into silently wrong
deltas. v2 pages (pre-integrity stores) still read; parse damage raises
:class:`~repro.core.integrity.CorruptPageError`, never a bare
``ValueError``/``struct.error``.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

from .bitpack import pack_bits_planar, planar_plane_bytes, unpack_bits_planar
from .integrity import CorruptPageError, crc32
from .quantize import QuantMeta

__all__ = [
    "TensorRecord", "TensorPage", "write_page", "read_page_header",
    "verify_page", "read_record", "read_record_partial", "encode_payload",
    "decode_payload", "read_page_refs", "salvage_page_refs",
    "remap_page_vertices", "page_dim_keys",
]

_MAGIC = b"NSPG"
_VERSION = 3
_LEGACY_VERSION = 2
_HDR = struct.Struct("<4sHI")           # magic, version, n_records
_OFFSET = struct.Struct("<QQ")          # v2: offset, length per record
_OFFSET3 = struct.Struct("<QQI")        # v3: offset, length, record crc32
_TABLE_CRC = struct.Struct("<I")        # v3: crc32 over header + table
_REC_FIXED = struct.Struct("<HBqQqdqBd")  # name_len, ndim, vertex, dim_key, numel, scale, zp, nbit, mid


@dataclasses.dataclass
class TensorRecord:
    """One compressed tensor: quantized delta + reference to its base."""

    name: str
    shape: tuple[int, ...]
    dim_key: int          # flattened length == which HNSW index pool entry
    vertex_id: int        # base tensor vertex in that index
    meta: QuantMeta       # delta quantization parameters
    qdelta: np.ndarray | None = None   # int64 codes (None until payload read)
    payload: bytes = b""

    @property
    def numel(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def payload_nbytes(self) -> int:
        return self.meta.nbit * planar_plane_bytes(self.numel)


def encode_payload(rec: TensorRecord) -> bytes:
    """Planar-pack a record's quantized delta (all planes in one packbits).

    The engine calls this outside its global lock so the bit-packing CPU
    work never serializes concurrent saves.
    """
    if rec.qdelta is None or rec.meta.nbit == 0:
        return b""
    return pack_bits_planar(rec.qdelta, rec.meta.nbit)


def decode_payload(rec: TensorRecord) -> np.ndarray:
    """Unpack a record's payload into int64 codes (inverse of encode)."""
    if rec.meta.nbit == 0:
        return np.zeros(rec.numel, dtype=np.int64)
    return unpack_bits_planar(rec.payload, rec.meta.nbit, rec.numel)


def _encode_record(rec: TensorRecord) -> bytes:
    name_b = rec.name.encode("utf-8")
    payload = rec.payload or encode_payload(rec)
    fixed = _REC_FIXED.pack(
        len(name_b), len(rec.shape), rec.vertex_id, rec.dim_key, rec.numel,
        rec.meta.scale, rec.meta.zero_point, rec.meta.nbit, rec.meta.mid,
    )
    dims = struct.pack(f"<{len(rec.shape)}I", *rec.shape)
    return fixed + name_b + dims + payload


def _decode_record(
    buf: memoryview,
    with_payload: bool = True,
    bits: int | None = None,
    decode: bool = True,
) -> TensorRecord:
    try:
        (name_len, ndim, vertex, dim_key, numel, scale, zp, nbit, mid) = _REC_FIXED.unpack_from(buf, 0)
        off = _REC_FIXED.size
        name = bytes(buf[off:off + name_len]).decode("utf-8")
        off += name_len
        shape = struct.unpack_from(f"<{ndim}I", buf, off)
    except (struct.error, UnicodeDecodeError) as exc:
        raise CorruptPageError(f"truncated tensor record: {exc}") from exc
    off += 4 * ndim
    meta = QuantMeta(scale=scale, zero_point=zp, nbit=nbit, mid=mid)
    rec = TensorRecord(name=name, shape=tuple(shape), dim_key=dim_key,
                       vertex_id=vertex, meta=meta)
    if with_payload and nbit > 0:
        plane = planar_plane_bytes(numel)
        b = nbit if bits is None else min(bits, nbit)
        rec.payload = bytes(buf[off:off + b * plane])
        if len(rec.payload) < b * plane:
            raise CorruptPageError(
                f"record {name!r}: truncated payload "
                f"({len(rec.payload)} of {b * plane} bytes)"
            )
        if b < nbit:
            # MSB-truncated read: widen scale, shift zero point (Alg. 2 l.6-8).
            # The stored payload holds exactly the top b planes, so the
            # record stays self-consistent with its truncated meta.
            shift = nbit - b
            rec.meta = QuantMeta(scale=scale * (1 << shift), zero_point=zp >> shift,
                                 nbit=b, mid=mid)
        if decode:
            rec.qdelta = decode_payload(rec)
    elif with_payload:
        if decode:
            rec.qdelta = np.zeros(numel, dtype=np.int64)
    return rec


@dataclasses.dataclass
class TensorPage:
    """A parsed page: header offsets plus raw buffer for lazy record reads.

    ``crcs`` is the per-record CRC32 list for v3 pages (``None`` for legacy
    v2 pages; a stored CRC of 0 means "not checksummed at write time").
    """

    buf: bytes
    offsets: list[tuple[int, int]]
    crcs: list[int] | None = None
    version: int = _VERSION

    @property
    def n_records(self) -> int:
        return len(self.offsets)


def write_page(records: list[TensorRecord], checksums: bool = True) -> bytes:
    """Serialize records into one read-only v3 tensor page.

    With ``checksums=False`` record CRCs are stored as 0 (skipped on
    verify) — the durability benchmark uses this to isolate CRC cost; the
    table CRC sealing the header is always written (it is one pass over a
    few hundred bytes and torn-header detection depends on it).
    """
    blobs = [_encode_record(r) for r in records]
    header = _HDR.pack(_MAGIC, _VERSION, len(blobs))
    base = len(header) + _OFFSET3.size * len(blobs) + _TABLE_CRC.size
    out = bytearray(header)
    off = base
    for b in blobs:
        out += _OFFSET3.pack(off, len(b), crc32(b) if checksums else 0)
        off += len(b)
    out += _TABLE_CRC.pack(crc32(out))
    for b in blobs:
        out += b
    return bytes(out)


def read_page_header(buf: bytes) -> TensorPage:
    """Parse a page header, verifying framing and bounds.

    Detects torn pages (offset table or records extending past the buffer)
    and, for v3, any damage to the header/offset table via the table CRC.
    Record payload damage is only caught by :func:`verify_page` (per-record
    CRC pass) — header parsing stays O(records)."""
    try:
        magic, version, n = _HDR.unpack_from(buf, 0)
    except struct.error as exc:
        raise CorruptPageError("truncated page header") from exc
    if magic != _MAGIC:
        raise CorruptPageError("not a NeurStore tensor page")
    offsets: list[tuple[int, int]] = []
    crcs: list[int] | None = None
    if version == _LEGACY_VERSION:
        table_end = _HDR.size + _OFFSET.size * n
        if len(buf) < table_end:
            raise CorruptPageError("torn page: offset table truncated")
        for i in range(n):
            o, l = _OFFSET.unpack_from(buf, _HDR.size + i * _OFFSET.size)
            offsets.append((o, l))
        data_start = table_end
    elif version == _VERSION:
        table_end = _HDR.size + _OFFSET3.size * n
        if len(buf) < table_end + _TABLE_CRC.size:
            raise CorruptPageError("torn page: offset table truncated")
        (stored,) = _TABLE_CRC.unpack_from(buf, table_end)
        if crc32(bytes(buf[:table_end])) != stored:
            raise CorruptPageError("page header checksum mismatch")
        crcs = []
        for i in range(n):
            o, l, c = _OFFSET3.unpack_from(buf, _HDR.size + i * _OFFSET3.size)
            offsets.append((o, l))
            crcs.append(c)
        data_start = table_end + _TABLE_CRC.size
    else:
        raise CorruptPageError(f"unsupported tensor page version {version}")
    for o, l in offsets:
        if o < data_start or o + l > len(buf):
            raise CorruptPageError("torn page: record out of bounds")
    return TensorPage(buf=buf, offsets=offsets, crcs=crcs, version=version)


def verify_page(buf: bytes) -> TensorPage:
    """Full integrity check: header/table framing plus per-record CRCs.

    Returns the parsed page on success so callers (frame admission, the
    scrubber, fsck) get the parse for free. Legacy v2 pages pass framing
    and bounds checks only — they carry no checksums to verify.
    """
    page = read_page_header(buf)
    if page.crcs is not None:
        for i, ((o, l), c) in enumerate(zip(page.offsets, page.crcs)):
            if c and crc32(bytes(buf[o:o + l])) != c:
                raise CorruptPageError(f"record {i} checksum mismatch")
    return page


def read_record(page: TensorPage, i: int, with_payload: bool = True,
                decode: bool = True) -> TensorRecord:
    """Read record i. ``decode=False`` keeps the payload as packed bytes
    (``qdelta=None``) so callers can defer bit-unpacking — the loader uses
    this to push decode work into its pipeline's dequant stage."""
    o, l = page.offsets[i]
    return _decode_record(memoryview(page.buf)[o:o + l], with_payload=with_payload,
                          decode=decode)


# Byte offset of the vertex_id field inside _REC_FIXED ("<H B q ...").
_VERTEX_OFF = struct.calcsize("<HB")


def read_page_refs(f) -> list[tuple[int, int]]:
    """``(dim_key, vertex_id)`` per record, reading headers only.

    The engine's lifecycle operations (delete/replace/vacuum) need a
    page's base references but not its payloads; this seeks to each
    record's fixed header instead of reading the whole file, so the cost
    is O(records), not O(page bytes). ``f`` is an open binary file.
    """
    f.seek(0)
    hdr = f.read(_HDR.size)
    try:
        magic, version, n = _HDR.unpack(hdr)
    except struct.error as exc:
        raise CorruptPageError("truncated page header") from exc
    if magic != _MAGIC:
        raise CorruptPageError("not a NeurStore tensor page")
    if version == _LEGACY_VERSION:
        entry = _OFFSET
        table = f.read(entry.size * n)
        if len(table) < entry.size * n:
            raise CorruptPageError("torn page: offset table truncated")
    elif version == _VERSION:
        entry = _OFFSET3
        table = f.read(entry.size * n + _TABLE_CRC.size)
        if len(table) < entry.size * n + _TABLE_CRC.size:
            raise CorruptPageError("torn page: offset table truncated")
        (stored,) = _TABLE_CRC.unpack_from(table, entry.size * n)
        if crc32(hdr + table[:entry.size * n]) != stored:
            raise CorruptPageError("page header checksum mismatch")
    else:
        raise CorruptPageError(f"unsupported tensor page version {version}")
    refs = []
    for i in range(n):
        o = entry.unpack_from(table, i * entry.size)[0]
        f.seek(o + _VERTEX_OFF)
        raw = f.read(16)
        if len(raw) < 16:
            raise CorruptPageError("torn page: record out of bounds")
        vertex, dim = struct.unpack("<qQ", raw)
        refs.append((int(dim), int(vertex)))
    return refs


def salvage_page_refs(buf: bytes) -> list[tuple[int, int]]:
    """Best-effort ``(dim_key, vertex_id)`` refs from a *damaged* page.

    Only records whose stored CRC still verifies contribute (v2 pages and
    CRC-less records: any in-bounds record). Quarantine-path reference
    accounting uses this where under-counting is the safe direction — a
    missed ref merely leaks (``rebuild_vertex_refs`` reclaims it later),
    while an invented ref could keep a dead base alive or, worse, free a
    live one on the decrement side. Never raises.
    """
    try:
        magic, version, n = _HDR.unpack_from(buf, 0)
    except struct.error:
        return []
    if magic != _MAGIC:
        return []
    if version == _VERSION:
        entry, has_crc = _OFFSET3, True
    elif version == _LEGACY_VERSION:
        entry, has_crc = _OFFSET, False
    else:
        return []
    out: list[tuple[int, int]] = []
    for i in range(n):
        base = _HDR.size + i * entry.size
        if base + entry.size > len(buf):
            break  # table itself is torn (or n is garbage)
        fields = entry.unpack_from(buf, base)
        o, l = fields[0], fields[1]
        if o + l > len(buf) or o + _VERTEX_OFF + 16 > len(buf):
            continue
        if has_crc and fields[2] and crc32(bytes(buf[o:o + l])) != fields[2]:
            continue
        vertex, dim = struct.unpack_from("<qQ", buf, o + _VERTEX_OFF)
        out.append((int(dim), int(vertex)))
    return out


def page_dim_keys(page: TensorPage) -> set[int]:
    """Distinct ``dim_key`` values referenced by a parsed page.

    Header-field reads only (no payload touch): snapshot capture uses this
    to know which HNSW indexes a model's records need *before* any tensor
    is reconstructed, so the index references can be pinned into the
    snapshot in one short critical section.
    """
    buf = page.buf
    return {
        struct.unpack_from("<qQ", buf, o + _VERTEX_OFF)[1]
        for o, _l in page.offsets
    }


def remap_page_vertices(buf: bytes, remap: dict[int, int], dim_key: int) -> tuple[bytes, bool]:
    """Patch base-vertex ids of every ``dim_key`` record in a page image.

    Index compaction renumbers vertices; pages are read-only, so the engine
    rewrites affected pages through the catalog journal. Only the 8-byte
    ``vertex_id`` field of matching records is patched in place — names,
    shapes, quantization metadata and bit-packed payloads are untouched, so
    the rewritten page is byte-identical except for the remapped ids (the
    vacuum parity bar rests on this).

    Returns ``(new_buf, changed)``; raises ``KeyError`` if a record still
    references a vertex the remap dropped (a dangling reference — the
    caller must only compact vertices with zero catalog references).
    """
    page = read_page_header(buf)
    out = bytearray(buf)
    changed_idx = []
    for i, (o, _l) in enumerate(page.offsets):
        vertex, dim = struct.unpack_from("<qQ", buf, o + _VERTEX_OFF)
        if dim != dim_key:
            continue
        nv = remap[vertex]
        if nv != vertex:
            struct.pack_into("<q", out, o + _VERTEX_OFF, nv)
            changed_idx.append(i)
    if changed_idx and page.crcs is not None:
        # Patched records invalidate their stored CRCs; re-seal them and
        # the table CRC so the rewritten page still verifies.
        for i in changed_idx:
            if page.crcs[i]:
                o, l = page.offsets[i]
                struct.pack_into(
                    "<I", out, _HDR.size + i * _OFFSET3.size + 16,
                    crc32(bytes(out[o:o + l])),
                )
        table_end = _HDR.size + _OFFSET3.size * len(page.offsets)
        _TABLE_CRC.pack_into(out, table_end, crc32(bytes(out[:table_end])))
    return bytes(out), bool(changed_idx)


def read_record_partial(page: TensorPage, i: int, bits: int,
                        decode: bool = True) -> TensorRecord:
    """Flexible loading: read only the top ``bits`` bit-planes of record i.

    I/O saved is real — only ``bits * plane_bytes`` of the payload region is
    touched, matching the paper's reduced disk I/O claim (Fig. 11).
    """
    o, l = page.offsets[i]
    return _decode_record(memoryview(page.buf)[o:o + l], with_payload=True,
                          bits=bits, decode=decode)
