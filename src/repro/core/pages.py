"""Tensor pages — NeurStore's on-disk unit for compressed tensors (paper §5).

Layout (paper §5, kept byte-faithful in spirit):

* a tensor page holds the complete set of compressed tensors of one model;
* a fixed-length header records offsets and lengths of all delta tensors;
* each record keeps metadata — name, shape, reference to its base tensor
  (index dim + HNSW vertex id), quantization parameters (scale, zero point),
  single-element bit width — followed by a bit-packed payload.

Payloads are stored **planar MSB-first** (see ``bitpack.pack_bits_planar``)
so flexible loading can read only the top ``b`` bit-planes of each tensor —
the storage-level realization of paper §4.3.1.

Pages are read-only once written (paper §5) and addressed by ``bytes`` /
``memoryview`` slicing, the library analogue of the paper's ``mmap``.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

from .bitpack import pack_bits_planar, planar_plane_bytes, unpack_bits_planar
from .quantize import QuantMeta

__all__ = [
    "TensorRecord", "TensorPage", "write_page", "read_page_header",
    "read_record", "read_record_partial", "encode_payload", "decode_payload",
    "read_page_refs", "remap_page_vertices", "page_dim_keys",
]

_MAGIC = b"NSPG"
_VERSION = 2
_HDR = struct.Struct("<4sHI")           # magic, version, n_records
_OFFSET = struct.Struct("<QQ")          # offset, length per record
_REC_FIXED = struct.Struct("<HBqQqdqBd")  # name_len, ndim, vertex, dim_key, numel, scale, zp, nbit, mid


@dataclasses.dataclass
class TensorRecord:
    """One compressed tensor: quantized delta + reference to its base."""

    name: str
    shape: tuple[int, ...]
    dim_key: int          # flattened length == which HNSW index pool entry
    vertex_id: int        # base tensor vertex in that index
    meta: QuantMeta       # delta quantization parameters
    qdelta: np.ndarray | None = None   # int64 codes (None until payload read)
    payload: bytes = b""

    @property
    def numel(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def payload_nbytes(self) -> int:
        return self.meta.nbit * planar_plane_bytes(self.numel)


def encode_payload(rec: TensorRecord) -> bytes:
    """Planar-pack a record's quantized delta (all planes in one packbits).

    The engine calls this outside its global lock so the bit-packing CPU
    work never serializes concurrent saves.
    """
    if rec.qdelta is None or rec.meta.nbit == 0:
        return b""
    return pack_bits_planar(rec.qdelta, rec.meta.nbit)


def decode_payload(rec: TensorRecord) -> np.ndarray:
    """Unpack a record's payload into int64 codes (inverse of encode)."""
    if rec.meta.nbit == 0:
        return np.zeros(rec.numel, dtype=np.int64)
    return unpack_bits_planar(rec.payload, rec.meta.nbit, rec.numel)


def _encode_record(rec: TensorRecord) -> bytes:
    name_b = rec.name.encode("utf-8")
    payload = rec.payload or encode_payload(rec)
    fixed = _REC_FIXED.pack(
        len(name_b), len(rec.shape), rec.vertex_id, rec.dim_key, rec.numel,
        rec.meta.scale, rec.meta.zero_point, rec.meta.nbit, rec.meta.mid,
    )
    dims = struct.pack(f"<{len(rec.shape)}I", *rec.shape)
    return fixed + name_b + dims + payload


def _decode_record(
    buf: memoryview,
    with_payload: bool = True,
    bits: int | None = None,
    decode: bool = True,
) -> TensorRecord:
    (name_len, ndim, vertex, dim_key, numel, scale, zp, nbit, mid) = _REC_FIXED.unpack_from(buf, 0)
    off = _REC_FIXED.size
    name = bytes(buf[off:off + name_len]).decode("utf-8")
    off += name_len
    shape = struct.unpack_from(f"<{ndim}I", buf, off)
    off += 4 * ndim
    meta = QuantMeta(scale=scale, zero_point=zp, nbit=nbit, mid=mid)
    rec = TensorRecord(name=name, shape=tuple(shape), dim_key=dim_key,
                       vertex_id=vertex, meta=meta)
    if with_payload and nbit > 0:
        plane = planar_plane_bytes(numel)
        b = nbit if bits is None else min(bits, nbit)
        rec.payload = bytes(buf[off:off + b * plane])
        if b < nbit:
            # MSB-truncated read: widen scale, shift zero point (Alg. 2 l.6-8).
            # The stored payload holds exactly the top b planes, so the
            # record stays self-consistent with its truncated meta.
            shift = nbit - b
            rec.meta = QuantMeta(scale=scale * (1 << shift), zero_point=zp >> shift,
                                 nbit=b, mid=mid)
        if decode:
            rec.qdelta = decode_payload(rec)
    elif with_payload:
        if decode:
            rec.qdelta = np.zeros(numel, dtype=np.int64)
    return rec


@dataclasses.dataclass
class TensorPage:
    """A parsed page: header offsets plus raw buffer for lazy record reads."""

    buf: bytes
    offsets: list[tuple[int, int]]

    @property
    def n_records(self) -> int:
        return len(self.offsets)


def write_page(records: list[TensorRecord]) -> bytes:
    """Serialize records into one read-only tensor page."""
    blobs = [_encode_record(r) for r in records]
    header = _HDR.pack(_MAGIC, _VERSION, len(blobs))
    table_size = _OFFSET.size * len(blobs)
    base = len(header) + table_size
    out = bytearray(header)
    off = base
    for b in blobs:
        out += _OFFSET.pack(off, len(b))
        off += len(b)
    for b in blobs:
        out += b
    return bytes(out)


def read_page_header(buf: bytes) -> TensorPage:
    magic, version, n = _HDR.unpack_from(buf, 0)
    if magic != _MAGIC:
        raise ValueError("not a NeurStore tensor page")
    if version != _VERSION:
        raise ValueError(f"unsupported tensor page version {version}")
    offsets = []
    pos = _HDR.size
    for _ in range(n):
        o, l = _OFFSET.unpack_from(buf, pos)
        offsets.append((o, l))
        pos += _OFFSET.size
    return TensorPage(buf=buf, offsets=offsets)


def read_record(page: TensorPage, i: int, with_payload: bool = True,
                decode: bool = True) -> TensorRecord:
    """Read record i. ``decode=False`` keeps the payload as packed bytes
    (``qdelta=None``) so callers can defer bit-unpacking — the loader uses
    this to push decode work into its pipeline's dequant stage."""
    o, l = page.offsets[i]
    return _decode_record(memoryview(page.buf)[o:o + l], with_payload=with_payload,
                          decode=decode)


# Byte offset of the vertex_id field inside _REC_FIXED ("<H B q ...").
_VERTEX_OFF = struct.calcsize("<HB")


def read_page_refs(f) -> list[tuple[int, int]]:
    """``(dim_key, vertex_id)`` per record, reading headers only.

    The engine's lifecycle operations (delete/replace/vacuum) need a
    page's base references but not its payloads; this seeks to each
    record's fixed header instead of reading the whole file, so the cost
    is O(records), not O(page bytes). ``f`` is an open binary file.
    """
    f.seek(0)
    magic, version, n = _HDR.unpack(f.read(_HDR.size))
    if magic != _MAGIC:
        raise ValueError("not a NeurStore tensor page")
    if version != _VERSION:
        raise ValueError(f"unsupported tensor page version {version}")
    table = f.read(_OFFSET.size * n)
    refs = []
    for i in range(n):
        o, _l = _OFFSET.unpack_from(table, i * _OFFSET.size)
        f.seek(o + _VERTEX_OFF)
        vertex, dim = struct.unpack("<qQ", f.read(16))
        refs.append((int(dim), int(vertex)))
    return refs


def page_dim_keys(page: TensorPage) -> set[int]:
    """Distinct ``dim_key`` values referenced by a parsed page.

    Header-field reads only (no payload touch): snapshot capture uses this
    to know which HNSW indexes a model's records need *before* any tensor
    is reconstructed, so the index references can be pinned into the
    snapshot in one short critical section.
    """
    buf = page.buf
    return {
        struct.unpack_from("<qQ", buf, o + _VERTEX_OFF)[1]
        for o, _l in page.offsets
    }


def remap_page_vertices(buf: bytes, remap: dict[int, int], dim_key: int) -> tuple[bytes, bool]:
    """Patch base-vertex ids of every ``dim_key`` record in a page image.

    Index compaction renumbers vertices; pages are read-only, so the engine
    rewrites affected pages through the catalog journal. Only the 8-byte
    ``vertex_id`` field of matching records is patched in place — names,
    shapes, quantization metadata and bit-packed payloads are untouched, so
    the rewritten page is byte-identical except for the remapped ids (the
    vacuum parity bar rests on this).

    Returns ``(new_buf, changed)``; raises ``KeyError`` if a record still
    references a vertex the remap dropped (a dangling reference — the
    caller must only compact vertices with zero catalog references).
    """
    page = read_page_header(buf)
    out = bytearray(buf)
    changed = False
    for o, _l in page.offsets:
        vertex, dim = struct.unpack_from("<qQ", buf, o + _VERTEX_OFF)
        if dim != dim_key:
            continue
        nv = remap[vertex]
        if nv != vertex:
            struct.pack_into("<q", out, o + _VERTEX_OFF, nv)
            changed = True
    return bytes(out), changed


def read_record_partial(page: TensorPage, i: int, bits: int,
                        decode: bool = True) -> TensorRecord:
    """Flexible loading: read only the top ``bits`` bit-planes of record i.

    I/O saved is real — only ``bits * plane_bytes`` of the payload region is
    touched, matching the paper's reduced disk I/O claim (Fig. 11).
    """
    o, l = page.offsets[i]
    return _decode_record(memoryview(page.buf)[o:o + l], with_payload=True,
                          bits=bits, decode=decode)
