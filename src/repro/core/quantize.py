"""Quantization primitives for NeurStore (paper §2.4, §4.2).

Two quantizers live here:

* ``quantize_linear`` — standard linear asymmetric PTQ used for *base tensors*
  stored in HNSW vertices (8-bit, paper §4.1 "each base tensor is quantized to
  8-bit using linear quantization prior to insertion").
* ``quantize_delta`` — the adaptive delta quantizer of Eq. (2)/(3):
  ``nbit = ceil(log2((dmax - dmin) / 2p))``, ``scale = 2p``,
  ``zero_point = floor(-dmin / scale)``, ``q_i = floor(d_i / scale) + zp``.

Per paper §5, delta computation and quantization run in double precision to
avoid rounding artifacts of low-precision intermediates.

Reconstruction uses bin *centres* (``+0.5`` bin) so the paper's stated bound —
"any points in between are within the distance of p to their closest
quantized number" — holds exactly: floor-binning + centre-dequant gives
``|x - dq(q(x))| <= p``.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "QuantMeta",
    "quantize_linear",
    "quantize_linear_batch",
    "dequantize_linear",
    "dequantize_linear_batch",
    "delta_nbit",
    "quantize_delta",
    "dequantize_delta",
    "extract_msb",
]

# Upper bound on adaptive bit width; beyond this the tensor should become a
# new base vertex instead (engine enforces tau before we ever get here).
MAX_NBIT = 32


@dataclasses.dataclass(frozen=True)
class QuantMeta:
    """Per-tensor quantization parameters, serialized as the record prefix."""

    scale: float
    zero_point: int
    nbit: int
    # Mid value used when nbit == 0 (range <= 2p: a single bin suffices).
    mid: float = 0.0


def quantize_linear(x: np.ndarray, nbit: int = 8) -> tuple[np.ndarray, QuantMeta]:
    """Linear asymmetric quantization of a full tensor to ``nbit`` bits.

    ``s = (max - min) / (2^b - 1)``; ``q = round(x / s) + z``;
    ``z = round(-min / s)``. Degenerate (constant) tensors quantize to a
    single level with the constant stored in ``mid``.
    """
    x64 = np.asarray(x, dtype=np.float64).ravel()
    levels = (1 << nbit) - 1
    xmin = float(x64.min())
    xmax = float(x64.max())
    if xmax <= xmin:  # constant tensor
        meta = QuantMeta(scale=0.0, zero_point=0, nbit=nbit, mid=xmin)
        return np.zeros(x64.shape, dtype=np.int64), meta
    scale = (xmax - xmin) / levels
    zero_point = int(round(-xmin / scale))
    q = np.clip(np.round(x64 / scale).astype(np.int64) + zero_point, 0, levels)
    return q, QuantMeta(scale=scale, zero_point=zero_point, nbit=nbit)


def quantize_linear_batch(
    x: np.ndarray, nbit: int = 8
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Row-wise :func:`quantize_linear` over a ``(B, D)`` block in one sweep.

    Returns ``(codes, scales, zero_points, mids)`` with per-row parameter
    arrays. Bit-exact with the per-tensor path: every operation (min/max,
    ``x / s``, round-half-even, clip) is the same float64 computation
    broadcast over rows, so ``codes[i]`` equals ``quantize_linear(x[i])[0]``
    exactly (asserted in ``tests/test_batch_ingest.py``). Constant rows get
    ``scale == 0`` with the constant in ``mids`` — same convention as the
    scalar path.
    """
    x2 = np.atleast_2d(np.asarray(x, dtype=np.float64))
    b, _d = x2.shape
    levels = (1 << nbit) - 1
    xmin = x2.min(axis=1)
    xmax = x2.max(axis=1)
    const = xmax <= xmin
    scales = np.where(const, 0.0, (xmax - xmin) / levels)
    safe = np.where(const, 1.0, scales)
    zps = np.where(const, 0, np.round(-xmin / safe)).astype(np.int64)
    # Fused float path: round yields integral float64 (exact ≤ 2^53), so
    # adding the zero-point and clipping before the single int cast is
    # value-identical to the scalar path's int64 arithmetic.
    q = np.round(x2 / safe[:, None])
    q += zps.astype(np.float64)[:, None]
    np.clip(q, 0, levels, out=q)
    codes = q.astype(np.int64)
    codes[const] = 0
    mids = np.where(const, xmin, 0.0)
    return codes, scales, zps, mids


def dequantize_linear(q: np.ndarray, meta: QuantMeta) -> np.ndarray:
    if meta.scale == 0.0:
        return np.full(q.shape, meta.mid, dtype=np.float64)
    return (q.astype(np.float64) - meta.zero_point) * meta.scale


def dequantize_linear_batch(
    codes: np.ndarray,
    scales: np.ndarray,
    zero_points: np.ndarray,
    mids: np.ndarray,
) -> np.ndarray:
    """Row-wise inverse of :func:`quantize_linear_batch` → ``(B, D)`` float64."""
    c2 = np.atleast_2d(codes)
    s = np.asarray(scales, dtype=np.float64)
    z = np.asarray(zero_points, dtype=np.float64)
    deq = (c2.astype(np.float64) - z[:, None]) * s[:, None]
    const = s == 0.0
    if const.any():
        deq[const] = np.asarray(mids, dtype=np.float64)[const, None]
    return deq


def delta_nbit(dmin: float, dmax: float, p: float) -> int:
    """Eq. (2): bit width for a delta with range [dmin, dmax] at tolerance p."""
    rng = dmax - dmin
    if rng <= 2.0 * p:
        return 0
    nbit = int(math.ceil(math.log2(rng / (2.0 * p))))
    return max(1, min(nbit, MAX_NBIT))


def quantize_delta(delta: np.ndarray, p: float) -> tuple[np.ndarray, QuantMeta]:
    """Eq. (3): adaptive linear asymmetric quantization of a delta tensor.

    ``scale = 2p``; ``zero_point = floor(-dmin / scale)``;
    ``q_i = floor(d_i / scale) + zero_point``. Values are clipped into
    ``[0, 2^nbit - 1]`` (zero_point guarantees the min lands at 0 or 1).
    """
    d64 = np.asarray(delta, dtype=np.float64).ravel()
    dmin = float(d64.min())
    dmax = float(d64.max())
    nbit = delta_nbit(dmin, dmax, p)
    if nbit == 0:
        # One bin: everything reconstructs to the range midpoint, err <= p.
        meta = QuantMeta(scale=2.0 * p, zero_point=0, nbit=0, mid=(dmin + dmax) / 2.0)
        return np.zeros(d64.shape, dtype=np.int64), meta
    scale = 2.0 * p
    # Paper writes zp = floor(-dmin/scale); that leaves q_min = -1 whenever
    # dmin/scale is non-integral (floor(x)+floor(-x) = -1), and clipping the
    # stray -1 breaks the |err| <= p guarantee. zp = -floor(dmin/scale) pins
    # q_min to exactly 0 — same quantity up to the paper's off-by-one.
    zero_point = -int(math.floor(dmin / scale))
    q = np.floor(d64 / scale).astype(np.int64) + zero_point
    qmax = int(q.max())
    while qmax > (1 << nbit) - 1 and nbit < MAX_NBIT:
        # Rare bin-alignment overflow (range/scale lands exactly on a power
        # of two): widen by one bit rather than clip and violate the bound.
        nbit += 1
    q = np.clip(q, 0, (1 << nbit) - 1)
    return q, QuantMeta(scale=scale, zero_point=zero_point, nbit=nbit)


def dequantize_delta(q: np.ndarray, meta: QuantMeta) -> np.ndarray:
    """Bin-centre reconstruction: ``(q - zp + 0.5) * scale`` (err <= p)."""
    if meta.nbit == 0:
        return np.full(q.shape, meta.mid, dtype=np.float64)
    return (q.astype(np.float64) - meta.zero_point + 0.5) * meta.scale


def extract_msb(q: np.ndarray, meta: QuantMeta, b: int) -> tuple[np.ndarray, QuantMeta]:
    """Flexible loading (Alg. 2 lines 6-8): keep the ``b`` most-significant
    bits of a quantized delta and widen the scale by ``2^(nbit-b)``.
    """
    if meta.nbit <= b:
        return q, meta
    shift = meta.nbit - b
    q_trunc = q >> shift
    meta_trunc = QuantMeta(
        scale=meta.scale * (1 << shift),
        zero_point=meta.zero_point >> shift,
        nbit=b,
        mid=meta.mid,
    )
    return q_trunc, meta_trunc
