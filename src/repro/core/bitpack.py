"""Bit-packing for n-bit quantized payloads (paper §5: "bit-packed payload").

Values are packed MSB-first so that *flexible loading* (paper §4.3.1) can
read a byte-aligned prefix of each value's bits: with ``pack_bits_planar`` the
payload is stored as ``nbit`` bit-planes ordered from most significant to
least significant, so reading the first ``b`` planes yields exactly
``extract_msb(q, b)``. This mirrors NeurStore's ability to fetch only the
most-significant bits of each delta tensor from disk.
"""

from __future__ import annotations

import numpy as np

__all__ = ["pack_bits", "unpack_bits", "pack_bits_planar", "unpack_bits_planar", "planar_plane_bytes"]


def pack_bits(values: np.ndarray, nbit: int) -> bytes:
    """Pack unsigned ints (< 2^nbit) into a dense MSB-first bitstream."""
    if nbit == 0 or values.size == 0:
        return b""
    v = np.ascontiguousarray(values.ravel(), dtype=np.uint64)
    shifts = np.arange(nbit - 1, -1, -1, dtype=np.uint64)
    bits = ((v[:, None] >> shifts[None, :]) & 1).astype(np.uint8)
    return np.packbits(bits.ravel()).tobytes()


def unpack_bits(data: bytes, nbit: int, count: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`; returns int64 values of length ``count``."""
    if nbit == 0 or count == 0:
        return np.zeros(count, dtype=np.int64)
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8), count=count * nbit)
    bits = bits.reshape(count, nbit).astype(np.int64)
    weights = (1 << np.arange(nbit - 1, -1, -1, dtype=np.int64))
    return bits @ weights


def planar_plane_bytes(count: int) -> int:
    """Bytes used by one bit-plane for ``count`` values."""
    return (count + 7) // 8


# Values per chunk for planar (un)packing: multiple of 8 so every chunk
# boundary is byte-aligned within a plane; sized to keep the per-chunk bit
# matrix in L2 even at nbit=64.
_PLANE_CHUNK = 1 << 16


def pack_bits_planar(values: np.ndarray, nbit: int) -> bytes:
    """Pack as ``nbit`` bit-planes, most-significant plane first.

    Plane ``k`` (0-based) holds bit ``nbit-1-k`` of every value. A reader
    wanting only the top ``b`` bits reads ``b * planar_plane_bytes(n)`` bytes.

    All planes of a value-chunk are built with one broadcast shift and one
    row-wise ``np.packbits`` (``axis=1`` pads each plane independently to a
    byte boundary — exactly the planar on-disk layout). Values are
    processed in byte-aligned chunks so transient memory stays bounded at
    ~9·nbit·CHUNK bytes for any input size and the working set stays
    cache-resident; only the final chunk may be ragged, and its per-row
    padding coincides with the global plane padding.
    """
    if nbit == 0 or values.size == 0:
        return b""
    v = np.ascontiguousarray(values.ravel(), dtype=np.uint64)
    n = v.size
    plane_nbytes = planar_plane_bytes(n)
    shifts = np.arange(nbit - 1, -1, -1, dtype=np.uint64)[:, None]
    out = np.empty((nbit, plane_nbytes), dtype=np.uint8)
    chunk = _PLANE_CHUNK  # multiple of 8 → chunk planes stay byte-aligned
    for start in range(0, n, chunk):
        seg = v[start:start + chunk]
        bits = ((seg[None, :] >> shifts) & np.uint64(1)).astype(np.uint8)
        out[:, start // 8: start // 8 + (seg.size + 7) // 8] = np.packbits(bits, axis=1)
    return out.tobytes()


def unpack_bits_planar(data: bytes, nbit: int, count: int, b: int | None = None) -> np.ndarray:
    """Unpack the top ``b`` (default all) bit-planes into int64 values.

    Returns values of width ``min(b, nbit)`` — i.e. already MSB-truncated,
    matching :func:`repro.core.quantize.extract_msb` on the full values.
    Inverse of :func:`pack_bits_planar`: per byte-aligned value-chunk, one
    ``np.unpackbits`` over the (b, chunk_bytes) view and an in-place
    shift-or fold over the ≤64 plane rows — transient memory is bounded by
    the chunk, not by ``b·count``.
    """
    if nbit == 0 or count == 0:
        return np.zeros(count, dtype=np.int64)
    b = nbit if b is None else min(b, nbit)
    if b <= 0:
        return np.zeros(count, dtype=np.int64)
    plane_nbytes = planar_plane_bytes(count)
    planes = np.frombuffer(data, dtype=np.uint8)[: b * plane_nbytes]
    planes = planes.reshape(b, plane_nbytes)
    acc = np.empty(count, dtype=np.int64)
    chunk = _PLANE_CHUNK
    for start in range(0, count, chunk):
        stop = min(start + chunk, count)
        seg = planes[:, start // 8: (stop + 7) // 8]
        bits = np.unpackbits(seg, axis=1, count=stop - start)
        out = acc[start:stop]
        out[:] = bits[0]
        for k in range(1, b):
            out <<= 1
            out |= bits[k]
    return acc
