"""Bit-packing for n-bit quantized payloads (paper §5: "bit-packed payload").

Values are packed MSB-first so that *flexible loading* (paper §4.3.1) can
read a byte-aligned prefix of each value's bits: with ``pack_bits_planar`` the
payload is stored as ``nbit`` bit-planes ordered from most significant to
least significant, so reading the first ``b`` planes yields exactly
``extract_msb(q, b)``. This mirrors NeurStore's ability to fetch only the
most-significant bits of each delta tensor from disk.
"""

from __future__ import annotations

import numpy as np

__all__ = ["pack_bits", "unpack_bits", "pack_bits_planar", "unpack_bits_planar", "planar_plane_bytes"]


def pack_bits(values: np.ndarray, nbit: int) -> bytes:
    """Pack unsigned ints (< 2^nbit) into a dense MSB-first bitstream."""
    if nbit == 0 or values.size == 0:
        return b""
    v = np.ascontiguousarray(values.ravel(), dtype=np.uint64)
    shifts = np.arange(nbit - 1, -1, -1, dtype=np.uint64)
    bits = ((v[:, None] >> shifts[None, :]) & 1).astype(np.uint8)
    return np.packbits(bits.ravel()).tobytes()


def unpack_bits(data: bytes, nbit: int, count: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`; returns int64 values of length ``count``."""
    if nbit == 0 or count == 0:
        return np.zeros(count, dtype=np.int64)
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8), count=count * nbit)
    bits = bits.reshape(count, nbit).astype(np.int64)
    weights = (1 << np.arange(nbit - 1, -1, -1, dtype=np.int64))
    return bits @ weights


def planar_plane_bytes(count: int) -> int:
    """Bytes used by one bit-plane for ``count`` values."""
    return (count + 7) // 8


def pack_bits_planar(values: np.ndarray, nbit: int) -> bytes:
    """Pack as ``nbit`` bit-planes, most-significant plane first.

    Plane ``k`` (0-based) holds bit ``nbit-1-k`` of every value. A reader
    wanting only the top ``b`` bits reads ``b * planar_plane_bytes(n)`` bytes.
    """
    if nbit == 0 or values.size == 0:
        return b""
    v = np.ascontiguousarray(values.ravel(), dtype=np.uint64)
    out = bytearray()
    for k in range(nbit - 1, -1, -1):
        plane = ((v >> np.uint64(k)) & 1).astype(np.uint8)
        out += np.packbits(plane).tobytes()
    return bytes(out)


def unpack_bits_planar(data: bytes, nbit: int, count: int, b: int | None = None) -> np.ndarray:
    """Unpack the top ``b`` (default all) bit-planes into int64 values.

    Returns values of width ``min(b, nbit)`` — i.e. already MSB-truncated,
    matching :func:`repro.core.quantize.extract_msb` on the full values.
    """
    if nbit == 0 or count == 0:
        return np.zeros(count, dtype=np.int64)
    b = nbit if b is None else min(b, nbit)
    plane_nbytes = planar_plane_bytes(count)
    buf = np.frombuffer(data, dtype=np.uint8)
    acc = np.zeros(count, dtype=np.int64)
    for k in range(b):
        plane = np.unpackbits(buf[k * plane_nbytes:(k + 1) * plane_nbytes], count=count)
        acc = (acc << 1) | plane.astype(np.int64)
    return acc
