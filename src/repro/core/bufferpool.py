"""Tensor-page buffer pool — the single path to page bytes (concurrency PR).

A database-style buffer pool over NeurStore's read-only tensor pages: a
fixed byte budget, LRU eviction, pin counts, and per-frame locks. Every
page read in the engine and the loader goes through :meth:`BufferPool.get`,
so N concurrent readers of one model share ONE copy of the page bytes and
ONE decoded copy of each bit-packed payload instead of re-reading and
re-unpacking per handle (the seed behaviour).

Design points (see ``docs/concurrency.md``):

* **Frames are immutable once loaded.** A tensor page is read-only on disk
  (pages are never patched in place — vacuum rewrites are copy-on-write
  under a *new* page name), so ``frame.data`` never changes after the load
  completes and readers need no lock to use it.
* **Pin counts, not borrow checking.** ``get`` returns the frame pinned;
  a pinned frame is never evicted, so a snapshot reader can hold page
  bytes across an arbitrarily long materialization while unrelated reads
  churn the pool. Unpin when done (snapshot release does this).
* **Per-frame read-mostly locks.** The pool lock covers only the frame
  table and byte accounting. Loading a missed page and populating the
  frame's decoded-payload cache happen under the *frame's* lock (or an
  event wait), so a slow page read never blocks hits on other frames.
* **Detached frames.** ``invalidate`` (called when a writer unlinks or
  rewrites a page) removes the frame from the table; if readers still pin
  it, the frame survives *detached* — its bytes stay valid for those
  readers, it no longer counts against the budget, and it is dropped when
  the last pin goes.
* **Budget invariant.** After every operation,
  ``resident_bytes() <= max(budget, pinned_bytes())``: the pool only
  exceeds its budget when pinned frames alone exceed it (it can never
  evict those), and then holds nothing unpinned. The hypothesis property
  test in ``tests/test_bufferpool.py`` drives random op sequences against
  exactly this invariant.
"""

from __future__ import annotations

import threading
from collections import OrderedDict

from ..obs.metrics import default_registry

__all__ = ["BufferPool", "PageFrame"]

# Process-wide pool metrics (docs/observability.md). Counters sum over
# every pool in the process; the byte gauges attach per-pool weakly via
# attach_gauges() (called by the owning engine) so a collected pool
# drops out of the sum.
_REG = default_registry()
_M_HITS = _REG.counter(
    "neurstore_pool_hits_total", "Buffer-pool frame hits."
)
_M_MISSES = _REG.counter(
    "neurstore_pool_misses_total", "Buffer-pool frame misses (page loads)."
)
_M_EVICTIONS = _REG.counter(
    "neurstore_pool_evictions_total", "Buffer-pool frames evicted."
)
_M_DECODED_HITS = _REG.counter(
    "neurstore_pool_decoded_hits_total",
    "Decoded-payload cache hits (shared dequant skipped).",
)
_M_DECODED_MISSES = _REG.counter(
    "neurstore_pool_decoded_misses_total",
    "Decoded-payload cache misses (payload unpacked).",
)
_M_RESIDENT = _REG.gauge(
    "neurstore_pool_resident_bytes",
    "Bytes resident in buffer pools, summed over open pools.",
)
_M_PINNED = _REG.gauge(
    "neurstore_pool_pinned_bytes",
    "Resident bytes pinned by live snapshots, summed over open pools.",
)
_M_BUDGET = _REG.gauge(
    "neurstore_pool_budget_bytes",
    "Buffer-pool byte budget, summed over open pools.",
)


class PageFrame:
    """One resident page: immutable bytes + a shared decoded-payload cache.

    ``data`` is the raw page image (never mutated after load). ``decoded``
    maps ``(record_index, bits)`` to a read-only ndarray of unpacked delta
    codes, shared by every handle over this page version; ``page`` caches
    the parsed header. Both are populated under ``lock`` (read-mostly:
    lookups are lock-free dict reads, inserts take the lock and re-check).
    """

    __slots__ = (
        "key", "data", "size", "extra", "pins", "lock", "decoded", "page",
        "ready", "error", "detached",
    )

    def __init__(self, key: str):
        self.key = key
        self.data: bytes | None = None
        self.size = 0
        self.extra = 0          # decoded-cache bytes accounted on top of data
        self.pins = 0
        self.lock = threading.Lock()
        self.decoded: dict[tuple[int, int | None], object] = {}
        self.page = None        # parsed TensorPage (loader-level cache)
        self.ready = threading.Event()
        self.error: BaseException | None = None
        self.detached = False

    @property
    def nbytes(self) -> int:
        return self.size + self.extra


class BufferPool:
    """Byte-budgeted LRU pool of :class:`PageFrame` objects."""

    def __init__(self, budget_bytes: int):
        self.budget = int(budget_bytes)
        self._frames: "OrderedDict[str, PageFrame]" = OrderedDict()
        self._detached: set[PageFrame] = set()
        self._resident = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.decoded_hits = 0
        self.decoded_misses = 0

    def attach_gauges(self) -> None:
        """Sum this pool's byte gauges into the process-wide registry.

        Called by the owning engine (not __init__) so bare pools built by
        unit tests don't pollute the process gauges. Idempotence is not
        required — attach once per pool.
        """
        _M_RESIDENT.attach(self, lambda p: p._resident)
        _M_PINNED.attach(self, lambda p: p.pinned_bytes())
        _M_BUDGET.attach(self, lambda p: p.budget)

    def count_decoded(self, hit: bool) -> None:
        """Decoded-payload cache accounting (called by the loader)."""
        if hit:
            self.decoded_hits += 1
            _M_DECODED_HITS.inc()
        else:
            self.decoded_misses += 1
            _M_DECODED_MISSES.inc()

    # ------------------------------------------------------------------ get
    def get(self, key: str, loader) -> PageFrame:
        """Fetch the frame for ``key``, loading via ``loader()`` on a miss.

        Returns the frame **pinned** — the caller owns one pin and must
        :meth:`unpin` when done. Concurrent getters of the same missing
        key block on the loading frame's event instead of the pool lock,
        so one disk read serves all of them.
        """
        owner = False
        with self._lock:
            frame = self._frames.get(key)
            if frame is not None:
                frame.pins += 1
                self._frames.move_to_end(key)
                self.hits += 1
                _M_HITS.inc()
            else:
                self.misses += 1
                _M_MISSES.inc()
                frame = PageFrame(key)
                frame.pins = 1
                self._frames[key] = frame
                owner = True
        if not owner:
            frame.ready.wait()
            if frame.error is not None:
                self.unpin(frame)
                raise frame.error
            return frame
        try:
            data = loader()
        except BaseException as exc:
            frame.error = exc
            with self._lock:
                frame.pins -= 1
                if self._frames.get(key) is frame:
                    del self._frames[key]
                if frame.pins <= 0 and frame.detached:
                    # An invalidate() raced the failed load (it popped the
                    # frame and parked it as detached): drop it here, or it
                    # would sit in _detached with zero pins forever.
                    self._detached.discard(frame)
            frame.ready.set()
            raise
        with self._lock:
            # size is assigned and accounted in ONE critical section: an
            # invalidate() racing this load pops the frame while its size
            # is still 0, so it can never subtract bytes never added.
            frame.data = data
            frame.size = len(data)
            if self._frames.get(key) is frame:
                self._resident += frame.nbytes
                self._evict_locked(self.budget)
        frame.ready.set()
        return frame

    # ----------------------------------------------------------- pin/unpin
    def pin(self, frame: PageFrame) -> None:
        with self._lock:
            frame.pins += 1

    def unpin(self, frame: PageFrame) -> None:
        with self._lock:
            frame.pins -= 1
            if frame.pins <= 0 and frame.detached:
                self._detached.discard(frame)
            elif frame.pins <= 0:
                # A pinned-over-budget pool shrinks as soon as pins drain.
                self._evict_locked(self.budget)

    # ------------------------------------------------------------- account
    def note_extra(self, frame: PageFrame, nbytes: int) -> None:
        """Account decoded-cache growth on ``frame`` against the budget."""
        with self._lock:
            frame.extra += nbytes
            if not frame.detached and self._frames.get(frame.key) is frame:
                self._resident += nbytes
                self._evict_locked(self.budget)

    def invalidate(self, key: str) -> None:
        """Forget ``key`` (the page was unlinked or rewritten copy-on-write).

        Pinned frames survive detached: their bytes stay valid for the
        snapshot readers holding them, but new ``get`` calls load fresh.
        """
        with self._lock:
            frame = self._frames.pop(key, None)
            if frame is None:
                return
            self._resident -= frame.nbytes
            if frame.pins > 0:
                frame.detached = True
                self._detached.add(frame)

    # ------------------------------------------------------------ eviction
    def _evict_locked(self, target: int) -> None:
        while self._resident > target:
            victim = None
            for f in self._frames.values():  # oldest-first (LRU order)
                if f.pins <= 0 and f.ready.is_set():
                    victim = f
                    break
            if victim is None:
                return  # everything resident is pinned (or still loading)
            del self._frames[victim.key]
            self._resident -= victim.nbytes
            self.evictions += 1
            _M_EVICTIONS.inc()

    def trim(self, target_bytes: int | None = None) -> int:
        """Evict unpinned frames until resident bytes reach ``target_bytes``
        (the budget by default). Returns bytes reclaimed — the maintenance
        daemon calls this on pool pressure."""
        with self._lock:
            before = self._resident
            self._evict_locked(self.budget if target_bytes is None
                               else int(target_bytes))
            return before - self._resident

    # --------------------------------------------------------------- stats
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident

    def pinned_bytes(self) -> int:
        with self._lock:
            return self._pinned_locked()

    def _pinned_locked(self) -> int:
        pinned = sum(f.nbytes for f in self._frames.values() if f.pins > 0)
        pinned += sum(f.nbytes for f in self._detached)
        return pinned

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "decoded_hits": self.decoded_hits,
                "decoded_misses": self.decoded_misses,
                "resident": len(self._frames),
                "resident_bytes": self._resident,
                "pinned_bytes": self._pinned_locked(),
                "detached": len(self._detached),
                "budget_bytes": self.budget,
            }
