"""Background maintenance daemon — incremental auto-vacuum + pool trims.

The write path never has to stop the world to reclaim space: a daemon
thread watches the store and does one small increment of work per step,
yielding the engine lock between steps so reader snapshots and writer
commits interleave freely.

Each :meth:`MaintenanceDaemon.step` does exactly:

1. **one dim-group of auto-vacuum** — round-robin over the index dims,
   calling ``engine.vacuum(min_dead_fraction=…, dims=[dim])`` for the
   single dim under the cursor. The engine's vacuum already skips dims
   with in-flight saves and is copy-on-write, so a step never invalidates
   a live reader; the dead-vertex threshold keeps steps cheap until
   enough garbage accrues to be worth a compaction.
2. **buffer-pool pressure trim** — when resident bytes exceed the high
   watermark, evict unpinned frames back down to it.
3. **index-cache trim** — the existing commit-boundary budget enforcement,
   run off the write path too so a read-only workload also converges.
4. **one scrub increment** — ``engine.scrub(scrub_models)`` verifies the
   next committed page's checksums (round-robin), so latent disk
   corruption is quarantined before a reader trips on it.

Tests drive ``step()`` synchronously for determinism; ``start()`` spawns
the daemon thread that calls it every ``interval_s`` seconds.

Failure containment (the daemon must never die silently): a step that
raises is counted and remembered (``errors`` / ``last_error``), and the
loop backs off exponentially (capped at ``max_backoff_s``) while errors
persist, resetting to ``interval_s`` on the first success. If the loop
body itself somehow escapes — a ``BaseException``, an error in the backoff
logic — a supervisor wrapper records it, increments ``restarts``, and
restarts the loop rather than leaving a dead thread that looks alive from
``stats()``.
"""

from __future__ import annotations

import threading
import time

from ..obs.metrics import default_registry
from ..obs.trace import trace

__all__ = ["MaintenanceDaemon"]

# Process-wide maintenance metrics (docs/observability.md), summed over
# every daemon in the process.
_REG = default_registry()
_M_STEPS = _REG.counter(
    "neurstore_maintenance_steps_total", "Completed maintenance steps."
)
_M_VACUUMED = _REG.counter(
    "neurstore_maintenance_vacuumed_vertices_total",
    "Dead vertices reclaimed by auto-vacuum.",
)
_M_TRIMMED = _REG.counter(
    "neurstore_maintenance_pool_bytes_trimmed_total",
    "Buffer-pool bytes evicted by pressure trims.",
)
_M_SCRUBBED = _REG.counter(
    "neurstore_maintenance_pages_scrubbed_total",
    "Pages checksum-verified by the scrubber.",
)
_M_CORRUPT = _REG.counter(
    "neurstore_maintenance_corrupt_found_total",
    "Corrupt pages found (and quarantined) by the scrubber.",
)
_M_ERRORS = _REG.counter(
    "neurstore_maintenance_errors_total", "Maintenance steps that raised."
)
_M_RESTARTS = _REG.counter(
    "neurstore_maintenance_restarts_total",
    "Supervisor restarts of an escaped maintenance loop.",
)
_M_CONSEC = _REG.gauge(
    "neurstore_maintenance_consecutive_errors",
    "Consecutive failed steps, summed over running daemons.",
)
_M_ERR_AGE = _REG.gauge(
    "neurstore_maintenance_last_error_age_seconds",
    "Seconds since the most recent step error (0 when none yet).",
)


class MaintenanceDaemon:
    """Incremental auto-vacuum + cache-pressure trims for a StorageEngine."""

    def __init__(
        self,
        engine,
        dead_fraction: float = 0.25,
        interval_s: float = 1.0,
        pool_high_watermark: float = 0.9,
        scrub_models: int = 1,
        max_backoff_s: float = 30.0,
    ):
        self.engine = engine
        self.dead_fraction = float(dead_fraction)
        self.interval_s = float(interval_s)
        self.pool_high_watermark = float(pool_high_watermark)
        self.scrub_models = int(scrub_models)
        self.max_backoff_s = float(max_backoff_s)
        self._cursor = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()  # one step at a time (thread + tests)
        self.steps = 0
        self.vacuumed_vertices = 0
        self.pages_rewritten = 0
        self.pool_bytes_trimmed = 0
        self.pages_scrubbed = 0
        self.corrupt_found = 0
        self.errors = 0
        self.last_error: str | None = None
        self.last_error_at: float | None = None  # time.monotonic() stamp
        self.restarts = 0
        self.consecutive_errors = 0
        _M_CONSEC.attach(self, lambda d: d.consecutive_errors)
        _M_ERR_AGE.attach(self, lambda d: d.last_error_age_s() or 0.0)

    # ------------------------------------------------------------- stepping
    def _note_error(self, exc: BaseException) -> None:
        self.errors += 1
        self.consecutive_errors += 1
        self.last_error = repr(exc)
        self.last_error_at = time.monotonic()
        _M_ERRORS.inc()

    def last_error_age_s(self) -> float | None:
        """Seconds since the most recent step error; None when none yet."""
        if self.last_error_at is None:
            return None
        return time.monotonic() - self.last_error_at

    def step(self) -> dict:
        """One deterministic maintenance increment (see module docstring)."""
        with trace("maintenance.step"), self._lock:
            report = {
                "dim_checked": None,
                "vertices_dropped": 0,
                "pages_rewritten": 0,
                "pool_bytes_trimmed": 0,
                "pages_scrubbed": 0,
                "scrub_corrupt": [],
            }
            engine = self.engine
            engine._drain_released()
            dims = engine.index_cache.dims()
            # A degraded (read-only) store never mutates disk: vacuum is
            # skipped, but scrubbing and cache trims still run.
            if dims and not engine.read_only:
                self._cursor %= len(dims)
                dim = dims[self._cursor]
                self._cursor += 1
                report["dim_checked"] = dim
                rep = engine.vacuum(
                    min_dead_fraction=self.dead_fraction, dims=[dim]
                )
                report["vertices_dropped"] = rep["vertices_dropped"]
                report["pages_rewritten"] = rep["pages_rewritten"]
                self.vacuumed_vertices += rep["vertices_dropped"]
                self.pages_rewritten += rep["pages_rewritten"]
                _M_VACUUMED.inc(rep["vertices_dropped"])
            if self.scrub_models > 0:
                srep = engine.scrub(self.scrub_models)
                report["pages_scrubbed"] = srep["scanned"]
                report["scrub_corrupt"] = srep["corrupt"]
                self.pages_scrubbed += srep["scanned"]
                self.corrupt_found += len(srep["corrupt"])
                _M_SCRUBBED.inc(srep["scanned"])
                _M_CORRUPT.inc(len(srep["corrupt"]))
            pool = engine.page_pool
            target = int(pool.budget * self.pool_high_watermark)
            if pool.resident_bytes() > target:
                trimmed = pool.trim(target)
                report["pool_bytes_trimmed"] = trimmed
                self.pool_bytes_trimmed += trimmed
                _M_TRIMMED.inc(trimmed)
            engine.index_cache.trim()
            self.steps += 1
            _M_STEPS.inc()
            return report

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._supervise, name="neurstore-maintenance", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)
        self._thread = None

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def _backoff_s(self) -> float:
        """Current sleep: interval_s doubled per consecutive error, capped."""
        if self.consecutive_errors == 0:
            return self.interval_s
        return min(
            self.interval_s * (2.0 ** self.consecutive_errors),
            self.max_backoff_s,
        )

    def _run(self) -> None:
        while not self._stop.wait(self._backoff_s()):
            try:
                self.step()
                self.consecutive_errors = 0
            except Exception as exc:  # counted, never fatal to the daemon
                self._note_error(exc)

    def _supervise(self) -> None:
        """Restart ``_run`` if it ever escapes — a maintenance thread that
        died silently would look alive from ``stats()`` forever."""
        while not self._stop.is_set():
            try:
                self._run()
            except BaseException as exc:
                self._note_error(exc)
                if self._stop.is_set():
                    return
                self.restarts += 1
                _M_RESTARTS.inc()
                self._stop.wait(self._backoff_s())

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "running": self.running,
            "steps": self.steps,
            "vacuumed_vertices": self.vacuumed_vertices,
            "pages_rewritten": self.pages_rewritten,
            "pool_bytes_trimmed": self.pool_bytes_trimmed,
            "pages_scrubbed": self.pages_scrubbed,
            "corrupt_found": self.corrupt_found,
            "errors": self.errors,
            "last_error": self.last_error,
            "last_error_age_s": self.last_error_age_s(),
            "restarts": self.restarts,
            "consecutive_errors": self.consecutive_errors,
            "backoff_s": self._backoff_s(),
        }
