"""Production mesh builders (TPU v5e target).

Functions — never module-level constants — so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import (see dryrun.py) to build these meshes on the CPU container.
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline denominators, EXPERIMENTS.md §Roofline).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, small-scale pipelines)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices exist on this host (smoke tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
