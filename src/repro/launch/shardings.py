"""Path-based sharding assignment for parameter / optimizer / cache pytrees.

Every leaf of the params tree is mapped to a logical axis name (DESIGN.md §5
rules in ``repro.distributed.sharding``) by its path and rank; leaves under
``periods`` are scan-stacked and get a leading replicated dim.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..distributed import sharding as sh


def _key_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return f"[{entry.idx}]"
    return str(entry)


def _logical_for_param(path: tuple, ndim: int, stacked: bool) -> str:
    keys = [_key_str(k) for k in path]
    name = keys[-1]
    base_ndim = ndim - (1 if stacked else 0)
    in_seq = "seq" in keys
    if name == "embed":
        return "p_embed"
    if name == "lm_head":
        return "p_head"
    if name in ("norm1", "norm2", "final_norm", "q_norm", "k_norm", "ln_w", "mu"):
        return "p_vec"
    if name in ("wq", "wk", "wv") and in_seq and base_ndim == 3:
        return "p_attn_qkv"
    if name == "wo" and in_seq and base_ndim == 3:
        return "p_attn_o"
    if name in ("wx", "wgate"):
        return "p_rnn_in"
    if name in ("wa", "wi") and in_seq:
        return "p_rnn_sq"
    if name == "conv":
        return "p_conv"
    if name == "lam":
        return "p_rnn_vec"
    if name == "u":
        return "p_rwkv_u"
    if name == "w_lora_a":
        return "p_rwkv_lora_a"
    if name == "w_lora_b":
        return "p_rwkv_lora_b"
    if name == "router":
        return "p_router"
    if name in ("wg", "wu") and base_ndim == 3:
        return "p_expert_in"
    if name == "wd" and base_ndim == 3:
        return "p_expert_out"
    # 2D channel/sequence projections: (D, F)-like → in; (F, D)-like → out.
    if name in ("wg", "wu", "w1", "wk", "wr", "wkx") and base_ndim == 2:
        return "p_ffn_in"
    if name in ("wd", "w2", "wv", "wo") and base_ndim == 2:
        return "p_ffn_out"
    return "p_vec"  # conservative: replicated


def _logical_for_cache(path: tuple) -> str:
    name = _key_str(path[-1])
    if name in ("k", "v"):
        return None  # adaptive — resolved against the live mesh below
    if name == "h":
        return "rnn_state"
    if name == "conv":
        return "cache_conv"
    if name == "wkv":
        return "rwkv_state"
    if name in ("shift_tm", "shift_cm"):
        return "cache_shift"
    raise ValueError(f"unknown cache leaf {name}")


def _spec_with_stack(spec: P, stacked: bool) -> P:
    if not stacked:
        return spec
    return P(*((None,) + tuple(spec)))


# Alternate specs tried in order when a dim is not divisible by its mesh
# axis (in_shardings demand exact divisibility; constraints do not):
#   * KV-head dims (8, 2, 1 heads) can't split over model=16 → shard d_head
#     or replicate;
#   * granite's 40 experts can't split over data=16 → shard (D, F) instead;
#   * odd vocabs (49155, 504) replicate the vocab dim.
_ALTERNATES = {
    "p_attn_qkv": [P("data", "model", None), P("data", None, "model"),
                   P("data", None, None)],
    "p_attn_o": [P("model", None, "data"), P(None, "model", "data"),
                 P(None, None, "data")],
    "p_expert_in": [P(("data",), None, "model"), P(None, "data", "model"),
                    P(None, None, "model")],
    "p_expert_out": [P(("data",), "model", None), P(None, "model", "data"),
                     P(None, "model", None)],
    "p_embed": [P("model", "data"), P(None, "data"), P(None, "model")],
    "p_head": [P("data", "model"), P("data", None), P(None, None)],
    "p_router": [P("data", None), P(None, None)],
}


def _axis_size(mesh, axis) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes[a]
        return n
    return sizes[axis]


def _fits(spec: P, shape: tuple, mesh) -> bool:
    for dim, axis in zip(shape, tuple(spec)):
        if dim % _axis_size(mesh, axis):
            return False
    return True


def _drop_misfits(spec: P, shape: tuple, mesh) -> P:
    fixed = []
    for i, axis in enumerate(tuple(spec)):
        dim = shape[i] if i < len(shape) else 1
        fixed.append(axis if dim % _axis_size(mesh, axis) == 0 else None)
    return P(*fixed)


def fit_spec(logical: str, spec: P, shape: tuple, mesh) -> P:
    """First alternate whose axes divide ``shape``; else drop offenders."""
    if _fits(spec, shape, mesh):
        return spec
    for alt in _ALTERNATES.get(logical, []):
        if _fits(alt, shape, mesh):
            return alt
    return _drop_misfits(spec, shape, mesh)


def param_specs_tree(params_tree, ctx: sh.ShardingCtx, kv_heads: int | None = None):
    """PartitionSpec pytree for params (or optimizer moments — same shape)."""

    def assign(path, leaf):
        keys = [_key_str(k) for k in path]
        stacked = "periods" in keys
        logical = _logical_for_param(path, leaf.ndim, stacked)
        shape = leaf.shape[1:] if stacked else leaf.shape
        spec = fit_spec(logical, ctx.spec(logical), shape, ctx.mesh)
        return _spec_with_stack(spec, stacked)

    return jax.tree_util.tree_map_with_path(assign, params_tree)


def cache_specs_tree(cache_tree, ctx: sh.ShardingCtx, kv_heads: int):
    model_size = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get("model", 1)
    kv_logical = "cache_bh" if kv_heads % model_size == 0 else "cache_bs"

    def assign(path, leaf):
        keys = [_key_str(k) for k in path]
        stacked = "periods" in keys
        logical = _logical_for_cache(path) or kv_logical
        shape = leaf.shape[1:] if stacked else leaf.shape
        spec = fit_spec(logical, ctx.spec(logical), shape, ctx.mesh)
        return _spec_with_stack(spec, stacked)

    return jax.tree_util.tree_map_with_path(assign, cache_tree)


def batch_specs_tree(batch_tree, ctx: sh.ShardingCtx):
    def assign(path, leaf):
        name = _key_str(path[-1])
        if name in ("tokens", "labels", "mask"):
            logical = "tokens"
        elif name == "embeds":
            logical = "embeds_in"
        else:
            return P()
        return fit_spec(logical, ctx.spec(logical), leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map_with_path(assign, batch_tree)


def opt_specs_tree(opt_tree, params_specs):
    """Optimizer state mirrors param shardings; step is replicated."""
    return {
        "m": params_specs,
        "v": params_specs,
        "step": P(),
    }


def named(tree, mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))


def compressed_param_specs_tree(qtree, ctx: sh.ShardingCtx):
    """Specs for storage-format weight trees (compressed serving).

    Each quantized group {base, packed, scales…} inherits the logical spec
    of its original tensor: ``base`` keeps the full-shape spec; ``packed``
    (dim0 halved, trailing dims flattened) keeps the dim-0 axis plus the
    first non-None trailing axis; scalars replicate.
    """
    is_q = lambda x: isinstance(x, dict) and ("raw" in x or "base" in x)

    def assign(path, q):
        keys = [_key_str(k) for k in path]
        stacked = "periods" in keys
        if "raw" in q:
            leaf = q["raw"]
            logical = _logical_for_param(path, leaf.ndim, stacked)
            shape = leaf.shape[1:] if stacked else leaf.shape
            spec = fit_spec(logical, ctx.spec(logical), shape, ctx.mesh)
            return {"raw": _spec_with_stack(spec, stacked)}
        base = q["base"]
        logical = _logical_for_param(path, base.ndim, stacked)
        shape = base.shape[1:] if stacked else base.shape
        spec = fit_spec(logical, ctx.spec(logical), shape, ctx.mesh)
        tail_axis = next((a for a in tuple(spec)[1:] if a is not None), None)
        pshape = q["packed"].shape[1:] if stacked else q["packed"].shape
        pspec = _drop_misfits(P(tuple(spec)[0] if spec else None, tail_axis),
                              pshape, ctx.mesh)
        out = {
            "base": _spec_with_stack(spec, stacked),
            "packed": _spec_with_stack(pspec, stacked),
        }
        for k in ("bs", "bz", "bmid", "ds", "dz"):
            out[k] = _spec_with_stack(P(), stacked)
        return out

    return jax.tree_util.tree_map_with_path(assign, qtree, is_leaf=is_q)
