import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any jax import — jax locks the device
count at first init. This module (and only this module) sees 512 host
devices; smoke tests and benches see 1.

Per cell we report:
  * ``compiled.memory_analysis()``  — per-device bytes (fits-in-HBM proof)
  * ``compiled.cost_analysis()``    — per-device HLO FLOPs / bytes accessed
  * collective bytes parsed from the optimized HLO (hlo_stats.py)
  * the three roofline terms + dominant bottleneck (EXPERIMENTS.md §Roofline)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out dryrun.json
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..distributed import sharding as sh
from ..models.config import SHAPES, ModelConfig, ShapeConfig
from . import shardings as shd
from .hlo_stats import collective_stats
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from .specs import batch_specs, decode_cache_specs, model_specs, opt_specs
from .steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    pick_microbatches,
)


def _probe_cfg(cfg: ModelConfig, n_periods: int) -> ModelConfig:
    import dataclasses
    n_layers = n_periods * len(cfg.period) + len(cfg.tail)
    return dataclasses.replace(cfg, n_layers=n_layers,
                               unroll_periods=True, scan_unroll=True)


# Sub-quadratic archs (rwkv6/recurrentgemma) are linear-in-S per layer
# (windowed attention, chunked linear recurrence), so long-sequence probes
# run at this length and scale linearly — fully unrolling 1024 RWKV chunk
# steps would blow up probe compile time. (The RG-LRU associative scan is
# O(S log S); the log factor on its elementwise term is ≤3 extra levels at
# 32k — noted in EXPERIMENTS.md.)
_SUBQUAD_PROBE_SEQ = 4096


def _probe_shape(shape: ShapeConfig, cfg: ModelConfig,
                 n_micro: int | None = None) -> tuple[ShapeConfig, float]:
    """Probe shape + linear scale factor back to the true shape.

    Train probes run ONE microbatch (no accumulation scan) so the body is
    seen exactly once; step total = n_micro × probe (+ O(N) optimizer
    update, negligible). Sub-quadratic archs probe long sequences at
    _SUBQUAD_PROBE_SEQ and scale by S/S_probe (all terms linear in S)."""
    import dataclasses
    scale = 1.0
    s = shape.seq_len
    b = shape.global_batch
    if shape.is_train and n_micro is None:
        n_micro = pick_microbatches(cfg, shape.global_batch)
    if shape.is_train:
        b = shape.global_batch // n_micro
        scale *= n_micro
    if cfg.subquadratic and shape.kind != "decode" and s > _SUBQUAD_PROBE_SEQ:
        scale *= s / _SUBQUAD_PROBE_SEQ
        s = _SUBQUAD_PROBE_SEQ
    return dataclasses.replace(shape, seq_len=s, global_batch=b), scale


def probe_costs(cfg: ModelConfig, shape: ShapeConfig, mesh, multi_pod: bool,
                n_devices: int, profile: str = "tp",
                compressed: bool = False) -> dict:
    """Scan-aware cost extraction: XLA cost_analysis counts scan/while
    bodies ONCE, so probes compile fully-unrolled 1-period and 2-period
    configs and extrapolate: cost(P) = cost(1) + (P-1)·[cost(2) - cost(1)],
    scaled back for microbatching / probe sequence length."""
    pshape, scale = _probe_shape(shape, cfg,
                                 n_micro=1 if profile == "dp" else None)

    def one(n_periods):
        pcfg = _probe_cfg(cfg, n_periods)
        compiled, _ = lower_cell(pcfg, pshape, mesh, multi_pod,
                                 force_single_micro=True, profile=profile,
                                 compressed=compressed)
        cost = compiled.cost_analysis() or {}
        colls = collective_stats(compiled.as_text(), n_devices)
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)),
                colls["total_bytes"], colls)

    f1, b1, c1, colls1 = one(1)
    if cfg.n_periods > 1:
        f2, b2, c2, colls2 = one(2)
    else:
        f2, b2, c2, colls2 = f1, b1, c1, colls1
    p = cfg.n_periods
    ext = lambda a, b: (a + (p - 1) * max(b - a, 0.0)) * scale
    coll_kinds = {
        k: ext(colls1.get(k, 0.0), colls2.get(k, 0.0))
        for k in ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")}
    return {
        "flops": ext(f1, f2),
        "bytes": ext(b1, b2),
        "collective_bytes": ext(c1, c2),
        "collective_kinds": coll_kinds,
        "probe": {"flops_1p": f1, "flops_2p": f2, "scale": scale,
                  "probe_seq": pshape.seq_len},
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) / 2·N·D (fwd) with N = active params, D = tokens."""
    n = cfg.n_active_params
    if shape.is_train:
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, multi_pod: bool,
               force_single_micro: bool = False, profile: str = "tp",
               compressed: bool = False):
    """Build shardings + lower + compile one cell; returns (compiled, lowered)."""
    seq_shard = shape.kind != "decode"
    with sh.use_mesh(mesh, multi_pod=multi_pod, seq_shard=seq_shard,
                     serve=not shape.is_train, profile=profile) as ctx:
        p_specs = model_specs(cfg)
        p_shard = shd.named(shd.param_specs_tree(p_specs, ctx), mesh)
        b_specs = batch_specs(cfg, shape)
        b_shard = shd.named(shd.batch_specs_tree(b_specs, ctx), mesh)
        if shape.is_train:
            big = cfg.n_params > 100e9
            moment_dtype = jnp.bfloat16 if big else jnp.float32
            grad_dtype = jnp.bfloat16 if big else jnp.float32
            o_specs = opt_specs(cfg, moment_dtype)
            o_shard = shd.named(
                shd.opt_specs_tree(o_specs, shd.param_specs_tree(p_specs, ctx)),
                mesh)
            n_micro = (1 if force_single_micro or profile == "dp"
                       else pick_microbatches(cfg, shape.global_batch))
            step = make_train_step(cfg, n_micro, grad_dtype=grad_dtype)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_specs, o_specs, b_specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_specs, b_specs)
        else:  # decode
            c_specs = decode_cache_specs(cfg, shape)
            c_shard = shd.named(
                shd.cache_specs_tree(c_specs, ctx, cfg.n_kv_heads), mesh)
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            if compressed:
                # NeurStore storage format as the runtime weight format.
                from .compressed_serve import (
                    compressed_param_specs,
                    make_compressed_serve_step,
                )
                p_specs = compressed_param_specs(cfg)
                p_shard = shd.named(
                    shd.compressed_param_specs_tree(p_specs, ctx), mesh)
                step = make_compressed_serve_step(cfg)
            else:
                step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard, None),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(p_specs, c_specs, b_specs, pos_spec)
        compiled = lowered.compile()
        return compiled, lowered


def analyse(compiled, costs: dict, cfg: ModelConfig, shape: ShapeConfig,
            n_devices: int) -> dict:
    mem = compiled.memory_analysis()
    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    coll_dev = costs["collective_bytes"]
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_total = flops_dev * n_devices
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "n_devices": n_devices,
        "per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_hbm_bytes": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               - mem.alias_size_in_bytes
                               + mem.temp_size_in_bytes),
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
        },
        "collectives": costs.get("collective_kinds", {}),
        "probe": costs.get("probe", {}),
        "roofline_s": terms,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_flops_ratio": mf / hlo_flops_total if hlo_flops_total else 0.0,
        # Fraction of the *compute* roofline (meaningful for train/prefill).
        "roofline_fraction": (
            mf / n_devices / PEAK_FLOPS_BF16 / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
        # Decode cells are weight/cache-bandwidth bound: the ideal step time
        # is one pass over the per-device arguments (params + cache). This
        # is the number to hillclimb for decode shapes.
        "ideal_memory_s": mem.argument_size_in_bytes / HBM_BW,
        "bandwidth_fraction": (
            (mem.argument_size_in_bytes / HBM_BW) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             probes: bool = True, profile: str = "tp",
             compressed: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape_name):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": ("encoder-only: no decode step"
                           if not cfg.has_decode
                           else "full attention: long_500k needs sub-quadratic")}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    # Full-depth compile: proves the sharding config is coherent and gives
    # the per-device memory analysis.
    compiled, lowered = lower_cell(cfg, shape, mesh, multi_pod,
                                   profile=profile, compressed=compressed)
    dt = time.time() - t0
    if probes:
        # Scan-aware roofline costs from 1-period/2-period probe compiles.
        costs = probe_costs(cfg, shape, mesh, multi_pod, n_dev, profile,
                            compressed)
    else:
        cost = compiled.cost_analysis() or {}
        colls = collective_stats(compiled.as_text(), n_dev)
        costs = {"flops": float(cost.get("flops", 0)),
                 "bytes": float(cost.get("bytes accessed", 0)),
                 "collective_bytes": colls["total_bytes"],
                 "collective_kinds": colls}
    rec = analyse(compiled, costs, cfg, shape, n_dev)
    rec["compile_s"] = round(dt, 1)
    rec["multi_pod"] = multi_pod
    if verbose:
        mem = compiled.memory_analysis()
        print(f"== {arch} × {shape_name} ({'multi' if multi_pod else 'single'}-pod, "
              f"{n_dev} devices) compiled in {dt:.0f}s")
        print(f"   memory_analysis: {mem}")
        print(f"   peak_hbm/dev: {rec['per_device']['peak_hbm_bytes']/2**30:.2f} GiB"
              f" (HBM 16 GiB)")
        print(f"   per-step per-device: flops={rec['per_device']['hlo_flops']:.3e} "
              f"bytes={rec['per_device']['hlo_bytes']:.3e} "
              f"collective={rec['per_device']['collective_bytes']:.3e}")
        print(f"   collective MB: "
              f"{ {k: round(v/1e6,1) for k,v in rec['collectives'].items() if v} }")
        print(f"   roofline terms (s): "
              f"compute={rec['roofline_s']['compute']:.4f} "
              f"memory={rec['roofline_s']['memory']:.4f} "
              f"collective={rec['roofline_s']['collective']:.4f} "
              f"→ {rec['bottleneck']}-bound; "
              f"useful-FLOP ratio {rec['useful_flops_ratio']:.2f}; "
              f"roofline fraction {rec['roofline_fraction']:.2%}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--profile", default="tp", choices=["tp", "dp"])
    ap.add_argument("--compressed-serve", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp,
                                            profile=args.profile,
                                            compressed=args.compressed_serve))
                except Exception as e:  # a failure here is a bug in the system
                    results.append({"arch": arch, "shape": shape,
                                    "multi_pod": mp, "error": repr(e)[:500]})
                    print(f"!! {arch} × {shape} (multi_pod={mp}) FAILED: {e!r}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_err = sum(1 for r in results if "error" in r)
    n_skip = sum(1 for r in results if r.get("skipped"))
    print(f"\n{len(results)} cells: {len(results)-n_err-n_skip} ok, "
          f"{n_skip} skipped (documented), {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
