"""Jittable train / prefill / serve steps for every architecture.

``make_train_step`` builds the canonical production step: microbatched
gradient accumulation (lax.scan), remat-per-period forward, AdamW update
with sharded moments. Microbatching both bounds activation memory and lets
XLA overlap the data-parallel gradient reduce-scatter of microbatch *i*
with the compute of *i+1*.

``make_serve_step`` is one decode token against the KV/state cache;
``make_prefill_step`` is a full forward returning last-position logits
(returning (B, S, V) logits at 32k prefill would be a ~300 GB output).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import decode_step, forward, loss_fn
from ..models.config import ModelConfig
from ..optim import adamw_update


def pick_microbatches(cfg: ModelConfig, global_batch: int) -> int:
    """Microbatch count heuristic: keep per-microbatch tokens ≲ 128k for
    big-d models (activation + logits memory), ≲ 256k otherwise."""
    micro = 16 if cfg.d_model > 4096 or cfg.n_experts >= 64 else 32
    micro = min(micro, global_batch)
    while global_batch % micro:
        micro //= 2
    return max(global_batch // micro, 1)


def make_train_step(cfg: ModelConfig, n_microbatches: int = 1, *,
                    lr: float = 1e-4, grad_dtype=None):
    """Returns train_step(params, opt_state, batch) → (params, opt, metrics).

    ``grad_dtype``: accumulation dtype for the grad sum (f32 default;
    bf16 for the 480B-scale configs where f32 accumulators don't fit HBM).
    """
    acc_dtype = grad_dtype or jnp.float32

    def train_step(params, opt_state, batch):
        def split(x):
            return x.reshape((n_microbatches, x.shape[0] // n_microbatches)
                             + x.shape[1:])

        grad_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg)[0])

        if n_microbatches == 1:  # no accumulation scan (dry-run probes)
            loss, grads = grad_fn(params, batch)
            params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
            return params, opt_state, {"loss": loss}

        micro_batches = jax.tree.map(split, batch)

        def micro_step(carry, mb):
            gsum, lsum = carry
            loss, grads = grad_fn(params, mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(acc_dtype), gsum, grads)
            return (gsum, lsum + loss), None

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (gsum, lsum), _ = jax.lax.scan(
            micro_step, (gzero, jnp.zeros((), jnp.float32)), micro_batches)
        grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        metrics = {"loss": lsum / n_microbatches}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        return loss_fn(params, batch, cfg)[0]
    return eval_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits = forward(params, batch, cfg)
        return logits[:, -1, :].astype(jnp.float32)  # (B, V)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One greedy decode token for the whole batch."""

    def serve_step(params, cache, batch, pos):
        logits, new_cache = decode_step(params, cache, batch, pos, cfg)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step
