"""Collective-traffic extraction from optimized HLO text (§Roofline source).

``cost_analysis()`` has no collective bytes, so we parse the compiled
module: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute result shape (per-device, post-SPMD) is converted to
bytes moved per device under ring algorithms:

    all-gather          out × (n-1)/n
    reduce-scatter      out × (n-1)        (ring RS moves (n-1)/n of input)
    all-reduce          2 × size × (n-1)/n (RS + AG)
    all-to-all          size × (n-1)/n
    collective-permute  size
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=\s]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[[0-9,]+\]<=\[\d+\])")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return len([x for x in first.split(",") if x.strip() != ""])
    # iota form: [g0,g1,...]<=[N] — group size is the product of all dims
    # except the number of groups; for [G,n]<=[N] it's n = N/G.
    dims = [int(x) for x in g[1:g.index("]")].split(",")]
    total = int(g[g.index("<=[") + 3:-1])
    n_groups = dims[0]
    return max(total // n_groups, 1) if len(dims) > 1 else dims[0]


def collective_stats(hlo_text: str, n_devices: int) -> dict:
    """Per-device collective bytes, split by op kind."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done" in line.split("=")[1].split("(")[0]:
            continue
        size = _shape_bytes(type_str)
        n = _group_size(line, n_devices)
        if kind == "all-gather":
            moved = size * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            moved = size * (n - 1)
        elif kind == "all-reduce":
            moved = 2 * size * (n - 1) / max(n, 1)
        elif kind == "all-to-all":
            moved = size * (n - 1) / max(n, 1)
        else:  # collective-permute
            moved = size
        out[kind] += moved
        out["count"] += 1
    out["total_bytes"] = sum(v for k, v in out.items()
                             if k not in ("count", "total_bytes"))
    return out


def hlo_op_histogram(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    """Crude op-name histogram of the optimized module (perf-loop aid)."""
    ops: dict[str, int] = {}
    for m in re.finditer(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w\-]*)\(", hlo_text):
        ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    return sorted(ops.items(), key=lambda kv: -kv[1])[:top]
