"""Serving driver: batched greedy decoding over NeurStore-resident models.

The in-database serving path (paper Fig. 1): a request names a model_id;
the server loads it from the NeurStore engine **compression-aware**
(flexible bits, optionally keeping weights in storage format via
``compressed_serve``), decodes a batch of requests lock-step, and caches
loaded models LRU-style — the serving-tier mirror of the paper's index
cache.

CPU-sized by default; the jitted step is the same `decode_step` the
512-chip dry-run lowers, so this driver is shape-compatible with the
production mesh.
"""

from __future__ import annotations

import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..models import decode_step, init_cache
from ..models.config import ModelConfig


class ModelServer:
    def __init__(self, cfg: ModelConfig, ckpt_dir: str, *,
                 max_models: int = 2, bits: int | None = 8):
        self.cfg = cfg
        self.mgr = CheckpointManager(ckpt_dir)
        self.bits = bits
        self.max_models = max_models
        self._models: OrderedDict[int, dict] = OrderedDict()
        self._decode = jax.jit(
            lambda p, c, b, pos: decode_step(p, c, b, pos, cfg))

    # ------------------------------------------------------------ model mgmt
    def load(self, step: int | None = None) -> int:
        """Load a checkpointed model (flexible-bit) into the server cache."""
        step, state = self.mgr.restore(step, bits=self.bits)
        if step is None:
            raise ValueError("no checkpoints available")
        if step in self._models:
            self._models.move_to_end(step)
            return step
        params = jax.tree.map(jnp.asarray, state["params"])
        self._models[step] = params
        while len(self._models) > self.max_models:  # LRU eviction
            self._models.popitem(last=False)
        return step

    # --------------------------------------------------------------- serving
    def generate(self, model_step: int, prompts: np.ndarray,
                 max_new_tokens: int = 16) -> tuple[np.ndarray, dict]:
        """Greedy decode a batch. prompts: (B, S0) int32. Returns tokens +
        latency stats (prefill-as-decode loop; batched lock-step)."""
        params = self._models[model_step]
        b, s0 = prompts.shape
        cache = init_cache(self.cfg, b, s0 + max_new_tokens)
        t0 = time.perf_counter()
        tok = None
        # Teacher-forced pass over the prompt (decode steps share the cache
        # machinery; a chunked prefill is the production path on TPU).
        for t in range(s0):
            batch = {"tokens": jnp.asarray(prompts[:, t:t + 1])}
            logits, cache = self._decode(params, cache, batch, jnp.int32(t))
        t_prefill = time.perf_counter() - t0
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        t0 = time.perf_counter()
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode(
                params, cache, {"tokens": tok}, jnp.int32(s0 + i))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        t_decode = time.perf_counter() - t0
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": b * max_new_tokens / max(t_decode, 1e-9),
        }
        return np.concatenate(out, axis=1), stats
