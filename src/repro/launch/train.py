"""Production trainer: deterministic data, delta-compressed checkpoints,
crash/elastic restart, straggler accounting.

This is the library form of ``examples/train_e2e.py`` — the pieces a
cluster deployment needs around the jitted step:

* **restart-safe**: state = (step, params, opt) lives in the NeurStore
  checkpoint store; the data pipeline is step-indexed, so resume from any
  step on any topology replays the exact token stream.
* **elastic**: checkpoints are unsharded per-tensor; `restore_sharded`
  device_puts onto whatever mesh is live.
* **straggler mitigation**: per-step wall times feed an EWMA; steps slower
  than ``straggler_factor``× the EWMA are counted and surfaced via
  ``TrainReport`` (on a real fleet this signal drives the
  skip-and-rebalance hook — here the hook is a callback).
* **async checkpointing**: save threads overlap the next steps.

Usage:
    trainer = Trainer(cfg, ckpt_dir, mesh=None)
    report = trainer.fit(steps=100, batch=8, seq=128)
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models import init_params
from ..models.config import ModelConfig
from ..optim import adamw_init
from .steps import make_train_step


@dataclasses.dataclass
class TrainReport:
    start_step: int
    end_step: int
    losses: list
    step_seconds: list
    n_stragglers: int
    resumed: bool

    @property
    def final_loss(self) -> float:
        return float(np.mean(self.losses[-5:]))


class Trainer:
    def __init__(self, cfg: ModelConfig, ckpt_dir: str, *,
                 n_microbatches: int = 1, lr: float = 3e-4, seed: int = 0,
                 ckpt_every: int = 50, straggler_factor: float = 3.0,
                 on_straggler=None):
        self.cfg = cfg
        self.mgr = CheckpointManager(ckpt_dir)
        self.data = SyntheticLM(cfg.vocab_size, seed=seed)
        self.step_fn = jax.jit(make_train_step(cfg, n_microbatches, lr=lr))
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.seed = seed

    def _init_or_resume(self):
        latest = self.mgr.latest_step()
        if latest is not None:
            step, state = self.mgr.restore()
            params = jax.tree.map(jnp.asarray, state["params"])
            opt = jax.tree.map(jnp.asarray, state["opt"])
            return step, params, opt, True
        params = init_params(self.cfg, jax.random.PRNGKey(self.seed))
        return 0, params, adamw_init(params), False

    def fit(self, steps: int, batch: int, seq: int) -> TrainReport:
        start, params, opt, resumed = self._init_or_resume()
        losses, times = [], []
        ewma = None
        n_strag = 0
        for step in range(start, start + steps):
            t0 = time.perf_counter()
            b = self.data.batch(step, batch, seq)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, metrics = self.step_fn(params, opt, b)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            times.append(dt)
            if step > start:  # first step includes jit compile — no signal
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > self.straggler_factor * ewma and len(times) > 3:
                    n_strag += 1
                    if self.on_straggler is not None:
                        self.on_straggler(step, dt, ewma)
            if (step + 1) % self.ckpt_every == 0:
                self.mgr.save(step + 1, params, opt, blocking=False)
        self.mgr.save(start + steps, params, opt, blocking=True)
        self._params, self._opt = params, opt
        return TrainReport(start, start + steps, losses, times, n_strag,
                           resumed)

    def storage_report(self) -> dict:
        return self.mgr.storage_report()


def restore_sharded(mgr: CheckpointManager, mesh, ctx, step=None):
    """Elastic restore: load unsharded tensors, device_put with the live
    mesh's rules (any topology)."""
    from . import shardings as shd

    step, state = mgr.restore(step)
    if state is None:
        return None, None
    specs = shd.param_specs_tree(state["params"], ctx)
    params = jax.tree.map(
        lambda x, s: jax.device_put(
            x, jax.sharding.NamedSharding(mesh, s)),
        state["params"], specs,
        is_leaf=lambda x: isinstance(x, np.ndarray))
    return step, params
