"""Compressed-weight serving: NeurStore storage format as the *runtime*
weight format (paper §4.3 pushed to the TPU serving fleet).

Weights live in HBM exactly as the storage engine keeps them — int8 base
codes + 4-bit packed quantized deltas (flexible loading at b=4) — and are
de-quantized on use. HBM traffic per weight element drops from 2.0 bytes
(bf16) to 1.5 (int8 + int4), directly scaling the memory roofline term of
weight-bound decode. In-graph dequantization is elementwise → XLA fuses it
into the consuming matmul (the jnp analogue of the ``dequant_matmul``
Pallas kernel, which is the real-TPU path).

Accuracy: deltas at 4 bits relative to the 8-bit base reproduce the
paper's flexible-loading error regime (§6.4.2); `examples/serve_compressed.py`
demonstrates greedy-decode agreement at b=8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quantize import dequantize_linear, extract_msb, quantize_delta, quantize_linear
from ..models import decode_step
from ..models.config import ModelConfig

# Leaves smaller than this stay raw (norm vectors, biases).
MIN_QUANT_SIZE = 65_536
DELTA_BITS = 4


def _quantizable(leaf) -> bool:
    return (np.issubdtype(np.asarray(leaf).dtype if not hasattr(leaf, "dtype")
                          else leaf.dtype, np.floating)
            and leaf.ndim >= 2 and leaf.size >= MIN_QUANT_SIZE
            and leaf.shape[0] % 2 == 0)


def quantize_leaf(arr: np.ndarray) -> dict:
    """Host-side: tensor → int8 base + packed int4 delta (storage format)."""
    flat = np.asarray(arr, np.float64).ravel()
    base_q, base_meta = quantize_linear(flat, nbit=8)
    base = dequantize_linear(base_q, base_meta)
    delta = flat - base
    dq, dmeta = quantize_delta(delta, p=2.0 ** -24)
    dq4, dmeta4 = extract_msb(dq, dmeta, DELTA_BITS)
    if dmeta4.nbit < DELTA_BITS:  # pad code space so packing is uniform
        dq4 = dq4 << (DELTA_BITS - dmeta4.nbit)
        dmeta4 = type(dmeta4)(scale=dmeta4.scale / (1 << (DELTA_BITS - dmeta4.nbit)),
                              zero_point=dmeta4.zero_point << (DELTA_BITS - dmeta4.nbit),
                              nbit=DELTA_BITS, mid=dmeta4.mid)
    v = dq4.astype(np.uint8).reshape(arr.shape[0], -1)
    packed = (v[0::2] | (v[1::2] << 4)).astype(np.uint8)  # pack along dim 0
    return {
        "base": (base_q.astype(np.int16) - 128).astype(np.int8).reshape(arr.shape),
        "packed": packed,
        "bs": np.float32(base_meta.scale),
        "bz": np.float32(base_meta.zero_point - 128),
        "bmid": np.float32(base_meta.mid),
        "ds": np.float32(dmeta4.scale),
        "dz": np.float32(dmeta4.zero_point),
    }


def quantize_params(params) -> dict:
    """Whole-tree storage-format conversion (host side, done once)."""
    def conv(leaf):
        leaf = np.asarray(leaf)
        if _quantizable(leaf):
            return quantize_leaf(leaf)
        return {"raw": leaf}

    return jax.tree.map(conv, params)


def dequantize_leaf_jnp(q: dict, dtype=jnp.bfloat16):
    """In-graph reconstruction — fuses into the consuming matmul on TPU."""
    if "raw" in q:
        return q["raw"]
    base = (q["base"].astype(jnp.float32) - q["bz"]) * q["bs"]
    packed = q["packed"]
    low = (packed & 0xF).astype(jnp.float32)
    high = (packed >> 4).astype(jnp.float32)
    d0_half = packed.shape[0]
    nibbles = jnp.stack([low, high], axis=1).reshape(2 * d0_half, -1)
    delta = (nibbles - q["dz"] + 0.5) * q["ds"]
    return (base + delta.reshape(base.shape)).astype(dtype)


def make_compressed_serve_step(cfg: ModelConfig):
    """serve_step over storage-format weights (greedy decode one token)."""
    is_q = lambda x: isinstance(x, dict) and ("raw" in x or "base" in x)

    def step(qparams, cache, batch, pos):
        params = jax.tree.map(
            lambda q: dequantize_leaf_jnp(q, jnp.dtype(cfg.compute_dtype)),
            qparams, is_leaf=is_q)
        logits, new_cache = decode_step(params, cache, batch, pos, cfg)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return step


def compressed_param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct tree of the storage-format weights (dry-run)."""
    from .specs import model_specs

    def conv(leaf):
        if _quantizable(leaf):
            n_cols = leaf.size // leaf.shape[0]
            return {
                "base": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                "packed": jax.ShapeDtypeStruct(
                    (leaf.shape[0] // 2, n_cols), jnp.uint8),
                "bs": jax.ShapeDtypeStruct((), jnp.float32),
                "bz": jax.ShapeDtypeStruct((), jnp.float32),
                "bmid": jax.ShapeDtypeStruct((), jnp.float32),
                "ds": jax.ShapeDtypeStruct((), jnp.float32),
                "dz": jax.ShapeDtypeStruct((), jnp.float32),
            }
        return {"raw": leaf}

    return jax.tree.map(conv, model_specs(cfg))
