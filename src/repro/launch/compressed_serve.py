"""Compressed-weight serving: NeurStore storage format as the *runtime*
weight format (paper §4.3 pushed to the serving fleet).

Two paths share this module:

**Store-backed (the real NeurStore path).** A llama3-shaped decoder
(GQA + RMSNorm + SwiGLU) is saved through ``StorageEngine.save_model``
and served straight off the engine: ``load_model(name, bits=8|4)`` →
:class:`~repro.core.compressed.CompressedModel` → every large matmul of
:func:`greedy_decode` consumes int8 base codes + int8/int4-packed deltas
through ``kernels.ops.dequant_matmul_auto``. The snapshot's buffer-pool
frame stays pinned for the serving session and ``materialize()`` is never
called on kernel-served tensors — HBM traffic per weight element drops
from 2.0 bytes (bf16) to 2.0 (int8 base + int8 delta) or 1.5 (int8 +
int4 packed), and the up-front full-precision decode of every weight is
skipped entirely. :class:`MaterializedProvider` is the materialize-then-
serve baseline behind the same provider interface, so the benchmark
(``benchmarks/compressed_serve_bench.py``) swaps only the weight source.

Weights are stored **(in, out)** — ``y = x @ W`` directly, matching the
kernel's (K, N) layout (HF checkpoints store the transpose).

**Host-quantized jnp path (demo/legacy).** ``quantize_params`` converts a
params pytree to the storage format from scratch and
``make_compressed_serve_step`` serves it through in-graph dequantization
that XLA fuses into the consuming matmul — the jnp analogue of the
``dequant_matmul`` Pallas kernel, kept for the tpu-graph serve demos.

Accuracy: deltas at 4 bits relative to the 8-bit base reproduce the
paper's flexible-loading error regime (§6.4.2); greedy decode at b=8
agrees with the materialized forward pass (tests/test_compressed_domain.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quantize import dequantize_linear, extract_msb, quantize_delta, quantize_linear
from ..models import decode_step
from ..models.config import ModelConfig

__all__ = [
    "DecoderSpec", "MaterializedProvider", "decoder_architecture",
    "greedy_decode", "init_decoder_tensors", "save_decoder",
    "spec_from_architecture", "quantize_params", "quantize_leaf",
    "dequantize_leaf_jnp", "make_compressed_serve_step",
    "compressed_param_specs",
]

# Leaves smaller than this stay raw (norm vectors, biases).
MIN_QUANT_SIZE = 65_536
DELTA_BITS = 4


# --------------------------------------------------------------------------
# Store-backed serving: llama3-shaped decoder over StorageEngine weights
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecoderSpec:
    """Shape of the stored decoder (llama3 family, GQA)."""

    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 512
    n_layers: int = 2
    vocab_size: int = 512
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def decoder_architecture(spec: DecoderSpec) -> dict:
    """Catalog ``architecture`` payload for a saved decoder."""
    return {"kind": "llama3_decoder", **dataclasses.asdict(spec)}


def spec_from_architecture(arch: dict) -> DecoderSpec:
    fields = {f.name for f in dataclasses.fields(DecoderSpec)}
    return DecoderSpec(**{k: v for k, v in dict(arch).items() if k in fields})


def init_decoder_tensors(spec: DecoderSpec, seed: int = 0) -> dict:
    """Random-init decoder weights, llama3/HF naming, (in, out) layout."""
    rng = np.random.default_rng(seed)
    d, dh = spec.d_model, spec.head_dim
    h, kv, f = spec.n_heads, spec.n_kv_heads, spec.d_ff

    def w(k_dim, n_dim):
        return rng.normal(0.0, k_dim ** -0.5, (k_dim, n_dim)).astype(np.float32)

    tensors = {"model.embed_tokens.weight":
               rng.normal(0.0, 1.0, (spec.vocab_size, d)).astype(np.float32)}
    for i in range(spec.n_layers):
        pre = f"model.layers.{i}."
        tensors[pre + "input_layernorm.weight"] = np.ones(d, np.float32)
        tensors[pre + "self_attn.q_proj.weight"] = w(d, h * dh)
        tensors[pre + "self_attn.k_proj.weight"] = w(d, kv * dh)
        tensors[pre + "self_attn.v_proj.weight"] = w(d, kv * dh)
        tensors[pre + "self_attn.o_proj.weight"] = w(h * dh, d)
        tensors[pre + "post_attention_layernorm.weight"] = np.ones(d, np.float32)
        tensors[pre + "mlp.gate_proj.weight"] = w(d, f)
        tensors[pre + "mlp.up_proj.weight"] = w(d, f)
        tensors[pre + "mlp.down_proj.weight"] = w(f, d)
    tensors["model.norm.weight"] = np.ones(d, np.float32)
    tensors["lm_head.weight"] = w(d, spec.vocab_size)
    return tensors


def save_decoder(engine, name: str, spec: DecoderSpec, seed: int = 0):
    """Save a random-init decoder; returns the engine's SaveReport."""
    return engine.save_model(
        name, decoder_architecture(spec), init_decoder_tensors(spec, seed))


class MaterializedProvider:
    """materialize-then-serve baseline: float32 weights, provider interface.

    Pays the full up-front de-quantization of every stored tensor
    (``LoadedModel.materialize()``), then serves plain float32 gemms.
    Bytes-moved counts float32 weight-operand traffic per matmul — what a
    serving host actually streams when the weights live uncompressed.
    """

    def __init__(self, lm):
        self.lm = lm
        self.params = lm.materialize()
        self._2d: dict[str, np.ndarray] = {}
        self.counters = {"matmul_calls": 0, "gather_calls": 0,
                         "bytes_moved": 0, "fused_elems": 0}

    def matmul(self, x: np.ndarray, name: str) -> np.ndarray:
        w = self._2d.get(name)
        if w is None:
            arr = self.params[name]
            w = self._2d[name] = arr.reshape(arr.shape[0], -1)
        c = self.counters
        c["matmul_calls"] += 1
        c["bytes_moved"] += w.nbytes
        c["fused_elems"] += w.size
        return np.asarray(x, np.float32) @ w

    def gather_rows(self, name: str, ids: np.ndarray) -> np.ndarray:
        rows = self.params[name][np.asarray(ids)]
        self.counters["gather_calls"] += 1
        self.counters["bytes_moved"] += rows.nbytes
        return rows

    def vector(self, name: str) -> np.ndarray:
        return self.params[name]

    def reset_counters(self) -> None:
        for key in self.counters:
            self.counters[key] = 0

    def close(self) -> None:
        self.lm.close()


def _rms_norm(x: np.ndarray, gamma: np.ndarray, eps: float) -> np.ndarray:
    ms = np.mean(np.square(x), axis=-1, keepdims=True)
    return (x / np.sqrt(ms + eps)) * gamma


def _softmax(x: np.ndarray) -> np.ndarray:
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def _silu(x: np.ndarray) -> np.ndarray:
    return x / (1.0 + np.exp(-x))


def _rope(x: np.ndarray, pos: int, theta: float) -> np.ndarray:
    """Interleaved-pair rotary embedding at one position; x (..., dh)."""
    dh = x.shape[-1]
    inv = theta ** (-np.arange(0, dh, 2, dtype=np.float32) / dh)
    ang = pos * inv
    cos, sin = np.cos(ang), np.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = np.empty_like(x)
    out[..., 0::2] = x1 * cos - x2 * sin
    out[..., 1::2] = x1 * sin + x2 * cos
    return out


def _attn_block(provider, li: int, x: np.ndarray, kc, vc, pos: int,
                spec: DecoderSpec) -> np.ndarray:
    b = x.shape[0]
    h, kv, dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    pre = f"model.layers.{li}."
    xn = _rms_norm(x, provider.vector(pre + "input_layernorm.weight"),
                   spec.norm_eps)
    q = provider.matmul(xn, pre + "self_attn.q_proj.weight").reshape(b, h, dh)
    k = provider.matmul(xn, pre + "self_attn.k_proj.weight").reshape(b, kv, dh)
    v = provider.matmul(xn, pre + "self_attn.v_proj.weight").reshape(b, kv, dh)
    q = _rope(q, pos, spec.rope_theta)
    k = _rope(k, pos, spec.rope_theta)
    kc[li][:, :, pos] = k
    vc[li][:, :, pos] = v
    # Grouped-query attention: g query heads share each KV head.
    g = h // kv
    qg = q.reshape(b, kv, g, dh)
    keys = kc[li][:, :, :pos + 1]
    vals = vc[li][:, :, :pos + 1]
    s = np.einsum("bkgd,bktd->bkgt", qg, keys) / np.sqrt(dh)
    o = np.einsum("bkgt,bktd->bkgd", _softmax(s), vals).reshape(b, h * dh)
    return provider.matmul(o, pre + "self_attn.o_proj.weight")


def _mlp_block(provider, li: int, x: np.ndarray, spec: DecoderSpec) -> np.ndarray:
    pre = f"model.layers.{li}."
    xn = _rms_norm(x, provider.vector(pre + "post_attention_layernorm.weight"),
                   spec.norm_eps)
    gate = provider.matmul(xn, pre + "mlp.gate_proj.weight")
    up = provider.matmul(xn, pre + "mlp.up_proj.weight")
    return provider.matmul(_silu(gate) * up, pre + "mlp.down_proj.weight")


def greedy_decode(provider, spec: DecoderSpec, prompt: np.ndarray,
                  steps: int, return_logits: bool = False):
    """Greedy decode ``steps`` tokens after consuming ``prompt`` (B, P).

    ``provider`` is anything with the matmul/gather_rows/vector interface
    (:class:`~repro.core.compressed.CompressedModel` for compressed-domain
    serving, :class:`MaterializedProvider` for the float baseline). Every
    projection and the LM head go through ``provider.matmul``; the
    embedding lookup through ``provider.gather_rows`` — the decode loop
    itself owns no weights. Returns (B, steps) int64 tokens, plus the
    per-step (B, steps, V) logits when ``return_logits``.
    """
    prompt = np.atleast_2d(np.asarray(prompt, dtype=np.int64))
    b, p = prompt.shape
    total = p + steps
    shape = (spec.n_layers, b, spec.n_kv_heads, total, spec.head_dim)
    kc = np.zeros(shape, np.float32)
    vc = np.zeros(shape, np.float32)
    generated: list[np.ndarray] = []
    logits_trace: list[np.ndarray] = []
    tok = prompt[:, 0]
    pos = 0
    while len(generated) < steps:
        x = provider.gather_rows("model.embed_tokens.weight", tok)
        for li in range(spec.n_layers):
            x = x + _attn_block(provider, li, x, kc, vc, pos, spec)
            x = x + _mlp_block(provider, li, x, spec)
        x = _rms_norm(x, provider.vector("model.norm.weight"), spec.norm_eps)
        logits = provider.matmul(x, "lm_head.weight")
        nxt = np.argmax(logits, axis=1)
        pos += 1
        if pos < p:
            tok = prompt[:, pos]
        else:
            tok = nxt
            generated.append(nxt)
            if return_logits:
                logits_trace.append(logits)
    tokens = np.stack(generated, axis=1)
    if return_logits:
        return tokens, np.stack(logits_trace, axis=1)
    return tokens


# --------------------------------------------------------------------------
# Host-quantized jnp path (demo/legacy): storage format built from scratch
# --------------------------------------------------------------------------

def _quantizable(leaf) -> bool:
    return (np.issubdtype(np.asarray(leaf).dtype if not hasattr(leaf, "dtype")
                          else leaf.dtype, np.floating)
            and leaf.ndim >= 2 and leaf.size >= MIN_QUANT_SIZE
            and leaf.shape[0] % 2 == 0)


def quantize_leaf(arr: np.ndarray) -> dict:
    """Host-side: tensor → int8 base + packed int4 delta (storage format)."""
    flat = np.asarray(arr, np.float64).ravel()
    base_q, base_meta = quantize_linear(flat, nbit=8)
    base = dequantize_linear(base_q, base_meta)
    delta = flat - base
    dq, dmeta = quantize_delta(delta, p=2.0 ** -24)
    dq4, dmeta4 = extract_msb(dq, dmeta, DELTA_BITS)
    if dmeta4.nbit < DELTA_BITS:  # pad code space so packing is uniform
        dq4 = dq4 << (DELTA_BITS - dmeta4.nbit)
        dmeta4 = type(dmeta4)(scale=dmeta4.scale / (1 << (DELTA_BITS - dmeta4.nbit)),
                              zero_point=dmeta4.zero_point << (DELTA_BITS - dmeta4.nbit),
                              nbit=DELTA_BITS, mid=dmeta4.mid)
    v = dq4.astype(np.uint8).reshape(arr.shape[0], -1)
    packed = (v[0::2] | (v[1::2] << 4)).astype(np.uint8)  # pack along dim 0
    return {
        "base": (base_q.astype(np.int16) - 128).astype(np.int8).reshape(arr.shape),
        "packed": packed,
        "bs": np.float32(base_meta.scale),
        "bz": np.float32(base_meta.zero_point - 128),
        "bmid": np.float32(base_meta.mid),
        "ds": np.float32(dmeta4.scale),
        "dz": np.float32(dmeta4.zero_point),
    }


def quantize_params(params) -> dict:
    """Whole-tree storage-format conversion (host side, done once)."""
    def conv(leaf):
        leaf = np.asarray(leaf)
        if _quantizable(leaf):
            return quantize_leaf(leaf)
        return {"raw": leaf}

    return jax.tree.map(conv, params)


def dequantize_leaf_jnp(q: dict, dtype=jnp.bfloat16):
    """In-graph reconstruction — fuses into the consuming matmul on TPU."""
    if "raw" in q:
        return q["raw"]
    base = (q["base"].astype(jnp.float32) - q["bz"]) * q["bs"]
    packed = q["packed"]
    low = (packed & 0xF).astype(jnp.float32)
    high = (packed >> 4).astype(jnp.float32)
    d0_half = packed.shape[0]
    nibbles = jnp.stack([low, high], axis=1).reshape(2 * d0_half, -1)
    delta = (nibbles - q["dz"] + 0.5) * q["ds"]
    return (base + delta.reshape(base.shape)).astype(dtype)


def make_compressed_serve_step(cfg: ModelConfig):
    """serve_step over storage-format weights (greedy decode one token)."""
    is_q = lambda x: isinstance(x, dict) and ("raw" in x or "base" in x)  # noqa: E731

    def step(qparams, cache, batch, pos):
        params = jax.tree.map(
            lambda q: dequantize_leaf_jnp(q, jnp.dtype(cfg.compute_dtype)),
            qparams, is_leaf=is_q)
        logits, new_cache = decode_step(params, cache, batch, pos, cfg)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return step


def compressed_param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct tree of the storage-format weights (dry-run)."""
    from .specs import model_specs

    def conv(leaf):
        if _quantizable(leaf):
            n_cols = leaf.size // leaf.shape[0]
            return {
                "base": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                "packed": jax.ShapeDtypeStruct(
                    (leaf.shape[0] // 2, n_cols), jnp.uint8),
                "bs": jax.ShapeDtypeStruct((), jnp.float32),
                "bz": jax.ShapeDtypeStruct((), jnp.float32),
                "bmid": jax.ShapeDtypeStruct((), jnp.float32),
                "ds": jax.ShapeDtypeStruct((), jnp.float32),
                "dz": jax.ShapeDtypeStruct((), jnp.float32),
            }
        return {"raw": leaf}

    return jax.tree.map(conv, model_specs(cfg))
