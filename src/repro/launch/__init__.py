"""Launch layer: meshes, sharded steps, dry-run, trainer."""
