"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

No device allocation — the dry-run lowers against these (the shannon/kernels
pattern: weak-type-correct, shardable specs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import cache_specs, param_specs
from ..models.config import ModelConfig, ShapeConfig
from ..optim import adamw_init


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    specs = {}
    if cfg.frontend == "embeddings":
        specs["embeds"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.is_train:
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs


def model_specs(cfg: ModelConfig):
    return param_specs(cfg)


def opt_specs(cfg: ModelConfig, moment_dtype=jnp.float32):
    params = param_specs(cfg)
    out = jax.eval_shape(adamw_init, params)
    if moment_dtype != jnp.float32:
        cast = lambda s: jax.ShapeDtypeStruct(s.shape, moment_dtype)
        out = {"m": jax.tree.map(cast, out["m"]),
               "v": jax.tree.map(cast, out["v"]),
               "step": out["step"]}
    return out


def decode_cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    return cache_specs(cfg, shape.global_batch, shape.seq_len)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Everything the lowered step consumes, keyed by argument name."""
    out = {"batch": batch_specs(cfg, shape), "params": model_specs(cfg)}
    if shape.is_train:
        out["opt_state"] = opt_specs(cfg)
    if shape.kind == "decode":
        out["cache"] = decode_cache_specs(cfg, shape)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
