"""Fault tolerance: NeurStore-backed delta-compressed checkpointing."""

from .manager import CheckpointManager

__all__ = ["CheckpointManager"]
