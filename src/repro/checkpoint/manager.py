"""Delta-compressed distributed checkpointing on the NeurStore engine.

This is the paper's technique as a first-class training-framework feature:
every checkpoint's tensors are delta-encoded against the HNSW-matched base —
usually the previous checkpoint's tensor — so periodic checkpoints cost
O(bits of parameter drift), not O(model size). Fine-tune forks of one
pretrained model dedup against shared bases exactly as in the paper's
e-commerce scenario.

Fault-tolerance properties:
* **atomic commit** — the engine's meta.json is replaced atomically after
  the page is fully written; a manifest records the latest complete step.
  A crash mid-save leaves the previous checkpoint intact.
* **async save** — ``save(..., blocking=False)`` snapshots to host memory
  and writes in a background thread; training continues.
* **elastic restore** — checkpoints are stored unsharded (per-tensor); any
  mesh shape can restore by device_put-ing with its own shardings. Combined
  with the deterministic data pipeline (step-indexed), restart on a
  different topology reproduces training exactly.
* **flexible-bit restore** — ``restore(bits=8)`` uses the paper's flexible
  loading for fast approximate restore (e.g. spinning up eval replicas).
"""

from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np

from ..core import StorageEngine

SEP = "//"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(flat: dict[str, np.ndarray]):
    root: dict = {}
    for key, value in flat.items():
        parts = key.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root


def _fix_lists(node):
    """Dict nodes whose keys are all ints become lists (tail layers)."""
    if not isinstance(node, dict):
        return node
    fixed = {k: _fix_lists(v) for k, v in node.items()}
    if fixed and all(k.isdigit() for k in fixed):
        return [fixed[str(i)] for i in range(len(fixed))]
    return fixed


class CheckpointManager:
    def __init__(self, root: str, tolerance: float | None = None, tau: float | None = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        kwargs = {}
        if tolerance is not None:
            kwargs["tolerance"] = tolerance
        if tau is not None:
            kwargs["tau"] = tau
        self.engine = StorageEngine(os.path.join(root, "store"), **kwargs)
        self._manifest_path = os.path.join(root, "MANIFEST.json")
        self._manifest = {"steps": [], "latest": None}
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                self._manifest = json.load(f)
        self._bg: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def _commit_manifest(self, step: int, meta: dict):
        self._manifest["steps"].append(step)
        self._manifest["latest"] = step
        self._manifest[f"meta_{step}"] = meta
        tmp = self._manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._manifest, f)
        os.replace(tmp, self._manifest_path)  # atomic

    def save(self, step: int, params, opt_state=None, blocking: bool = True,
             extra_meta: dict | None = None):
        """Snapshot → delta-quantize → page write → atomic manifest commit."""
        self.wait()
        trees = {"params": params}
        if opt_state is not None:
            trees["opt"] = opt_state
        # Snapshot to host (cheap vs the compression; frees the train loop).
        flat: dict[str, np.ndarray] = {}
        int_leaves: dict[str, int] = {}
        dtypes: dict[str, str] = {}
        for tree_name, tree in trees.items():
            for key, arr in _flatten(tree).items():
                full_key = f"{tree_name}{SEP}{key}"
                if not np.issubdtype(arr.dtype, np.floating):
                    int_leaves[full_key] = arr.tolist() if arr.ndim else int(arr)
                    continue
                dtypes[full_key] = str(arr.dtype)
                flat[full_key] = arr.astype(np.float32)

        def work():
            report = self.engine.save_model(
                f"ckpt-{step}", {"step": step, "dtypes": dtypes,
                                 "ints": int_leaves,
                                 **(extra_meta or {})},
                flat)
            self._commit_manifest(step, {
                "page_bytes": report.page_bytes,
                "original_bytes": report.original_bytes,
                "new_bases": report.n_new_bases,
                "mean_nbit": report.mean_nbit,
            })

        if blocking:
            work()
        else:
            self._bg = threading.Thread(target=work, daemon=True)
            self._bg.start()

    def wait(self):
        if self._bg is not None:
            self._bg.join()
            self._bg = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        self.wait()
        return self._manifest["latest"]

    def restore(self, step: int | None = None, bits: int | None = None):
        """Returns (step, {"params": tree, "opt": tree|None}) as numpy trees.

        The caller re-shards with device_put — restore is mesh-agnostic
        (elastic): save on 256 chips, restore on 8, or vice versa.
        """
        self.wait()
        step = self._manifest["latest"] if step is None else step
        if step is None:
            return None, None
        lm = self.engine.load_model(f"ckpt-{step}", bits=bits)
        arch = lm.architecture
        flat = {}
        for name in lm.tensor_names():
            arr = lm.tensor(name)
            dt = arch["dtypes"].get(name, "float32")
            flat[name] = arr.astype(dt)
        for key, val in arch.get("ints", {}).items():
            flat[key] = np.asarray(val, dtype=np.int32)
        nested = _fix_lists(_unflatten(flat))
        params = nested.get("params")
        opt = nested.get("opt")
        return step, {"params": params, "opt": opt}

    # ------------------------------------------------------------ accounting
    def storage_report(self) -> dict:
        self.wait()
        s = self.engine.storage_bytes()
        orig = sum(self._manifest[f"meta_{st}"]["original_bytes"]
                   for st in self._manifest["steps"])
        return {**s, "original_bytes": orig,
                "compression_ratio": orig / max(s["total"], 1),
                "n_checkpoints": len(self._manifest["steps"])}
