"""Flash attention (forward) Pallas TPU kernel — grouped-GQA, causal/local.

Motivation (EXPERIMENTS.md §Perf): the pure-JAX chunked attention used by
the baseline train/prefill steps round-trips its (B, H, Sq, ck) f32 score
tensors through HBM at every KV chunk — on qwen3-8b train_4k that score
traffic dominates the memory roofline term. This kernel keeps scores,
running max/sum and the accumulator in VMEM scratch across the KV sweep;
only q/k/v tiles and the final output touch HBM, exactly like
FlashAttention-2 on GPU but tiled for (8,128)-aligned VMEM and the MXU.

Grid: (B·KV·G, Sq/bq, Sk/bk) with the KV sweep innermost. Blocks:
q (bq, dh), k/v (bk, dh), VMEM scratch m/l (bq, 1) + acc (bq, dh).
Causal masking prunes nothing structurally (grid is dense) but masked
blocks contribute zeros — block-level skipping is a TODO noted in §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k, block_q, block_k, scale, causal, window, sk_true):
    kk = pl.program_id(2)
    qq = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                     # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kk * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # Padded key positions (>= sk_true) get the large-negative bias so they
    # never win the softmax — this is what makes non-block-aligned Sk safe
    # for bidirectional (non-causal) inputs, where no causal mask would
    # otherwise exclude them.
    mask = k_pos < sk_true
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kk == n_k - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "sk_true",
                     "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0,
                           block_q=128, block_k=128, sk_true=None,
                           interpret=False):
    """q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh); grouped GQA, no KV repeat.

    Returns (B, Sq, H, dh). Sq % block_q == Sk % block_k == 0 (ops.py pads).
    ``sk_true`` is the pre-padding key length: positions >= sk_true are
    masked with the NEG_INF bias inside the kernel, so zero-padded k/v
    rows never contribute softmax mass (defaults to Sk — no padding).
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if sk_true is None:
        sk_true = sk
    g = h // kv
    scale = 1.0 / (dh ** 0.5)
    n_q = sq // block_q
    n_k = sk // block_k
    # Flatten (B, KV, G) into one grid axis; q/o indexed by (b, kv, g),
    # k/v by (b, kv) — the group dim g reuses the same KV block.
    qr = q.reshape(b, sq, kv, g, dh).transpose(0, 2, 3, 1, 4).reshape(
        b * kv * g, sq, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, dh)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, n_k=n_k, block_q=block_q,
                          block_k=block_k, scale=scale, causal=causal,
                          window=window, sk_true=sk_true),
        grid=(b * kv * g, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, qq, kk: (i, qq, 0)),
            pl.BlockSpec((1, block_k, dh), lambda i, qq, kk, g=g: (i // g, kk, 0)),
            pl.BlockSpec((1, block_k, dh), lambda i, qq, kk, g=g: (i // g, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda i, qq, kk: (i, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv * g, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, kv, g, sq, dh).transpose(0, 3, 1, 2, 4).reshape(
        b, sq, h, dh)
