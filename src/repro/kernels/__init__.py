"""Pallas TPU kernels for NeurStore's compute hot-spots.

* ``dequant_matmul`` — fused compute-on-compressed matmul (paper §4.3
  adapted to TPU: dequantization happens tile-wise in VMEM inside the
  matmul, so the full-precision weight never exists in HBM).
* ``quantized_l2`` — batched quantized-L2 distance (the paper's AVX2
  ``QuantizedL2Space``, §5), the HNSW search hot loop.

Each kernel ships with ``ops.py`` jitted wrappers and ``ref.py`` pure-jnp
oracles; tests validate in interpret mode (CPU) against the oracles.
"""

from . import ops, ref
from .ops import (
    dequant_matmul,
    dequant_matmul_auto,
    dequant_matmul_int4,
    flash_attention,
    pack_int4,
    quantized_l2,
)

__all__ = [
    "dequant_matmul",
    "dequant_matmul_auto",
    "dequant_matmul_int4",
    "flash_attention",
    "ops",
    "pack_int4",
    "quantized_l2",
    "ref",
]
