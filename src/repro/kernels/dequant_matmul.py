"""Fused dequantize-and-matmul Pallas TPU kernel — compute on compressed.

The TPU-native form of NeurStore's compression-aware inference (paper §4.3):
instead of inserting DequantizeLinear+Add graph nodes that materialize the
full-precision weight in HBM, the weight stays in HBM as **int8 base codes +
int8 (or int4-packed) delta codes** and is de-quantized **tile-wise in VMEM**
inside the matmul's K-loop. The f32 weight only ever exists as a
(block_k × block_n) VMEM tile feeding the MXU.

HBM bytes per weight element: 2.0 (int8+int8), 1.5 (int8+int4) — vs 2.0 for
bf16 and 4.0 for f32. For memory-bound decode this directly scales the
roofline memory term (see EXPERIMENTS.md §Perf).

Grid: (M/bm, N/bn, K/bk), K innermost so the f32 accumulator tile lives in a
VMEM scratch across the K sweep. Block shapes default to 128-multiples so
matmul dims are MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dequant_matmul_pallas", "dequant_matmul_int4_pallas"]


def _dq_matmul_kernel(x_ref, base_ref, delta_ref, scal_ref, o_ref, acc_ref, *, n_k):
    """One (bm, bn) output tile; K swept by the innermost grid dim."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base_scale = scal_ref[0, 0]
    base_zp = scal_ref[0, 1]
    delta_scale = scal_ref[0, 2]
    delta_zp = scal_ref[0, 3]

    # Dequantize this (bk, bn) weight tile in VMEM: never touches HBM.
    w = (base_ref[...].astype(jnp.float32) - base_zp) * base_scale
    w += (delta_ref[...].astype(jnp.float32) - delta_zp + 0.5) * delta_scale
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def dequant_matmul_pallas(
    x,
    base,
    base_scale,
    base_zp,
    delta,
    delta_scale,
    delta_zp,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """y = x @ (dq(base_int8) + dq(delta_int8)); shapes x:(M,K), w:(K,N)."""
    m, k = x.shape
    k2, n = base.shape
    assert k == k2 and delta.shape == (k, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        "pad inputs to block multiples (ops.py does this)")
    n_k = k // block_k
    scalars = jnp.stack(
        [jnp.float32(base_scale), jnp.float32(base_zp),
         jnp.float32(delta_scale), jnp.float32(delta_zp)]
    ).reshape(1, 4)
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_dq_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, base, delta, scalars)


def _dq_matmul_int4_kernel(x_ref, base_ref, packed_ref, scal_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base_scale = scal_ref[0, 0]
    base_zp = scal_ref[0, 1]
    delta_scale = scal_ref[0, 2]
    delta_zp = scal_ref[0, 3]

    packed = packed_ref[...]  # (bk//2, bn) uint8 — 2 delta nibbles per byte
    low = (packed & 0xF).astype(jnp.float32)
    high = (packed >> 4).astype(jnp.float32)
    bk2, bn = packed.shape
    delta = jnp.stack([low, high], axis=1).reshape(2 * bk2, bn)

    w = (base_ref[...].astype(jnp.float32) - base_zp) * base_scale
    w += (delta - delta_zp + 0.5) * delta_scale
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def dequant_matmul_int4_pallas(
    x,
    base,
    base_scale,
    base_zp,
    packed_delta,
    delta_scale,
    delta_zp,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """y = x @ (dq(base_int8) + dq(unpack4(packed_delta))).

    ``packed_delta`` is (K//2, N) uint8; rows 2k/2k+1 are the low/high
    nibbles (NeurStore flexible loading at b=4 → 1.5 HBM bytes/weight).
    """
    m, k = x.shape
    k2, n = base.shape
    assert k == k2 and packed_delta.shape == (k // 2, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % 2 == 0
    n_k = k // block_k
    scalars = jnp.stack(
        [jnp.float32(base_scale), jnp.float32(base_zp),
         jnp.float32(delta_scale), jnp.float32(delta_zp)]
    ).reshape(1, 4)
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_dq_matmul_int4_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, base, packed_delta, scalars)
