"""Jitted public wrappers over the NeurStore Pallas kernels.

These pad inputs to block multiples, pick interpret mode automatically on
CPU (the kernels TARGET TPU; interpret=True executes the kernel body in
Python for validation), and slice padding back off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .dequant_matmul import dequant_matmul_int4_pallas, dequant_matmul_pallas
from .flash_attention import flash_attention_pallas
from .quantized_l2 import quantized_l2_pallas

__all__ = ["dequant_matmul", "dequant_matmul_auto", "dequant_matmul_int4",
           "flash_attention", "quantized_l2", "quantized_l2_auto",
           "pack_int4", "KERNEL_DISPATCH_MIN_ELEMS"]

# Code blocks (N*D elements) below this floor never dispatch to the kernel:
# the launch + host<->device transfer would swamp the distance math.
KERNEL_DISPATCH_MIN_ELEMS = 4 << 20


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantized_l2_auto(queries, codes, scales, zps, mids, *,
                      min_elems: int = KERNEL_DISPATCH_MIN_ELEMS,
                      force: str | None = None):
    """Dispatch seam for the HNSW batched-distance hot loop.

    Routes a (B, D)-queries-vs-(N, D)-codes block to the Pallas
    ``quantized_l2`` kernel when running on a TPU backend and the block is
    large enough to amortize the launch. Returns the (B, N) float64
    distances, or ``None`` so the caller (``repro.core.hnsw``) falls back
    to its numpy decomposed-gemm form — on CPU that fallback *is* the fast
    path (interpret-mode Pallas executes the kernel body in Python).

    ``force="kernel"`` runs the kernel regardless of backend/size (tests
    use this for CPU interpret-mode parity); ``force="numpy"`` always
    declines.
    """
    if force == "numpy":
        return None
    codes = np.asarray(codes)
    if force != "kernel" and (not _on_tpu() or codes.size < min_elems):
        return None
    q2 = np.atleast_2d(np.asarray(queries, dtype=np.float32))
    n, d = codes.shape
    if q2.shape[0] == 0:
        return np.zeros((0, n), dtype=np.float64)
    # Hoist the O(N*D) pad + host→device transfer out of the per-query
    # loop: once padded, the _pad_to calls inside quantized_l2 are no-ops
    # and each iteration is just one (jit-cached) kernel launch. d_true
    # carries the real dimension past the padding.
    bd = min(512, max(128, d)) if d < 512 else 512
    codes_j = _pad_to(_pad_to(jnp.asarray(codes), 128, 0), bd, 1)
    s = _pad_to(jnp.asarray(np.asarray(scales, dtype=np.float32)), 128, 0)
    z = _pad_to(jnp.asarray(np.asarray(zps, dtype=np.float32)), 128, 0)
    m = _pad_to(jnp.asarray(np.asarray(mids, dtype=np.float32)), 128, 0)
    out = [
        np.asarray(
            quantized_l2(_pad_to(jnp.asarray(q), bd, 0), codes_j, s, z, m,
                         d_true=d)
        )[:n]
        for q in q2
    ]
    return np.stack(out).astype(np.float64)


def _pad_to(x, mult, axis, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


def dequant_matmul(x, base, base_scale, base_zp, delta, delta_scale, delta_zp,
                   *, block_m=128, block_n=128, block_k=128, interpret=None):
    """y = x @ (dq(base) + dq(delta)), fused; pads to MXU-aligned blocks."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = base.shape
    bm = min(block_m, max(8, m)) if m < block_m else block_m
    xp = _pad_to(_pad_to(x, bm, 0), block_k, 1)
    basep = _pad_to(_pad_to(base, block_k, 0), block_n, 1)
    deltap = _pad_to(_pad_to(delta, block_k, 0), block_n, 1)
    # NOTE: padded K rows contribute dq(0)+dq(0) * x_pad(=0) = 0 because x is
    # zero-padded along K — weight padding values are irrelevant.
    y = dequant_matmul_pallas(
        xp, basep, base_scale, base_zp, deltap, delta_scale, delta_zp,
        block_m=bm, block_n=block_n, block_k=block_k, interpret=interpret)
    return y[:m, :n]


def pack_int4(delta4: np.ndarray) -> np.ndarray:
    """(K, N) values in [0,15] → (K//2, N) uint8, row 2k low / 2k+1 high."""
    k, n = delta4.shape
    assert k % 2 == 0
    d = np.asarray(delta4, dtype=np.uint8)
    return (d[0::2] | (d[1::2] << 4)).astype(np.uint8)


def dequant_matmul_int4(x, base, base_scale, base_zp, packed_delta,
                        delta_scale, delta_zp,
                        *, block_m=128, block_n=128, block_k=128, interpret=None):
    """y = x @ (dq(base) + dq(unpack4(packed))); 1.5 HBM bytes/weight."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = base.shape
    bm = min(block_m, max(8, m)) if m < block_m else block_m
    xp = _pad_to(_pad_to(x, bm, 0), block_k, 1)
    basep = _pad_to(_pad_to(base, block_k, 0), block_n, 1)
    packedp = _pad_to(_pad_to(packed_delta, block_k // 2, 0), block_n, 1)
    y = dequant_matmul_int4_pallas(
        xp, basep, base_scale, base_zp, packedp, delta_scale, delta_zp,
        block_m=bm, block_n=block_n, block_k=block_k, interpret=interpret)
    return y[:m, :n]


def dequant_matmul_auto(x, base, base_scale, base_zp, delta, delta_scale,
                        delta_zp, *, packed=False,
                        min_elems: int = KERNEL_DISPATCH_MIN_ELEMS,
                        force: str | None = None,
                        scratch: dict | None = None) -> np.ndarray:
    """Dispatch seam for compute-on-compressed matmuls (serving hot loop).

    ``y = x @ (dq(base) + dq(delta))`` without ever materializing the
    float weight. Routes to the fused Pallas kernel (``dequant_matmul``,
    or ``dequant_matmul_int4`` when ``packed=True``) on a TPU backend when
    the weight block is large enough to amortize the launch; otherwise
    runs the decomposed CPU form

        ``y = x@(bs·Bf + ds·Df) + (-bs·bz + ds·(0.5-dz))·rowsum(x)``

    where ``bs·Bf + ds·Df`` is a single pre-scaled float32 combination of
    the *codes* (cached in the caller-owned ``scratch`` dict across
    calls, e.g. per decode step; valid only while operands and scales are
    fixed) and the scalar zero-point/bin-centre term folds into a rowsum
    correction, so the steady-state cost is one gemm — the same as
    serving a materialized weight. On CPU this decomposition *is* the
    fast path: interpret-mode Pallas executes the kernel body in Python.

    ``x``: (M, K) float; ``base``: (K, N) int8 recentred codes; ``delta``:
    (K, N) int8 recentred codes, or (K//2, N) uint8 nibble-packed when
    ``packed=True`` (``pack_int4`` layout: row 2k low / 2k+1 high, codes
    unsigned in [0, 15] with unsigned zero-point). Zero-points/scales are
    scalars matching the code recentring.

    ``force="kernel"`` runs the Pallas kernel regardless of backend/size
    (interpret mode on CPU — the parity-test hook); ``force="numpy"``
    always takes the decomposed path. Returns (M, N) float32 numpy.
    """
    if force not in (None, "kernel", "numpy"):
        raise ValueError(f"force must be None, 'kernel' or 'numpy': {force!r}")
    base = np.asarray(base)
    use_kernel = force == "kernel" or (
        force is None and _on_tpu() and base.size >= min_elems)
    if use_kernel:
        xj = jnp.asarray(np.asarray(x, dtype=np.float32))
        fn = dequant_matmul_int4 if packed else dequant_matmul
        y = fn(xj, jnp.asarray(base), float(base_scale), float(base_zp),
               jnp.asarray(delta), float(delta_scale), float(delta_zp))
        return np.asarray(y, dtype=np.float32)
    ops = scratch.get("cpu") if scratch is not None else None
    if ops is None:
        bf = base.astype(np.float32) * np.float32(base_scale)
        d = np.asarray(delta)
        if packed:
            # Unpack nibbles to the (K, N) code grid the decomposition
            # needs; the HBM-traffic win of packing belongs to the TPU
            # path — on CPU the one-time unpack is amortized via scratch.
            k2, n = d.shape
            low = (d & 0xF).astype(np.float32)
            high = (d >> 4).astype(np.float32)
            d = np.stack([low, high], axis=1).reshape(2 * k2, n)
        else:
            d = d.astype(np.float32)
        d *= np.float32(delta_scale)
        bf += d
        c = np.float32(-float(base_scale) * float(base_zp)
                       + float(delta_scale) * (0.5 - float(delta_zp)))
        ops = (bf, c)
        if scratch is not None:
            scratch["cpu"] = ops
    wf, c = ops
    x32 = np.asarray(x, dtype=np.float32)
    y = x32 @ wf
    y += c * x32.sum(axis=1, keepdims=True)
    return y


def quantized_l2(query, codes, scales, zps, mids,
                 *, block_n=128, block_d=512, d_true=None, interpret=None):
    """HNSW distance hot loop; pads N and D, returns (N,) f32.

    The kernel computes the decomposed form (code moments + per-row quant
    params; see ``quantized_l2.py``) — zero padding is exact because padded
    codes/query columns contribute nothing to the accumulated moments.
    ``d_true`` overrides the unpadded dimension when the caller passes
    already-padded inputs (``quantized_l2_auto`` hoists the padding out of
    its per-query loop); it scopes the zero-point D·z² correction to the
    real columns.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = codes.shape
    bd = min(block_d, max(128, d)) if d < block_d else block_d
    qp = _pad_to(jnp.asarray(query), bd, 0)
    codesp = _pad_to(_pad_to(jnp.asarray(codes), block_n, 0), bd, 1)
    # Padded rows: scale=0, mid=0 → dequantize to 0; padded query dims are 0,
    # so padded D contributes 0 and padded rows are sliced off below.
    scalesp = _pad_to(jnp.asarray(scales), block_n, 0)
    zpsp = _pad_to(jnp.asarray(zps), block_n, 0)
    midsp = _pad_to(jnp.asarray(mids), block_n, 0)
    out = quantized_l2_pallas(qp, codesp, scalesp, zpsp, midsp,
                              block_n=block_n, block_d=bd,
                              d_true=d if d_true is None else d_true,
                              interpret=interpret)
    return out[:n]


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """Flash attention fwd (grouped GQA); pads Sq/Sk to block multiples."""
    if interpret is None:
        interpret = not _on_tpu()
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    bq = min(block_q, max(8, sq)) if sq < block_q else block_q
    bk = min(block_k, max(8, sk)) if sk < block_k else block_k
    qp = _pad_to(q, bq, 1)
    kp = _pad_to(k, bk, 1)
    vp = _pad_to(v, bk, 1)
    # Padded K positions must never win the softmax: the kernel masks
    # positions >= sk with its large-negative bias (sk_true), which covers
    # bidirectional (hubert-shaped) inputs at any length — causal masking
    # alone only protected them when q ran ahead of k. Padded q rows
    # attend real keys and produce finite garbage, sliced off below.
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_k=bk, sk_true=sk,
                                 interpret=interpret)
    return out[:, :sq]
