"""Jitted public wrappers over the NeurStore Pallas kernels.

These pad inputs to block multiples, pick interpret mode automatically on
CPU (the kernels TARGET TPU; interpret=True executes the kernel body in
Python for validation), and slice padding back off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .dequant_matmul import dequant_matmul_int4_pallas, dequant_matmul_pallas
from .flash_attention import flash_attention_pallas
from .quantized_l2 import quantized_l2_pallas

__all__ = ["dequant_matmul", "dequant_matmul_int4", "flash_attention",
           "quantized_l2", "quantized_l2_auto", "pack_int4",
           "KERNEL_DISPATCH_MIN_ELEMS"]

# Code blocks (N*D elements) below this floor never dispatch to the kernel:
# the launch + host<->device transfer would swamp the distance math.
KERNEL_DISPATCH_MIN_ELEMS = 4 << 20


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantized_l2_auto(queries, codes, scales, zps, mids, *,
                      min_elems: int = KERNEL_DISPATCH_MIN_ELEMS,
                      force: str | None = None):
    """Dispatch seam for the HNSW batched-distance hot loop.

    Routes a (B, D)-queries-vs-(N, D)-codes block to the Pallas
    ``quantized_l2`` kernel when running on a TPU backend and the block is
    large enough to amortize the launch. Returns the (B, N) float64
    distances, or ``None`` so the caller (``repro.core.hnsw``) falls back
    to its numpy decomposed-gemm form — on CPU that fallback *is* the fast
    path (interpret-mode Pallas executes the kernel body in Python).

    ``force="kernel"`` runs the kernel regardless of backend/size (tests
    use this for CPU interpret-mode parity); ``force="numpy"`` always
    declines.
    """
    if force == "numpy":
        return None
    codes = np.asarray(codes)
    if force != "kernel" and (not _on_tpu() or codes.size < min_elems):
        return None
    q2 = np.atleast_2d(np.asarray(queries, dtype=np.float32))
    n, d = codes.shape
    if q2.shape[0] == 0:
        return np.zeros((0, n), dtype=np.float64)
    # Hoist the O(N*D) pad + host→device transfer out of the per-query
    # loop: once padded, the _pad_to calls inside quantized_l2 are no-ops
    # and each iteration is just one (jit-cached) kernel launch. d_true
    # carries the real dimension past the padding.
    bd = min(512, max(128, d)) if d < 512 else 512
    codes_j = _pad_to(_pad_to(jnp.asarray(codes), 128, 0), bd, 1)
    s = _pad_to(jnp.asarray(np.asarray(scales, dtype=np.float32)), 128, 0)
    z = _pad_to(jnp.asarray(np.asarray(zps, dtype=np.float32)), 128, 0)
    m = _pad_to(jnp.asarray(np.asarray(mids, dtype=np.float32)), 128, 0)
    out = [
        np.asarray(
            quantized_l2(_pad_to(jnp.asarray(q), bd, 0), codes_j, s, z, m,
                         d_true=d)
        )[:n]
        for q in q2
    ]
    return np.stack(out).astype(np.float64)


def _pad_to(x, mult, axis, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


def dequant_matmul(x, base, base_scale, base_zp, delta, delta_scale, delta_zp,
                   *, block_m=128, block_n=128, block_k=128, interpret=None):
    """y = x @ (dq(base) + dq(delta)), fused; pads to MXU-aligned blocks."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = base.shape
    bm = min(block_m, max(8, m)) if m < block_m else block_m
    xp = _pad_to(_pad_to(x, bm, 0), block_k, 1)
    basep = _pad_to(_pad_to(base, block_k, 0), block_n, 1)
    deltap = _pad_to(_pad_to(delta, block_k, 0), block_n, 1)
    # NOTE: padded K rows contribute dq(0)+dq(0) * x_pad(=0) = 0 because x is
    # zero-padded along K — weight padding values are irrelevant.
    y = dequant_matmul_pallas(
        xp, basep, base_scale, base_zp, deltap, delta_scale, delta_zp,
        block_m=bm, block_n=block_n, block_k=block_k, interpret=interpret)
    return y[:m, :n]


def pack_int4(delta4: np.ndarray) -> np.ndarray:
    """(K, N) values in [0,15] → (K//2, N) uint8, row 2k low / 2k+1 high."""
    k, n = delta4.shape
    assert k % 2 == 0
    d = np.asarray(delta4, dtype=np.uint8)
    return (d[0::2] | (d[1::2] << 4)).astype(np.uint8)


def dequant_matmul_int4(x, base, base_scale, base_zp, packed_delta,
                        delta_scale, delta_zp,
                        *, block_m=128, block_n=128, block_k=128, interpret=None):
    """y = x @ (dq(base) + dq(unpack4(packed))); 1.5 HBM bytes/weight."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = base.shape
    bm = min(block_m, max(8, m)) if m < block_m else block_m
    xp = _pad_to(_pad_to(x, bm, 0), block_k, 1)
    basep = _pad_to(_pad_to(base, block_k, 0), block_n, 1)
    packedp = _pad_to(_pad_to(packed_delta, block_k // 2, 0), block_n, 1)
    y = dequant_matmul_int4_pallas(
        xp, basep, base_scale, base_zp, packedp, delta_scale, delta_zp,
        block_m=bm, block_n=block_n, block_k=block_k, interpret=interpret)
    return y[:m, :n]


def quantized_l2(query, codes, scales, zps, mids,
                 *, block_n=128, block_d=512, d_true=None, interpret=None):
    """HNSW distance hot loop; pads N and D, returns (N,) f32.

    The kernel computes the decomposed form (code moments + per-row quant
    params; see ``quantized_l2.py``) — zero padding is exact because padded
    codes/query columns contribute nothing to the accumulated moments.
    ``d_true`` overrides the unpadded dimension when the caller passes
    already-padded inputs (``quantized_l2_auto`` hoists the padding out of
    its per-query loop); it scopes the zero-point D·z² correction to the
    real columns.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = codes.shape
    bd = min(block_d, max(128, d)) if d < block_d else block_d
    qp = _pad_to(jnp.asarray(query), bd, 0)
    codesp = _pad_to(_pad_to(jnp.asarray(codes), block_n, 0), bd, 1)
    # Padded rows: scale=0, mid=0 → dequantize to 0; padded query dims are 0,
    # so padded D contributes 0 and padded rows are sliced off below.
    scalesp = _pad_to(jnp.asarray(scales), block_n, 0)
    zpsp = _pad_to(jnp.asarray(zps), block_n, 0)
    midsp = _pad_to(jnp.asarray(mids), block_n, 0)
    out = quantized_l2_pallas(qp, codesp, scalesp, zpsp, midsp,
                              block_n=block_n, block_d=bd,
                              d_true=d if d_true is None else d_true,
                              interpret=interpret)
    return out[:n]


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """Flash attention fwd (grouped GQA); pads Sq/Sk to block multiples."""
    if interpret is None:
        interpret = not _on_tpu()
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    bq = min(block_q, max(8, sq)) if sq < block_q else block_q
    bk = min(block_k, max(8, sk)) if sk < block_k else block_k
    qp = _pad_to(q, bq, 1)
    kp = _pad_to(k, bk, 1)
    vp = _pad_to(v, bk, 1)
    # Padded K positions must never win the softmax: they sit at positions
    # >= sk; causal masking only protects them when q is also padded, so we
    # rely on the window/causal mask plus explicit exclusion via position —
    # padded k rows are zeros, scores 0, masked by causal for q<sk... For
    # bidirectional (hubert) we mask by passing window=0/causal=False and
    # slicing: scores with padded zero-keys add exp(0-m) mass — so instead
    # mask via a large negative bias built into k: simplest correct route is
    # requiring Sk % bk == 0 for non-causal inputs (asserted).
    if not causal and (sk % bk or sq % bq):
        raise ValueError("non-causal flash requires block-aligned Sq/Sk")
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :sq]
