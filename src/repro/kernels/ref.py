"""Pure-jnp oracles for the NeurStore Pallas kernels.

These define the exact semantics the kernels must reproduce; every kernel
test sweeps shapes/dtypes and asserts allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "flash_attention_ref",
    "dequantize_weight_ref",
    "dequant_matmul_ref",
    "unpack_int4_ref",
    "dequant_matmul_int4_ref",
    "quantized_l2_ref",
    "quantized_l2_batch_ref",
]


def dequantize_weight_ref(base, base_scale, base_zp, delta, delta_scale, delta_zp):
    """W = dq(base_int8) + dq(delta_int8)  — the augmented-graph Add node.

    Base uses plain asymmetric dequant; delta uses bin-centre dequant
    (matching ``repro.core.quantize.dequantize_delta``).
    """
    b = (base.astype(jnp.float32) - base_zp) * base_scale
    d = (delta.astype(jnp.float32) - delta_zp + 0.5) * delta_scale
    return b + d


def dequant_matmul_ref(x, base, base_scale, base_zp, delta, delta_scale, delta_zp):
    """y = x @ (dq(base) + dq(delta)); x:(M,K) f32/bf16, base/delta:(K,N) int8."""
    w = dequantize_weight_ref(base, base_scale, base_zp, delta, delta_scale, delta_zp)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def unpack_int4_ref(packed):
    """(K//2, N) uint8 → (K, N) int32 in [0, 15]; row 2k = low nibble."""
    low = (packed & 0xF).astype(jnp.int32)
    high = (packed >> 4).astype(jnp.int32)
    k2, n = packed.shape
    return jnp.stack([low, high], axis=1).reshape(2 * k2, n)


def dequant_matmul_int4_ref(x, base, base_scale, base_zp, packed_delta,
                            delta_scale, delta_zp):
    """Same as :func:`dequant_matmul_ref` with the delta 4-bit packed (2/byte).

    This is NeurStore flexible loading at b=4: weight HBM bytes are
    1 (base) + 0.5 (delta) = 1.5 per element vs 2.0 for bf16.
    """
    delta = unpack_int4_ref(packed_delta)
    b = (base.astype(jnp.float32) - base_zp) * base_scale
    d = (delta.astype(jnp.float32) - delta_zp + 0.5) * delta_scale
    return jnp.dot(x.astype(jnp.float32), b + d, preferred_element_type=jnp.float32)


# The seed's dense float64 hot loop, kept as the parity oracle for the
# decomposed distance in ``repro.core.hnsw`` (same semantics, numpy).
from repro.core.hnsw_ref import quantized_l2_batch_dense as quantized_l2_batch_ref  # noqa: E402


def quantized_l2_ref(query, codes, scales, zps, mids):
    """Squared L2 between f32 query (D,) and N quantized rows (N, D).

    Row i dequantizes as (codes[i] - zps[i]) * scales[i], or the constant
    mids[i] when scales[i] == 0 — mirroring ``hnsw.quantized_l2_batch``.
    The Pallas kernel computes this in decomposed form (see
    ``quantized_l2.py``); this dense version defines the semantics it must
    reproduce.
    """
    deq = (codes.astype(jnp.float32) - zps[:, None]) * scales[:, None]
    deq = jnp.where(scales[:, None] == 0.0, mids[:, None], deq)
    diff = deq - query[None, :].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Oracle: grouped-GQA softmax attention with causal/local masking.

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh)."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k.astype(jnp.float32))
    s = s / (dh ** 0.5)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (qp >= kp)
    if window > 0:
        mask = mask & ((qp - kp) < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", w, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)
