"""Batched quantized-L2 distance Pallas kernel — the HNSW hot loop.

TPU adaptation of the paper's AVX2 ``QuantizedL2Space`` (§5): one f32 query
against a block of int8-quantized base tensors with per-row scale/zero-point.
The HNSW graph walk stays on the host (control flow); each
neighbour-expansion calls this with the frontier's candidate block.

Mirrors the **decomposed** distance used by the host index
(``repro.core.hnsw``): instead of materializing the dequantized rows and
squaring the difference, the D-sweep accumulates three per-row moments of
the raw codes —

    dot_i = Σ_d c_id·q_d      sum_i = Σ_d c_id      sq_i = Σ_d c_id²

— and the final grid step combines them with the per-row quant params and
the query statistics (‖q‖², Σq):

    dist_i = ‖q‖² + s_i²·(sq_i − 2·z_i·sum_i + D·z_i²)
             + 2·(Σq·s_i·z_i − s_i·dot_i)                 (s_i ≠ 0)
    dist_i = ‖q‖² − 2·mid_i·Σq + D·mid_i²                  (s_i = 0)

so the kernel reads the int8 codes once and never forms the (N, D)
dequantized intermediate. Zero-padded columns contribute zero to all three
moments, so only the D·z² term needs the true dimension (``d_true``).

Precision: the float32 moments carry an *absolute* error ~``s·‖q‖·ε₃₂·√D``
into the combined distance (same property as the host path in
``repro.core.hnsw``). Relative error is ≤~1e-4 for queries at typical
distances but can reach ~1e-2 when the query nearly coincides with a row
(the distance itself → 0 while the moments stay ~1e8). Nearest-base
*ranking* is unaffected — competing candidates differ by orders of
magnitude — which is the only property the HNSW walk consumes.

Grid: (N/bn, D/bd); three (bn, 1) moment tiles accumulate across the D
sweep in VMEM scratch. The dense dequantize-and-square semantics the kernel
must reproduce live in ``repro.kernels.ref.quantized_l2_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["quantized_l2_pallas"]


def _ql2_kernel(q_ref, codes_ref, scal_ref, qs_ref, o_ref,
                dot_ref, sum_ref, sq_ref, *, n_d, d_true):
    dd = pl.program_id(1)

    @pl.when(dd == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    c = codes_ref[...].astype(jnp.float32)       # (bn, bd)
    q = q_ref[...].astype(jnp.float32)           # (1, bd) broadcasts over rows
    dot_ref[...] += jnp.sum(c * q, axis=-1, keepdims=True)
    sum_ref[...] += jnp.sum(c, axis=-1, keepdims=True)
    sq_ref[...] += jnp.sum(c * c, axis=-1, keepdims=True)

    @pl.when(dd == n_d - 1)
    def _combine():
        scales = scal_ref[:, 0:1]
        zps = scal_ref[:, 1:2]
        mids = scal_ref[:, 2:3]
        q2 = qs_ref[0, 0]
        qsum = qs_ref[0, 1]
        d = jnp.float32(d_true)
        norm = scales * scales * (sq_ref[...] - 2.0 * zps * sum_ref[...] + d * zps * zps)
        dist = q2 + norm + 2.0 * (qsum * scales * zps - scales * dot_ref[...])
        cdist = q2 - 2.0 * mids * qsum + d * mids * mids
        o_ref[...] = jnp.maximum(jnp.where(scales == 0.0, cdist, dist), 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "d_true", "interpret"))
def quantized_l2_pallas(
    query,
    codes,
    scales,
    zps,
    mids,
    *,
    block_n: int = 128,
    block_d: int = 512,
    d_true: int | None = None,
    interpret: bool = False,
):
    """Squared L2: f32 query (D,) vs N int8 rows (N, D) with per-row quant.

    Returns (N,) f32. Inputs must be padded to block multiples (ops.py pads;
    padded rows get scale=0/mid=0 and are sliced off after; zero padding
    contributes nothing to the code moments, and ``d_true`` scopes the
    zero-point correction to the real columns).
    """
    n, d = codes.shape
    assert query.shape == (d,)
    assert n % block_n == 0 and d % block_d == 0
    n_d = d // block_d
    d_true = d if d_true is None else d_true
    scal = jnp.stack(
        [scales.astype(jnp.float32), zps.astype(jnp.float32), mids.astype(jnp.float32)],
        axis=1,
    )  # (N, 3)
    qf = query.astype(jnp.float32)
    # Query statistics for the decomposed form; zero padding leaves both
    # unchanged, so computing them on the padded query is exact.
    qs = jnp.stack([jnp.vdot(qf, qf), jnp.sum(qf)]).reshape(1, 2)
    grid = (n // block_n, n_d)
    out = pl.pallas_call(
        functools.partial(_ql2_kernel, n_d=n_d, d_true=d_true),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda i, dd: (0, dd)),
            pl.BlockSpec((block_n, block_d), lambda i, dd: (i, dd)),
            pl.BlockSpec((block_n, 3), lambda i, dd: (i, 0)),
            pl.BlockSpec((1, 2), lambda i, dd: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i, dd: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(query.reshape(1, d), codes, scal, qs)
    return out[:, 0]
