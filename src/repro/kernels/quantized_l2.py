"""Batched quantized-L2 distance Pallas kernel — the HNSW hot loop.

TPU adaptation of the paper's AVX2 ``QuantizedL2Space`` (§5): one f32 query
against a block of int8-quantized base tensors with per-row scale/zero-point,
de-quantized in VREGs and reduced on the VPU. The HNSW graph walk stays on
the host (control flow); each neighbour-expansion calls this with the
frontier's candidate block.

Grid: (N/bn, D/bd); the (bn, 1) partial-sum tile accumulates across the D
sweep in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["quantized_l2_pallas"]


def _ql2_kernel(q_ref, codes_ref, scal_ref, o_ref, acc_ref, *, n_d, d_true, block_d):
    dd = pl.program_id(1)

    @pl.when(dd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    scales = scal_ref[:, 0:1]
    zps = scal_ref[:, 1:2]
    mids = scal_ref[:, 2:3]
    deq = (codes_ref[...].astype(jnp.float32) - zps) * scales
    deq = jnp.where(scales == 0.0, mids, deq)
    diff = deq - q_ref[...].astype(jnp.float32)  # (1, bd) broadcasts over rows
    # Mask columns beyond the true dimension (padding would otherwise add
    # ((0 - zp) * scale)^2 per padded column).
    cols = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1) + dd * block_d
    diff = jnp.where(cols < d_true, diff, 0.0)
    acc_ref[...] += jnp.sum(diff * diff, axis=-1, keepdims=True)

    @pl.when(dd == n_d - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "d_true", "interpret"))
def quantized_l2_pallas(
    query,
    codes,
    scales,
    zps,
    mids,
    *,
    block_n: int = 128,
    block_d: int = 512,
    d_true: int | None = None,
    interpret: bool = False,
):
    """Squared L2: f32 query (D,) vs N int8 rows (N, D) with per-row quant.

    Returns (N,) f32. Inputs must be padded to block multiples (ops.py pads;
    padded rows get scale=0/mid=0 and are sliced off after; ``d_true`` masks
    padded columns in-kernel).
    """
    n, d = codes.shape
    assert query.shape == (d,)
    assert n % block_n == 0 and d % block_d == 0
    n_d = d // block_d
    d_true = d if d_true is None else d_true
    scal = jnp.stack(
        [scales.astype(jnp.float32), zps.astype(jnp.float32), mids.astype(jnp.float32)],
        axis=1,
    )  # (N, 3)
    grid = (n // block_n, n_d)
    out = pl.pallas_call(
        functools.partial(_ql2_kernel, n_d=n_d, d_true=d_true, block_d=block_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda i, dd: (0, dd)),
            pl.BlockSpec((block_n, block_d), lambda i, dd: (i, dd)),
            pl.BlockSpec((block_n, 3), lambda i, dd: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i, dd: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n, 1), jnp.float32)],
        interpret=interpret,
    )(query.reshape(1, d), codes, scal)
    return out[:, 0]
