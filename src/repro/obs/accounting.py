"""Space accounting: where every stored byte went, attributably.

The paper's headline number is a compression ratio (§4, Fig. 9), so the
store must be able to answer "logical vs physical, per what?" without a
full rescan. :class:`SpaceAccountant` is the incremental answer: the
engine pushes one :class:`ModelSpace` fact at every commit point
(save / replace / delete / vacuum) and the accountant can at any moment
produce a report broken down per model, per dim-group, per tenant and
store-wide.

This module is pure bookkeeping on purpose. ``repro.obs`` is imported
by every layer and must never import back into them, so nothing here
knows about engines, catalogs or pages — the engine computes the byte
splits (it has the records in hand at save time) and passes plain data
in; refcounts arrive as a ``ref_count(dim_key, vertex_id)`` callable at
report time so shared-base amortization always reflects the *current*
catalog, not the one at save time.

Byte taxonomy (all integers, all bytes):

* **logical**: the uncompressed float32 footprint a naive store would
  hold (``numel * 4`` per tensor) — the denominator of the paper's
  compression ratio.
* **delta**: bit-packed quantized-delta payloads inside the model's
  page (``nbit`` planes of ``ceil(numel/8)`` bytes each).
* **metadata**: everything else in the page file — record headers,
  tensor names, shapes, the offset table and framing. Derived as
  ``page_bytes - delta_bytes`` so ``delta + metadata == page_bytes``
  holds by construction and the *real* conservation check is
  ``page_bytes == os.path.getsize(page)`` (tests/fsck do exactly that).
* **shared base**: 8-bit base codes live in the HNSW index and are
  shared by every model whose tensors reference the vertex. A vertex
  costs ~``numel`` bytes (one byte per element); a model is charged
  ``numel / refcount`` per reference — the same amortization rule as
  ``StorageEngine.per_model_bytes``.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

__all__ = [
    "TensorSpace",
    "ModelSpace",
    "SpaceAccountant",
]

# Key used in the per-tenant breakdown for models that do not belong to
# any tenant namespace (embedded saves without a "t/name" prefix).
UNTENANTED = "_embedded"


@dataclass(frozen=True)
class TensorSpace:
    """Space facts for one stored tensor record."""

    dim_key: int  # dim-group (flattened element count class)
    vertex_id: int  # base vertex this tensor's delta references
    numel: int  # elements (logical bytes = numel * 4)
    delta_bytes: int  # bit-packed delta payload bytes in the page


@dataclass(frozen=True)
class ModelSpace:
    """Space facts for one committed model version (one page file)."""

    name: str
    page: str  # page file name (e.g. "model_7.page")
    page_bytes: int  # on-disk page file size at commit
    logical_bytes: int  # uncompressed f32 footprint
    tensors: tuple[TensorSpace, ...] = field(default_factory=tuple)

    @property
    def delta_bytes(self) -> int:
        return sum(t.delta_bytes for t in self.tensors)

    @property
    def metadata_bytes(self) -> int:
        return self.page_bytes - self.delta_bytes

    def ref_counter(self) -> dict:
        """This model's reference multiset: ``(dim, vid) -> count``."""
        refs: dict = {}
        for t in self.tensors:
            key = (t.dim_key, t.vertex_id)
            refs[key] = refs.get(key, 0) + 1
        return refs


def _ratio(physical: int, logical: int) -> float | None:
    return (physical / logical) if logical > 0 else None


class SpaceAccountant:
    """Incremental logical/physical byte ledger over committed models.

    Mutations (``record_save`` / ``record_delete`` / ``reset``) are
    called by the engine *after* its commit point — the accountant only
    ever describes durable state. All methods are thread-safe; the
    report is computed from an atomic snapshot of the ledger.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._models: dict[str, ModelSpace] = {}

    # --------------------------------------------------------- mutation
    def record_save(self, space: ModelSpace) -> None:
        """Install (or replace, by name) one committed model's facts."""
        with self._lock:
            self._models[space.name] = space

    def record_delete(self, name: str) -> None:
        with self._lock:
            self._models.pop(name, None)

    def reset(self, spaces) -> None:
        """Replace the whole ledger (open-time / post-vacuum rescan)."""
        with self._lock:
            self._models = {s.name: s for s in spaces}

    # ---------------------------------------------------------- queries
    def models(self) -> dict[str, ModelSpace]:
        with self._lock:
            return dict(self._models)

    def totals(self, ref_count) -> tuple[int, int]:
        """``(logical_bytes, physical_bytes)`` store-wide.

        Cheap enough for a gauge callback: physical is page bytes plus
        one byte per element of every *unique* live-referenced vertex.
        """
        models = self.models()
        logical = sum(m.logical_bytes for m in models.values())
        physical = sum(m.page_bytes for m in models.values())
        seen: set = set()
        for m in models.values():
            for t in m.tensors:
                key = (t.dim_key, t.vertex_id)
                if key not in seen and t.vertex_id >= 0:
                    seen.add(key)
                    physical += t.numel
        return logical, physical

    def report(self, ref_count, tenant_of=None) -> dict:
        """Full attribution report (JSON-safe).

        ``ref_count(dim_key, vertex_id)`` must return the store-wide
        live reference count for a base vertex; ``tenant_of(name)``
        optionally maps a model name to its tenant (``None`` = not a
        tenant model). Shape::

            {"store": {...}, "per_model": {...},
             "per_dim": {...}, "per_tenant": {...}}
        """
        models = self.models()
        per_model: dict[str, dict] = {}
        per_dim: dict[int, dict] = {}
        seen_vertices: set = set()
        store_base_bytes = 0

        for name in sorted(models):
            m = models[name]
            own_refs = m.ref_counter()
            shared_base = 0.0
            reclaimable_base = 0
            for t in m.tensors:
                if t.vertex_id < 0:
                    continue
                rc = max(int(ref_count(t.dim_key, t.vertex_id)), 1)
                shared_base += t.numel / rc
                d = per_dim.setdefault(t.dim_key, {
                    "tensors": 0, "logical_bytes": 0, "delta_bytes": 0,
                    "base_vertices": 0, "base_bytes": 0,
                })
                d["tensors"] += 1
                d["logical_bytes"] += t.numel * 4
                d["delta_bytes"] += t.delta_bytes
                key = (t.dim_key, t.vertex_id)
                if key not in seen_vertices:
                    seen_vertices.add(key)
                    store_base_bytes += t.numel
                    d["base_vertices"] += 1
                    d["base_bytes"] += t.numel
            # Reclaimable-on-delete: the page itself, plus every base
            # vertex whose only live references come from this model
            # (its refcount equals this model's contribution).
            for (dim, vid), count in own_refs.items():
                if vid < 0:
                    continue
                if int(ref_count(dim, vid)) <= count:
                    numel = next(
                        t.numel for t in m.tensors
                        if t.dim_key == dim and t.vertex_id == vid)
                    reclaimable_base += numel
            physical = m.page_bytes + int(round(shared_base))
            per_model[name] = {
                "page": m.page,
                "n_tensors": len(m.tensors),
                "logical_bytes": m.logical_bytes,
                "page_bytes": m.page_bytes,
                "delta_bytes": m.delta_bytes,
                "metadata_bytes": m.metadata_bytes,
                "shared_base_bytes": int(round(shared_base)),
                "physical_bytes": physical,
                "reclaimable_bytes": m.page_bytes + reclaimable_base,
                "compression_ratio": _ratio(physical, m.logical_bytes),
            }

        store_logical = sum(m.logical_bytes for m in models.values())
        store_page = sum(m.page_bytes for m in models.values())
        store_delta = sum(m.delta_bytes for m in models.values())
        store_physical = store_page + store_base_bytes
        store = {
            "models": len(models),
            "logical_bytes": store_logical,
            "physical_bytes": store_physical,
            "page_bytes": store_page,
            "delta_bytes": store_delta,
            "metadata_bytes": store_page - store_delta,
            "base_bytes": store_base_bytes,
            "compression_ratio": _ratio(store_physical, store_logical),
        }

        per_tenant: dict[str, dict] = {}
        for name, pm in per_model.items():
            tenant = tenant_of(name) if tenant_of is not None else None
            if tenant is None:
                head, sep, _ = name.partition("/")
                tenant = head if sep else UNTENANTED
            t = per_tenant.setdefault(tenant, {
                "models": 0, "logical_bytes": 0, "physical_bytes": 0,
                "page_bytes": 0, "delta_bytes": 0,
            })
            t["models"] += 1
            t["logical_bytes"] += pm["logical_bytes"]
            t["physical_bytes"] += pm["physical_bytes"]
            t["page_bytes"] += pm["page_bytes"]
            t["delta_bytes"] += pm["delta_bytes"]
        for t in per_tenant.values():
            t["compression_ratio"] = _ratio(
                t["physical_bytes"], t["logical_bytes"])

        return {
            "store": store,
            "per_model": per_model,
            "per_dim": {str(k): v for k, v in sorted(per_dim.items())},
            "per_tenant": per_tenant,
        }

    # ------------------------------------------------------------ drift
    def diff(self, other: "SpaceAccountant") -> list[str]:
        """Compare two ledgers; each discrepancy is one human-readable
        line. Empty list = no drift. ``self`` is the incremental ledger,
        ``other`` the rescan ground truth (fsck ``--accounting``)."""
        mine = self.models()
        theirs = other.models()
        out: list[str] = []
        for name in sorted(set(mine) - set(theirs)):
            out.append(f"accounting: {name!r} tracked but not on disk")
        for name in sorted(set(theirs) - set(mine)):
            out.append(f"accounting: {name!r} on disk but not tracked")
        for name in sorted(set(mine) & set(theirs)):
            a, b = mine[name], theirs[name]
            for attr in ("page", "page_bytes", "logical_bytes",
                         "delta_bytes"):
                av, bv = getattr(a, attr), getattr(b, attr)
                if av != bv:
                    out.append(
                        f"accounting: {name!r} {attr} drift "
                        f"(tracked {av!r} != rescan {bv!r})")
            if a.ref_counter() != b.ref_counter():
                out.append(
                    f"accounting: {name!r} base-reference drift "
                    "(tracked refs != rescan refs)")
        return out
