"""Spans, trace context propagation, recent-trace ring, slow-op log.

A *span* is one timed operation; spans nest into a tree via a
``contextvars.ContextVar`` holding the current span (contextvars are
per-thread under ``ThreadingHTTPServer``, so concurrent requests never
cross-contaminate).  The root span of each tree carries the W3C-style
``trace_id``; ``Span.traceparent()`` / ``parse_traceparent()`` move it
across the HTTP hop (``StoreClient`` sends the header on every request,
``ModelStoreServer`` adopts it), so a client-side trace id names the
server-side span tree for the same logical operation.

Completed **root** spans go two places:

- a bounded in-memory ring (``recent_traces()``), newest last, for
  ``tools/nstat.py`` and post-hoc debugging;
- the slow-op log: a root span whose elapsed time exceeds
  ``set_slow_op_threshold()`` emits its full indented span tree at
  WARNING via ``logging.getLogger("repro.obs.slow")``.

Timing is monotonic (``time.perf_counter``).  ``trace()`` always times —
even with observability disabled — because engine wall-time reporting
(``SaveReport.seconds``) is derived from spans; disabling only stops
recording (no ring append, no slow-op log, no attr retention).
"""

from __future__ import annotations

import contextvars
import logging
import os
import secrets
import threading
import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Tuple

from repro.obs import metrics as _metrics

__all__ = [
    "Span",
    "current_span",
    "get_slow_op_threshold",
    "parse_traceparent",
    "recent_traces",
    "set_slow_op_threshold",
    "set_trace_ring_size",
    "trace",
]

_slow_log = logging.getLogger("repro.obs.slow")

_current: contextvars.ContextVar[Optional["Span"]] = contextvars.ContextVar(
    "repro_obs_current_span", default=None
)

_ring_lock = threading.Lock()
_ring: Deque["Span"] = deque(maxlen=256)

# Seconds; roots slower than this dump their tree to the slow-op log.
# Default 1.0 s: a full-model save at bench scale sits well under it,
# so production logs stay quiet unless something is actually slow.
# Overridable without code via NEURSTORE_SLOW_OP_THRESHOLD_S (read once
# at import; invalid values fall back to the default), and at runtime
# via set_slow_op_threshold() / the ModelStoreServer knob.
DEFAULT_SLOW_OP_THRESHOLD_S = 1.0


def _threshold_from_env() -> float:
    raw = os.environ.get("NEURSTORE_SLOW_OP_THRESHOLD_S")
    if raw is None:
        return DEFAULT_SLOW_OP_THRESHOLD_S
    try:
        val = float(raw)
    except ValueError:
        return DEFAULT_SLOW_OP_THRESHOLD_S
    if not (val > 0.0):  # rejects NaN, zero and negatives
        return DEFAULT_SLOW_OP_THRESHOLD_S
    return val


_slow_threshold_s = _threshold_from_env()

_slow_ops_total = _metrics.default_registry().counter(
    "neurstore_slow_ops_total",
    "Root spans exceeding the slow-op threshold, by root span name.",
    labelnames=("op",),
)


def set_trace_ring_size(n: int) -> None:
    """Resize the recent-trace ring (drops existing entries)."""
    global _ring
    with _ring_lock:
        _ring = deque(maxlen=max(1, int(n)))


def set_slow_op_threshold(seconds: float) -> float:
    """Set the slow-op threshold; returns the previous value."""
    global _slow_threshold_s
    prev = _slow_threshold_s
    _slow_threshold_s = float(seconds)
    return prev


def get_slow_op_threshold() -> float:
    return _slow_threshold_s


def recent_traces(n: Optional[int] = None) -> List["Span"]:
    """Most recent completed root spans, oldest first."""
    with _ring_lock:
        items = list(_ring)
    return items if n is None else items[-n:]


def _new_trace_id() -> str:
    return secrets.token_hex(16)


def _new_span_id() -> str:
    return secrets.token_hex(8)


def parse_traceparent(header: str) -> Optional[Tuple[str, str]]:
    """Parse a W3C traceparent header -> (trace_id, parent_span_id).

    Accepts ``{version}-{trace_id:32hex}-{span_id:16hex}-{flags}``;
    returns None on anything malformed (callers start a fresh trace).
    """
    if not header:
        return None
    parts = header.strip().split("-")
    if len(parts) != 4:
        return None
    version, trace_id, span_id, _flags = parts
    if len(version) != 2 or len(trace_id) != 32 or len(span_id) != 16:
        return None
    try:
        int(version, 16)
        int(trace_id, 16)
        int(span_id, 16)
    except ValueError:
        return None
    if trace_id == "0" * 32 or span_id == "0" * 16:
        return None
    return trace_id, span_id


class Span:
    """One timed operation.  Use via ``trace()``; not constructed directly.

    Attributes are public and stable for tools/tests: ``name``,
    ``trace_id``, ``span_id``, ``parent_id``, ``attrs``, ``children``,
    ``start`` / ``end`` (perf_counter seconds; ``end`` is None while
    open).
    """

    __slots__ = (
        "name",
        "trace_id",
        "span_id",
        "parent_id",
        "attrs",
        "children",
        "start",
        "end",
        "_token",
        "_recording",
    )

    def __init__(
        self,
        name: str,
        trace_id: str,
        span_id: str,
        parent_id: Optional[str],
        recording: bool,
        attrs: Optional[Dict[str, object]] = None,
    ):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs: Dict[str, object] = dict(attrs) if attrs else {}
        self.children: List[Span] = []
        self.start = time.perf_counter()
        self.end: Optional[float] = None
        self._token: Optional[contextvars.Token] = None
        self._recording = recording

    def elapsed(self) -> float:
        """Seconds since start (wall time of the span once closed)."""
        return (self.end if self.end is not None else time.perf_counter()) - self.start

    def set_attr(self, key: str, value: object) -> None:
        if self._recording:
            self.attrs[key] = value

    def traceparent(self) -> str:
        return f"00-{self.trace_id}-{self.span_id}-01"

    # -- context manager -------------------------------------------------

    def __enter__(self) -> "Span":
        self._token = _current.set(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.end = time.perf_counter()
        if self._token is not None:
            _current.reset(self._token)
            self._token = None
        if not self._recording:
            return
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        # Local root (no enclosing span in this context — a span adopted
        # from a remote traceparent has a parent_id but is still the
        # local root): publish to the ring + slow-op log.
        if _current.get() is None:
            self._finish_root()

    def _finish_root(self) -> None:
        with _ring_lock:
            _ring.append(self)
        took = self.elapsed()
        if took >= _slow_threshold_s:
            _slow_ops_total.labels(self.name).inc()
            _slow_log.warning(
                "slow op: %s took %.3fs (threshold %.3fs)\n%s",
                self.name,
                took,
                _slow_threshold_s,
                self.format_tree(),
            )

    # -- inspection ------------------------------------------------------

    def walk(self) -> Iterator["Span"]:
        yield self
        for child in self.children:
            yield from child.walk()

    def find(self, name: str) -> Optional["Span"]:
        for span in self.walk():
            if span.name == name:
                return span
        return None

    def format_tree(self, indent: int = 0) -> str:
        """Indented one-line-per-span rendering (the slow-op log format)."""
        attrs = ""
        if self.attrs:
            attrs = " " + " ".join(
                f"{k}={v}" for k, v in sorted(self.attrs.items())
            )
        lines = [
            f"{'  ' * indent}- {self.name} {self.elapsed() * 1e3:.3f}ms"
            f" [{self.span_id}]{attrs}"
        ]
        for child in self.children:
            lines.append(child.format_tree(indent + 1))
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "elapsed_s": self.elapsed(),
            "attrs": dict(self.attrs),
            "children": [c.to_dict() for c in self.children],
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Span({self.name!r}, trace={self.trace_id[:8]}…, "
            f"elapsed={self.elapsed():.6f}s, children={len(self.children)})"
        )


def current_span() -> Optional[Span]:
    return _current.get()


def trace(
    name: str,
    parent: Optional[Tuple[str, str]] = None,
    **attrs: object,
) -> Span:
    """Open a span as a context manager.

    Nesting is implicit: a ``trace()`` inside an active span becomes its
    child.  ``parent=(trace_id, span_id)`` (from ``parse_traceparent``)
    grafts this span under a **remote** parent instead — used by the
    server to adopt a client's trace id.

    With observability disabled the span still measures time (callers
    rely on ``elapsed()``) but records nothing: no child linkage beyond
    the context var, no ring, no slow-op log.
    """
    recording = _metrics.metrics_enabled()
    cur = _current.get()
    if not recording:
        # Disabled: a timer-only span.  No id generation (os.urandom is
        # the dominant cost of span creation), no child linkage.
        return Span(
            name,
            trace_id="0" * 32,
            span_id="0" * 16,
            parent_id=None,
            recording=False,
        )
    if parent is not None:
        trace_id, parent_id = parent
    elif cur is not None:
        trace_id, parent_id = cur.trace_id, cur.span_id
    else:
        trace_id, parent_id = _new_trace_id(), None
    span = Span(
        name,
        trace_id=trace_id,
        span_id=_new_span_id(),
        parent_id=parent_id,
        recording=recording,
        attrs=attrs,
    )
    if recording and cur is not None and parent is None:
        cur.children.append(span)
    return span
