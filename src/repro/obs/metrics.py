"""Process-wide metrics registry with a Prometheus text renderer.

Design constraints, in order:

1. **Hot-path cost.**  ``Counter.inc()`` sits inside HNSW search loops
   and the buffer-pool ``get()``.  An increment is one attribute load,
   one branch, one float ``+=`` — "atomic enough" under the GIL: a
   single ``+=`` on an instance attribute can interleave and drop an
   update under free-threading, but never corrupts, and the serving
   workload is orders of magnitude below where drops are observable.
   No lock is taken on increment; locks guard only child creation and
   rendering.
2. **Near-zero when disabled.**  ``set_enabled(False)`` flips one
   module-global checked at the top of every mutate call.  The
   counter-increment microbench in ``serving_bench.py`` records both
   costs.
3. **Stable exposition.**  ``render()`` emits Prometheus text format
   (``# HELP`` / ``# TYPE``, cumulative ``_bucket{le=...}`` histograms);
   ``parse_prometheus_text`` round-trips it for tests and for the
   bench-smoke scrape check.

Metric families are created idempotently: ``registry.counter(name, ...)``
returns the existing family if the name is taken (and asserts the type
matches), so every instrumented module can declare its own families at
import time without coordination.

Gauges support two styles: direct ``set()``/``inc()`` for values owned
by one writer (e.g. requests in flight), and **weakref callbacks**
(``gauge.attach(owner, fn)``) for values derived from live objects
(bytes resident in a buffer pool).  Tests open many engines per
process; attaching via weakref means a closed/collected engine silently
drops out of the sum instead of pinning itself alive or reporting stale
bytes.
"""

from __future__ import annotations

import math
import re
import threading
import weakref
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "parse_prometheus_text",
    "set_enabled",
    "LATENCY_BUCKETS",
]

# Toggled by set_enabled(); read (not imported) by every mutate call so
# the flip is visible process-wide without rebinding callers.
_ENABLED = True

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Fixed log-scaled latency buckets (seconds): 1 us .. 10 s, x10 per
# decade with a 2.5/5 split — fine enough to separate a pool hit from a
# page read from a full-model decode, coarse enough that a histogram is
# 23 floats.  Shared by every latency histogram so dashboards align.
LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    round(base * 10.0**exp, 12)
    for exp in range(-6, 1)
    for base in (1.0, 2.5, 5.0)
) + (10.0,)


def set_enabled(enabled: bool) -> None:
    """Enable/disable all metric mutation process-wide (render still works)."""
    global _ENABLED
    _ENABLED = bool(enabled)


def metrics_enabled() -> bool:
    return _ENABLED


def _fmt(v: float) -> str:
    """Prometheus-style float: integers bare, +Inf spelled, else repr."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if v != v:  # NaN
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_str(names: Sequence[str], values: Sequence[str]) -> str:
    if not names:
        return ""
    inner = ",".join(
        f'{n}="{_escape_label(str(v))}"' for n, v in zip(names, values)
    )
    return "{" + inner + "}"


class _Family:
    """Base for one named metric family with a fixed label schema."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str]):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name: {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name: {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()

    def labels(self, *values: object):
        """Child for one label-value tuple (created on first use)."""
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            if len(key) != len(self.labelnames):
                raise ValueError(
                    f"{self.name}: expected {len(self.labelnames)} label "
                    f"values, got {len(key)}"
                )
            with self._lock:
                child = self._children.setdefault(key, self._new_child())
        return child

    def _new_child(self):  # pragma: no cover - abstract
        raise NotImplementedError

    def samples(self) -> Iterable[Tuple[str, str, float]]:
        """Yield (name_suffix, label_str, value) triples for render()."""
        raise NotImplementedError  # pragma: no cover - abstract


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if _ENABLED:
            self.value += amount


class Counter(_Family):
    """Monotonic counter family.  Unlabeled families inc on self."""

    kind = "counter"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._self_child = _CounterChild() if not labelnames else None

    def _new_child(self) -> _CounterChild:
        return _CounterChild()

    def inc(self, amount: float = 1.0) -> None:
        if self._self_child is None:
            raise ValueError(f"{self.name} is labeled; use .labels(...).inc()")
        if _ENABLED:
            self._self_child.value += amount

    @property
    def value(self) -> float:
        if self._self_child is None:
            raise ValueError(f"{self.name} is labeled; read children instead")
        return self._self_child.value

    def samples(self):
        # Text format 0.0.4: the counter sample name IS the family name
        # (the `_total` suffix is a naming convention, not appended).
        if self._self_child is not None:
            yield "", "", self._self_child.value
            return
        with self._lock:
            items = sorted(self._children.items())
        for key, child in items:
            yield "", _label_str(self.labelnames, key), child.value


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        if _ENABLED:
            self.value = value

    def inc(self, amount: float = 1.0) -> None:
        if _ENABLED:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        if _ENABLED:
            self.value -= amount


class Gauge(_Family):
    """Gauge family: direct set/inc/dec plus weakref-bound callbacks.

    ``attach(owner, fn)`` registers ``fn()`` to be summed into the
    unlabeled value at render time for as long as ``owner`` is alive.
    A callback that raises contributes 0 (render must never fail
    because one engine is mid-close).
    """

    kind = "gauge"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._self_child = _GaugeChild() if not labelnames else None
        self._callbacks: List[Tuple[weakref.ref, Callable[[], float]]] = []

    def _new_child(self) -> _GaugeChild:
        return _GaugeChild()

    def set(self, value: float) -> None:
        if self._self_child is None:
            raise ValueError(f"{self.name} is labeled; use .labels(...).set()")
        if _ENABLED:
            self._self_child.value = value

    def inc(self, amount: float = 1.0) -> None:
        if self._self_child is None:
            raise ValueError(f"{self.name} is labeled; use .labels(...).inc()")
        if _ENABLED:
            self._self_child.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def attach(self, owner: object, fn: Callable[[object], float]) -> None:
        """Sum ``fn(owner)`` into this gauge while ``owner`` is alive.

        ``fn`` receives the (still-live) owner as its only argument — it
        must NOT close over the owner, or the strong reference in the
        closure would defeat the weakref and pin the owner forever.
        """
        if self._self_child is None:
            raise ValueError(f"{self.name} is labeled; attach is unlabeled-only")
        with self._lock:
            self._callbacks.append((weakref.ref(owner), fn))

    def _callback_sum(self) -> float:
        total = 0.0
        dead = False
        with self._lock:
            callbacks = list(self._callbacks)
        for ref, fn in callbacks:
            obj = ref()
            if obj is None:
                dead = True
                continue
            try:
                total += float(fn(obj))
            except Exception:
                continue
        if dead:
            with self._lock:
                self._callbacks = [
                    (r, f) for r, f in self._callbacks if r() is not None
                ]
        return total

    @property
    def value(self) -> float:
        if self._self_child is None:
            raise ValueError(f"{self.name} is labeled; read children instead")
        return self._self_child.value + self._callback_sum()

    def samples(self):
        if self._self_child is not None:
            yield "", "", self._self_child.value + self._callback_sum()
            return
        with self._lock:
            items = sorted(self._children.items())
        for key, child in items:
            yield "", _label_str(self.labelnames, key), child.value


class _HistogramChild:
    __slots__ = ("bucket_counts", "sum", "count", "buckets")

    def __init__(self, buckets: Tuple[float, ...]) -> None:
        self.buckets = buckets
        self.bucket_counts = [0] * len(buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        if not _ENABLED:
            return
        buckets = self.buckets
        lo, hi = 0, len(buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if value <= buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        if lo < len(buckets):
            self.bucket_counts[lo] += 1
        self.sum += value
        self.count += 1


class Histogram(_Family):
    """Histogram family with fixed buckets (defaults to LATENCY_BUCKETS)."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        labelnames: Sequence[str] = (),
        buckets: Optional[Sequence[float]] = None,
    ):
        super().__init__(name, help, labelnames)
        b = tuple(sorted(buckets if buckets is not None else LATENCY_BUCKETS))
        if not b:
            raise ValueError("histogram needs at least one bucket")
        self.buckets = b
        self._self_child = _HistogramChild(b) if not labelnames else None

    def _new_child(self) -> _HistogramChild:
        return _HistogramChild(self.buckets)

    def observe(self, value: float) -> None:
        if self._self_child is None:
            raise ValueError(
                f"{self.name} is labeled; use .labels(...).observe()"
            )
        self._self_child.observe(value)

    def _child_samples(self, label_key: Tuple[str, ...], child: _HistogramChild):
        cumulative = 0
        for ub, n in zip(child.buckets, child.bucket_counts):
            cumulative += n
            names = self.labelnames + ("le",)
            values = label_key + (_fmt(ub),)
            yield "_bucket", _label_str(names, values), float(cumulative)
        names = self.labelnames + ("le",)
        values = label_key + ("+Inf",)
        yield "_bucket", _label_str(names, values), float(child.count)
        base = _label_str(self.labelnames, label_key)
        yield "_sum", base, child.sum
        yield "_count", base, float(child.count)

    def samples(self):
        if self._self_child is not None:
            yield from self._child_samples((), self._self_child)
            return
        with self._lock:
            items = sorted(self._children.items())
        for key, child in items:
            yield from self._child_samples(key, child)


class MetricsRegistry:
    """Named families, created idempotently, rendered as Prometheus text."""

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name, help, labelnames, **kw) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls) or fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-registered with a different "
                        f"type or label schema"
                    )
                return fam
            fam = cls(name, help, labelnames, **kw)
            self._families[name] = fam
            return fam

    def counter(
        self, name: str, help: str, labelnames: Sequence[str] = ()
    ) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(
        self, name: str, help: str, labelnames: Sequence[str] = ()
    ) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(
        self,
        name: str,
        help: str,
        labelnames: Sequence[str] = (),
        buckets: Optional[Sequence[float]] = None,
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, labelnames, buckets=buckets
        )

    def families(self) -> List[_Family]:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        out: List[str] = []
        for fam in self.families():
            help_text = fam.help.replace("\\", "\\\\").replace("\n", "\\n")
            out.append(f"# HELP {fam.name} {help_text}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            for suffix, labels, value in fam.samples():
                out.append(f"{fam.name}{suffix}{labels} {_fmt(value)}")
        return "\n".join(out) + "\n"

    def sample_value(
        self, name: str, labels: Optional[Dict[str, str]] = None
    ) -> Optional[float]:
        """Test/tool helper: current value of one rendered sample."""
        parsed = parse_prometheus_text(self.render())
        want = dict(labels or {})
        for fam in parsed.values():
            for sample in fam["samples"]:
                if sample["name"] == name and sample["labels"] == want:
                    return sample["value"]
        return None


_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _default


def _strip_hist_suffix(sample_name: str) -> str:
    for suffix in ("_bucket", "_sum", "_count"):
        if sample_name.endswith(suffix):
            return sample_name[: -len(suffix)]
    return sample_name


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>\S+)(?:\s+\d+)?$"
)
_LABEL_PAIR_RE = re.compile(
    r'(?P<name>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<value>(?:[^"\\]|\\.)*)"'
)


def _unescape_label(v: str) -> str:
    return v.replace("\\n", "\n").replace('\\"', '"').replace("\\\\", "\\")


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    if s == "NaN":
        return math.nan
    return float(s)


def parse_prometheus_text(text: str) -> Dict[str, dict]:
    """Parse exposition text back into {family: {type, help, samples}}.

    Strict enough to catch malformed output (used by the bench-smoke
    scrape check): every non-comment line must match the sample grammar,
    every sample must belong to a family announced by ``# TYPE``.
    Raises ``ValueError`` on violation.
    """
    families: Dict[str, dict] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP ") :].split(" ", 1)
            fam = families.setdefault(
                parts[0], {"type": None, "help": "", "samples": []}
            )
            fam["help"] = parts[1] if len(parts) > 1 else ""
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE ") :].split(" ", 1)
            if len(parts) != 2 or parts[1] not in (
                "counter",
                "gauge",
                "histogram",
                "summary",
                "untyped",
            ):
                raise ValueError(f"line {lineno}: bad TYPE line: {raw!r}")
            fam = families.setdefault(
                parts[0], {"type": None, "help": "", "samples": []}
            )
            fam["type"] = parts[1]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample: {raw!r}")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            consumed = 0
            for pm in _LABEL_PAIR_RE.finditer(m.group("labels")):
                labels[pm.group("name")] = _unescape_label(pm.group("value"))
                consumed += 1
            # Every comma-separated pair must have parsed.
            n_pairs = len([p for p in m.group("labels").split(",") if p])
            if consumed != n_pairs:
                raise ValueError(f"line {lineno}: malformed labels: {raw!r}")
        name = m.group("name")
        # Exact family-name match wins (a counter named *_count is its
        # own family); otherwise strip histogram sample suffixes.
        if name in families and families[name]["type"] is not None:
            base = name
        else:
            base = _strip_hist_suffix(name)
        if base not in families or families[base]["type"] is None:
            raise ValueError(
                f"line {lineno}: sample {name!r} has no # TYPE announcement"
            )
        families[base]["samples"].append(
            {"name": name, "labels": labels, "value": _parse_value(m.group("value"))}
        )
    return families
