"""Observability substrate: metrics registry + request tracing.

Stdlib-only and dependency-free by design — ``repro.obs`` is imported by
every layer (core engine, buffer pool, HNSW, maintenance, server,
client, tools) and must never import back into them.  Everything here is
process-wide: one default :class:`MetricsRegistry`, one trace ring, one
slow-op threshold.

Instrumentation is **on by default**.  ``set_enabled(False)`` collapses
every counter increment to one attribute load + one branch and every
``trace()`` block to a bare ``perf_counter`` pair (timing stays correct
— ``SaveReport.seconds`` is derived from spans — but nothing is
recorded).  ``benchmarks/serving_bench.py`` measures both modes and
``benchmarks/perf_gate.py`` enforces obs-on >= 0.95x obs-off QPS.
"""

from repro.obs.accounting import (
    ModelSpace,
    SpaceAccountant,
    TensorSpace,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    parse_prometheus_text,
    set_enabled,
)
from repro.obs.trace import (
    Span,
    current_span,
    get_slow_op_threshold,
    parse_traceparent,
    recent_traces,
    set_slow_op_threshold,
    trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ModelSpace",
    "Span",
    "SpaceAccountant",
    "TensorSpace",
    "current_span",
    "default_registry",
    "get_slow_op_threshold",
    "parse_prometheus_text",
    "parse_traceparent",
    "recent_traces",
    "set_enabled",
    "set_slow_op_threshold",
    "trace",
]
