"""Render EXPERIMENTS.md tables from dryrun JSON (or the log as fallback)."""

from __future__ import annotations

import json
import sys


def fmt_row(r) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | — | skipped: {r['reason']} "
                f"| | | | | |")
    if "error" in r:
        return f"| {r['arch']} | {r['shape']} | — | ERROR | | | | | |"
    t = r["roofline_s"]
    pd = r["per_device"]
    mesh = "2×16×16" if r.get("multi_pod") else "16×16"
    return ("| {a} | {s} | {m} | {hbm:.1f} | {c:.3f} | {me:.3f} | {co:.3f} "
            "| {b} | {u:.2f} | {rf:.1%} |").format(
        a=r["arch"], s=r["shape"], m=mesh,
        hbm=pd["peak_hbm_bytes"] / 2**30,
        c=t["compute"], me=t["memory"], co=t["collective"],
        b=r["bottleneck"], u=r["useful_flops_ratio"],
        rf=r["roofline_fraction"] if r["shape"].startswith(("train", "prefill"))
        else r.get("bandwidth_fraction", 0.0))


def main(path: str, multi_pod: bool | None = None):
    with open(path) as f:
        rows = json.load(f)
    print("| arch | shape | mesh | HBM GiB/dev | compute s | memory s "
          "| collective s | bottleneck | useful-FLOPs | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    seen = set()
    for r in rows:
        if multi_pod is not None and bool(r.get("multi_pod")) != multi_pod:
            continue
        key = (r["arch"], r["shape"], r.get("skipped", False))
        if r.get("skipped") and key in seen:
            continue  # one skip record per mesh — show once
        seen.add(key)
        print(fmt_row(r))


if __name__ == "__main__":
    mp = None
    if len(sys.argv) > 2:
        mp = sys.argv[2] == "multi"
    main(sys.argv[1], mp)
