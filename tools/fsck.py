#!/usr/bin/env python
"""Offline checker / repairer for a NeurStore store directory.

Check phase (read-only, no engine): verifies the ``meta.json`` snapshot
CRC (and the ``.prev`` fallback), classifies journal damage (torn tail vs
corrupt body), verifies every model page's framing + per-record checksums,
verifies every HNSW index file's frame CRC + deserialization, cross-checks
the ``vertex_refs`` table against the references actually present in
healthy committed pages, and flags dangling base references and orphan
files. ``errors`` are integrity violations; ``warnings`` are survivable
states the engine handles itself (pending transactions, quarantined
models, orphans awaiting the open-time sweep).

Repair phase (``--repair``): promotes ``meta.json.prev`` over a corrupt
``meta.json`` (the damaged file is kept as ``meta.json.corrupt``), sets
aside a body-corrupt journal, then opens a :class:`StorageEngine` — which
replays pending transactions, truncates any torn journal tail and sweeps
orphans — and runs ``verify_store(quarantine=True)`` so damaged models are
quarantined in the catalog. With ``--drop-corrupt`` the quarantined models
are deleted, corrupt index files they referenced are removed, and the
reference table is rebuilt wholesale from the surviving pages.

Exit status: 0 if the store is clean (no errors; warnings allowed), 1
otherwise. See ``docs/durability.md`` for the corruption contract.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
if _SRC not in sys.path:  # runnable as a script from a checkout
    sys.path.insert(0, _SRC)

from repro.core.catalog import (  # noqa: E402
    STATUS_COMMITTED,
    STATUS_CORRUPT,
    CatalogState,
    read_journal,
)
from repro.core.engine import StorageEngine  # noqa: E402
from repro.core.hnsw import HNSWIndex  # noqa: E402
from repro.core.integrity import (  # noqa: E402
    CorruptMetaError,
    CorruptPageError,
    parse_meta,
    unframe_index,
)
from repro.core.pages import page_dim_keys, read_record, verify_page  # noqa: E402

__all__ = ["fsck"]


def _read(path: str) -> bytes:
    with open(path, "rb") as f:
        return f.read()


def _load_meta(root: str, rep: dict) -> CatalogState | None:
    """Parse meta.json (or its .prev fallback), recording errors/warnings."""
    meta = os.path.join(root, "meta.json")
    prev = meta + ".prev"
    primary: str | None = None
    if os.path.exists(meta):
        try:
            return CatalogState.from_dict(
                parse_meta(_read(meta).decode("utf-8"), meta)
            )
        except (CorruptMetaError, UnicodeDecodeError) as exc:
            primary = f"meta.json corrupt: {exc}"
    elif os.path.exists(prev):
        primary = "meta.json missing but meta.json.prev exists"
    else:
        return CatalogState()  # fresh/empty store
    try:
        state = CatalogState.from_dict(
            parse_meta(_read(prev).decode("utf-8"), prev)
        )
        rep["warnings"].append(f"{primary} — last good snapshot (.prev) usable")
        return state
    except (OSError, CorruptMetaError, UnicodeDecodeError) as exc:
        rep["errors"].append(f"{primary}; fallback unusable: {exc}")
        return None


def _check(root: str, rep: dict) -> None:
    state = _load_meta(root, rep)
    if state is None:
        return  # nothing else is trustworthy without a catalog

    records, _max_tx, torn, corrupt = read_journal(
        os.path.join(root, "journal.jsonl")
    )
    if corrupt is not None:
        rep["errors"].append(f"journal body corrupt: {corrupt}")
    elif torn is not None:
        rep["warnings"].append(
            f"torn journal tail at byte {torn} (truncated at next open)"
        )
    pending = {
        int(r.get("tx", 0)) for r in records if r.get("op") != "commit"
    } - {int(r.get("tx", 0)) for r in records if r.get("op") == "commit"}
    if pending:
        rep["warnings"].append(
            f"{len(pending)} pending transaction(s) (replayed at next open)"
        )

    # Index files: frame CRC + deserialization.
    indexes: dict[int, HNSWIndex] = {}
    bad_dims: set[int] = set()
    index_dir = os.path.join(root, "index")
    for fname in sorted(os.listdir(index_dir)) if os.path.isdir(index_dir) else []:
        if not (fname.startswith("hnsw_") and fname.endswith(".idx")):
            continue
        dim = int(fname[len("hnsw_"):-len(".idx")])
        path = os.path.join(index_dir, fname)
        try:
            indexes[dim] = HNSWIndex.from_bytes(unframe_index(_read(path), path))
        except Exception as exc:
            rep["errors"].append(f"index {fname} corrupt: {exc}")
            bad_dims.add(dim)

    # Model pages: framing + per-record CRCs; derive refs from healthy ones.
    derived: dict[str, int] = {}
    referenced_pages: set[str] = set()
    for name, entry in sorted(state.models.items()):
        referenced_pages.add(entry.page)
        if entry.status == STATUS_CORRUPT:
            rep["warnings"].append(f"model {name!r} is quarantined")
            continue
        if entry.status != STATUS_COMMITTED:
            rep["warnings"].append(
                f"model {name!r} has status {entry.status!r} "
                "(rolled back at next open)"
            )
            continue
        path = os.path.join(root, "pages", entry.page)
        try:
            page = verify_page(_read(path))
        except FileNotFoundError:
            rep["errors"].append(f"model {name!r}: page {entry.page} missing")
            continue
        except CorruptPageError as exc:
            rep["errors"].append(
                f"model {name!r}: page {entry.page} corrupt: {exc}"
            )
            continue
        dims = page_dim_keys(page)
        broken = sorted(dims & bad_dims)
        if broken:
            rep["errors"].append(
                f"model {name!r} references corrupt index dim(s) {broken}"
            )
        for i in range(page.n_records):
            r = read_record(page, i, with_payload=False)
            key = f"{r.dim_key}:{r.vertex_id}"
            derived[key] = derived.get(key, 0) + 1
            idx = indexes.get(r.dim_key)
            if r.dim_key in bad_dims:
                continue
            if idx is None:
                rep["errors"].append(
                    f"model {name!r} references dim {r.dim_key} "
                    "but no index file exists"
                )
            elif not (0 <= r.vertex_id < len(idx)) or idx.is_deleted(r.vertex_id):
                rep["errors"].append(
                    f"model {name!r}: dangling base reference "
                    f"{r.dim_key}:{r.vertex_id}"
                )

    # Reference table vs derived. Quarantined models and pending
    # transactions legitimately leave the table a superset (their records
    # are uncounted above); missing references are always an error.
    loose = bool(state.models) and (
        any(e.status != STATUS_COMMITTED for e in state.models.values())
        or bool(pending)
    )
    for key, count in sorted(derived.items()):
        have = int(state.vertex_refs.get(key, 0))
        if have < count:
            rep["errors"].append(
                f"vertex_refs[{key}] = {have} < {count} live references"
            )
    extra = {
        k: int(v) for k, v in state.vertex_refs.items()
        if int(v) > derived.get(k, 0)
    }
    if extra:
        msg = f"{len(extra)} leaked vertex reference(s) (e.g. {next(iter(sorted(extra)))})"
        if loose:
            rep["warnings"].append(msg + " — expected with pending/quarantined state")
        else:
            rep["warnings"].append(msg + " — rebuild with --repair --drop-corrupt")

    # Orphan files (the engine sweeps these at open).
    pages_dir = os.path.join(root, "pages")
    for fname in sorted(os.listdir(pages_dir)) if os.path.isdir(pages_dir) else []:
        if fname not in referenced_pages:
            rep["warnings"].append(
                f"orphan page file {fname} (swept at next open)"
            )


def _repair(root: str, rep: dict, drop_corrupt: bool) -> None:
    actions = rep["actions"]
    meta = os.path.join(root, "meta.json")
    prev = meta + ".prev"

    def _meta_ok(path: str) -> bool:
        try:
            parse_meta(_read(path).decode("utf-8"), path)
            return True
        except (OSError, CorruptMetaError, UnicodeDecodeError):
            return False

    if not _meta_ok(meta):
        if not _meta_ok(prev):
            return  # unrecoverable — leave every byte for forensics
        if os.path.exists(meta):
            os.replace(meta, meta + ".corrupt")
            actions.append("kept damaged snapshot as meta.json.corrupt")
        with open(meta, "wb") as f:
            f.write(_read(prev))
            f.flush()
            os.fsync(f.fileno())
        actions.append("promoted meta.json.prev over corrupt meta.json")

    journal = os.path.join(root, "journal.jsonl")
    _records, _max_tx, _torn, corrupt = read_journal(journal)
    if corrupt is not None:
        os.replace(journal, journal + ".corrupt")
        actions.append("set aside body-corrupt journal as journal.jsonl.corrupt")

    # Opening the engine replays pending transactions, truncates a torn
    # journal tail, and sweeps orphan files.
    eng = StorageEngine(root)
    try:
        verdict = eng.verify_store(quarantine=True)
        if verdict["quarantined"]:
            actions.append(
                f"quarantined corrupt model(s): {sorted(verdict['quarantined'])}"
            )
        if drop_corrupt:
            dropped = eng.drop_corrupt_models()
            if dropped:
                actions.append(f"dropped corrupt model(s): {sorted(dropped)}")
            for dim, status in verdict["indexes"].items():
                if not str(status).startswith("corrupt"):
                    continue
                path = eng.index_cache._path(dim)
                if os.path.exists(path):
                    os.unlink(path)
                    actions.append(f"removed corrupt index hnsw_{dim}.idx")
            eng.rebuild_vertex_refs()
            actions.append("rebuilt vertex reference table from pages")
    finally:
        eng.close()


def _check_accounting(root: str, rep: dict, engine=None) -> None:
    """Cross-check the incremental :class:`SpaceAccountant` against the
    full-rescan verifier; every drift line is a check *failure*.

    With ``engine`` given (a live ``StorageEngine``), its in-memory
    ledger — maintained incrementally at every commit point — is diffed
    against a fresh page rescan. Without one, a temporary engine is
    opened (whose open-time seed IS the rescan, so this degenerates to
    verifying the rescan is internally reproducible, e.g. that no page
    mutates between two reads).
    """
    own = engine is None
    if own:
        engine = StorageEngine(root)
    try:
        rep["errors"].extend(engine.accounting_drift())
    finally:
        if own:
            engine.close()


def fsck(root: str, repair: bool = False, drop_corrupt: bool = False,
         accounting: bool = False) -> dict:
    """Check (and optionally repair) the store at ``root``.

    Returns ``{"root", "errors", "warnings", "actions", "clean"}`` —
    ``clean`` means no errors (warnings allowed). With ``repair=True``
    the report reflects a fresh re-check *after* the repair actions.
    ``accounting=True`` additionally diffs the incremental space
    accountant against a full page rescan (drift = error).
    """
    rep: dict = {"root": root, "errors": [], "warnings": [], "actions": []}
    _check(root, rep)
    if repair:
        _repair(root, rep, drop_corrupt)
        rep["errors"], rep["warnings"] = [], []
        _check(root, rep)
    if accounting:
        _check_accounting(root, rep)
    rep["clean"] = not rep["errors"]
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="fsck.py", description="Check / repair a NeurStore store"
    )
    ap.add_argument("root", help="store directory (contains meta.json)")
    ap.add_argument("--repair", action="store_true",
                    help="repair what is safely repairable")
    ap.add_argument("--drop-corrupt", action="store_true",
                    help="with --repair: delete quarantined models and "
                         "rebuild the reference table")
    ap.add_argument("--accounting", action="store_true",
                    help="cross-check the incremental space accountant "
                         "against a full page rescan (drift = failure)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the report as JSON")
    args = ap.parse_args(argv)
    rep = fsck(args.root, repair=args.repair, drop_corrupt=args.drop_corrupt,
               accounting=args.accounting)
    if args.as_json:
        print(json.dumps(rep, indent=2))
    else:
        for kind in ("errors", "warnings", "actions"):
            for line in rep[kind]:
                print(f"{kind[:-1]}: {line}")
        print("clean" if rep["clean"] else "NOT clean")
    return 0 if rep["clean"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
