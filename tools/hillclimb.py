"""§Perf hillclimb driver: re-measure the three chosen cells with the
optimization under test and emit before/after JSON.

  PYTHONPATH=src python tools/hillclimb.py --out hillclimb.json
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell  # noqa: E402

CELLS = [
    # (label, arch, shape, kwargs)
    ("arctic_train/grouped_moe", "arctic-480b", "train_4k", {}),
    ("granite_train/grouped_moe", "granite-moe-3b-a800m", "train_4k", {}),
    ("qwen3_train/dp_zero", "qwen3-8b", "train_4k", {"profile": "dp"}),
    ("internlm2_train/dp_zero", "internlm2-1.8b", "train_4k", {"profile": "dp"}),
    ("deepseek_decode/compressed", "deepseek-67b", "decode_32k",
     {"compressed": True}),
    ("qwen3_decode/compressed", "qwen3-8b", "decode_32k",
     {"compressed": True}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="hillclimb.json")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    results = []
    for label, arch, shape, kw in CELLS:
        if args.only and args.only not in label:
            continue
        print(f"\n### {label}: {arch} × {shape} {kw}")
        try:
            rec = run_cell(arch, shape, multi_pod=False, **kw)
            rec["label"] = label
        except Exception as e:
            rec = {"label": label, "error": repr(e)[:500]}
            print(f"!! {label} failed: {e!r}")
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
