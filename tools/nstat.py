#!/usr/bin/env python
"""``nstat`` — one-shot or watch-mode dashboard over NeurStore metrics.

Two sources, one output:

* ``--url http://host:port`` scrapes a running server's ``/v1/metrics``
  (Prometheus text) over stdlib ``urllib``.
* ``PATH`` opens the store embedded (read-only open of the engine is not
  needed — metrics are process-wide, so opening the store and issuing a
  ``stats()`` call is enough to populate gauges) and renders the
  in-process registry. This mode is for debugging a store *in this
  process*; to observe a live server, scrape it.

Output groups the ``neurstore_*`` families by subsystem (engine / pool /
hnsw / maintenance / server) and prints ``name{labels} value`` lines,
plus histogram summaries as ``count`` / ``mean``. ``--watch N`` clears
and re-renders every N seconds, adding per-interval rates for counters.
``--traces`` additionally dumps the recent-trace ring (embedded mode
only — the ring is per-process).

``--space`` switches to the du-style space-accounting view (logical vs
physical bytes, base/delta/metadata split, compression ratio — see
``docs/observability.md``): embedded mode asks the engine's
``SpaceAccountant``, ``--url`` mode fetches ``GET /v1/accounting``.

Examples::

    PYTHONPATH=src python tools/nstat.py --url http://127.0.0.1:8080
    PYTHONPATH=src python tools/nstat.py --url http://127.0.0.1:8080 --watch 2
    PYTHONPATH=src python tools/nstat.py /path/to/store --traces
    PYTHONPATH=src python tools/nstat.py /path/to/store --space
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import urllib.request

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
if _SRC not in sys.path:  # runnable as a script from a checkout
    sys.path.insert(0, _SRC)

from repro.obs.metrics import parse_prometheus_text  # noqa: E402

_GROUPS = ("engine", "pool", "hnsw", "maintenance", "server", "slow")


def _fetch_text(url: str) -> str:
    with urllib.request.urlopen(url.rstrip("/") + "/v1/metrics",
                                timeout=10) as resp:
        ctype = resp.headers.get("Content-Type", "")
        if "text/plain" not in ctype:
            raise SystemExit(f"unexpected Content-Type {ctype!r} from {url}")
        return resp.read().decode("utf-8")


def _embedded_text(path: str) -> str:
    from repro.store import NeurStore
    with NeurStore.open(path) as store:
        store.stats()  # touch the engine so attached gauges have owners
        return store.metrics_text()


def _group_of(family: str) -> str:
    for g in _GROUPS:
        if family.startswith(f"neurstore_{g}_"):
            return g
    if family.startswith("neurstore_slow_ops"):
        return "slow"
    return "other"


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def _sample_key(sample: dict) -> tuple:
    return (sample["name"], tuple(sorted(sample["labels"].items())))


def _render(families: dict, prev: dict | None, interval_s: float) -> str:
    """Human-oriented rendering; histogram families collapse to
    count/mean, counters show a per-second rate when ``prev`` given."""
    by_group: dict[str, list[str]] = {}
    for fam_name in sorted(families):
        fam = families[fam_name]
        group = _group_of(fam_name)
        lines = by_group.setdefault(group, [])
        if fam["type"] == "histogram":
            sums: dict[tuple, float] = {}
            counts: dict[tuple, tuple] = {}
            for s in fam["samples"]:
                labels = tuple(sorted(s["labels"].items()))
                if s["name"].endswith("_sum"):
                    sums[labels] = s["value"]
                elif s["name"].endswith("_count"):
                    counts[labels] = s["value"]
            for labels in sorted(counts):
                n = counts[labels]
                mean = (sums.get(labels, 0.0) / n) if n else 0.0
                lines.append(
                    f"  {fam_name}{_fmt_labels(dict(labels))}"
                    f"  count={n:.0f}  mean={mean * 1e3:.3f}ms")
            continue
        prev_values = {}
        if prev is not None and fam_name in prev:
            prev_values = {_sample_key(s): s["value"]
                           for s in prev[fam_name]["samples"]}
        for s in sorted(fam["samples"], key=_sample_key):
            value = s["value"]
            rate = ""
            if prev is not None and fam["type"] == "counter":
                before = prev_values.get(_sample_key(s), 0.0)
                rate = f"  ({(value - before) / interval_s:+.1f}/s)"
            val = f"{value:.0f}" if value == int(value) else f"{value:.3f}"
            lines.append(
                f"  {s['name']}{_fmt_labels(s['labels'])} = {val}{rate}")
    out = []
    for group in (*_GROUPS, "other"):
        if group in by_group:
            out.append(f"[{group}]")
            out.extend(by_group[group])
    return "\n".join(out)


def _fetch_accounting(url: str) -> dict:
    import json
    with urllib.request.urlopen(url.rstrip("/") + "/v1/accounting",
                                timeout=10) as resp:
        return json.loads(resp.read().decode("utf-8"))


def _embedded_accounting(path: str) -> dict:
    from repro.store import NeurStore
    with NeurStore.open(path) as store:
        return store.accounting()


def _human(n) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n:.0f}B"
        n /= 1024
    return f"{n:.1f}GiB"


def _ratio_txt(r) -> str:
    return f"{r:.3f}" if r is not None else "-"


def _render_space(report: dict) -> str:
    """du-style rendering of the accounting report."""
    out = []
    s = report["store"]
    if not s["models"]:
        return "[store]  0 models (empty)"
    out.append(
        f"[store]  models={s['models']}  logical={_human(s['logical_bytes'])}"
        f"  physical={_human(s['physical_bytes'])}"
        f"  ratio={_ratio_txt(s.get('compression_ratio'))}")
    out.append(
        f"         pages={_human(s['page_bytes'])}"
        f" (delta {_human(s['delta_bytes'])}"
        f" + metadata {_human(s['metadata_bytes'])})"
        f"  shared base={_human(s['base_bytes'])}")
    per_model = report.get("per_model", {})
    if per_model:
        out.append("[per model]   physical  logical   ratio  reclaim  name")
        ordered = sorted(per_model.items(),
                         key=lambda kv: -kv[1]["physical_bytes"])
        for name, m in ordered:
            out.append(
                f"  {_human(m['physical_bytes']):>9}"
                f"  {_human(m['logical_bytes']):>8}"
                f"  {_ratio_txt(m.get('compression_ratio')):>6}"
                f"  {_human(m['reclaimable_bytes']):>7}  {name}")
    per_dim = report.get("per_dim", {})
    if per_dim:
        out.append("[per dim-group]  tensors  bases  base bytes  delta bytes")
        for dim, d in per_dim.items():
            out.append(
                f"  dim {dim:>10}  {d['tensors']:>7}  {d['base_vertices']:>5}"
                f"  {_human(d['base_bytes']):>10}"
                f"  {_human(d['delta_bytes']):>11}")
    per_tenant = report.get("per_tenant", {})
    if per_tenant:
        out.append("[per tenant]  models  physical  logical  ratio")
        for tenant, t in sorted(per_tenant.items()):
            out.append(
                f"  {tenant:<12}  {t['models']:>5}"
                f"  {_human(t['physical_bytes']):>8}"
                f"  {_human(t['logical_bytes']):>8}"
                f"  {_ratio_txt(t.get('compression_ratio'))}")
    return "\n".join(out)


def _dump_traces(n: int) -> str:
    from repro.obs.trace import recent_traces
    roots = recent_traces(n)
    if not roots:
        return "(no completed traces in this process)"
    return "\n".join(root.format_tree() for root in roots)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("path", nargs="?", help="store directory (embedded mode)")
    ap.add_argument("--url", help="scrape a running server's /v1/metrics")
    ap.add_argument("--watch", type=float, metavar="SECONDS",
                    help="refresh every N seconds until interrupted")
    ap.add_argument("--traces", type=int, nargs="?", const=8, metavar="N",
                    help="also dump the last N recent traces (embedded only)")
    ap.add_argument("--raw", action="store_true",
                    help="print the Prometheus text verbatim and exit")
    ap.add_argument("--space", action="store_true",
                    help="du-style space accounting view (logical vs "
                         "physical bytes, per model/dim/tenant)")
    args = ap.parse_args(argv)
    if bool(args.path) == bool(args.url):
        ap.error("give exactly one of PATH (embedded) or --url (scrape)")

    if args.space:
        report = (_fetch_accounting(args.url) if args.url
                  else _embedded_accounting(args.path))
        print(_render_space(report))
        return 0

    def snapshot() -> str:
        return _fetch_text(args.url) if args.url else _embedded_text(args.path)

    if args.raw:
        sys.stdout.write(snapshot())
        return 0

    prev = None
    while True:
        text = snapshot()
        families = parse_prometheus_text(text)
        if args.watch:
            sys.stdout.write("\x1b[2J\x1b[H")  # clear + home
        stamp = time.strftime("%H:%M:%S")
        print(f"nstat @ {stamp} — {len(families)} families")
        print(_render(families, prev, args.watch or 1.0))
        if args.traces is not None:
            print("\n[recent traces]")
            print(_dump_traces(args.traces))
        if not args.watch:
            return 0
        prev = families
        try:
            time.sleep(args.watch)
        except KeyboardInterrupt:
            return 0


if __name__ == "__main__":
    raise SystemExit(main())
