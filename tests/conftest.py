"""Shared test configuration: hypothesis profiles.

The ``ci`` profile (selected via ``HYPOTHESIS_PROFILE=ci`` in the GitHub
workflow) runs many more examples with no deadline — CI machines are slow
and shared, so wall-clock deadlines flake, while the extra examples are
exactly what an unattended run is for. Per-test ``@settings`` fields still
take precedence where they are explicitly set; the profile fills the rest.
"""

import os

try:
    from hypothesis import settings
except ImportError:  # hypothesis is optional locally; tests importorskip it
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=300, deadline=None)
    settings.register_profile("dev", max_examples=25, deadline=None)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)
