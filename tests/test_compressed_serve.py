"""Compressed-weight serving (storage format as runtime format)."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.launch.compressed_serve as cs
from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import init_cache, init_params

KEY = jax.random.PRNGKey(0)


def test_quantize_roundtrip_error_bounded(monkeypatch):
    monkeypatch.setattr(cs, "MIN_QUANT_SIZE", 1024)
    cfg = get_config("qwen3-8b", smoke=True)
    params = init_params(cfg, KEY)
    qparams = cs.quantize_params(params)
    is_q = lambda x: isinstance(x, dict) and ("raw" in x or "base" in x)
    recon = jax.tree.map(lambda q: cs.dequantize_leaf_jnp(q, jnp.float32),
                         qparams, is_leaf=is_q)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(recon)):
        err = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
        # int8 base + int4 delta: error dominated by the 4-bit delta bins.
        assert err < 2e-3, (pa, err)


def test_compressed_greedy_decode_agrees(monkeypatch):
    monkeypatch.setattr(cs, "MIN_QUANT_SIZE", 1024)
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = init_params(cfg, KEY)
    qparams = cs.quantize_params(params)
    cache = init_cache(cfg, 2, 32)
    cache2 = init_cache(cfg, 2, 32)
    toks = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    agree = 0
    for t in range(8):
        t1, cache = make_serve_step(cfg)(params, cache, toks, jnp.int32(t))
        t2, cache2 = cs.make_compressed_serve_step(cfg)(
            qparams, cache2, toks, jnp.int32(t))
        agree += int((np.asarray(t1) == np.asarray(t2)).all())
    assert agree >= 7  # ≥7/8 steps identical under 4-bit flexible loading


def test_compressed_specs_match_quantized_tree(monkeypatch):
    monkeypatch.setattr(cs, "MIN_QUANT_SIZE", 1024)
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = init_params(cfg, KEY)
    qparams = cs.quantize_params(params)
    specs = cs.compressed_param_specs(cfg)
    # Structures line up leaf-for-leaf (so dry-run shardings apply 1:1).
    ga = jax.tree_util.tree_structure(
        jax.tree.map(lambda x: 0, qparams))
    gb = jax.tree_util.tree_structure(
        jax.tree.map(lambda x: 0, specs))
    assert ga == gb
