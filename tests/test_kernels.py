"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels TARGET TPU; on this CPU container they execute in interpret mode
(kernel body run in Python), which validates the block decomposition,
accumulator logic and dequant math exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _mk_quant(k, n):
    base = RNG.integers(0, 256, (k, n)).astype(np.int8)
    delta = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    return base, delta


def _assert_close(got, want):
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5 * scale
    )


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 128, 128),      # decode row
        (8, 256, 128),
        (64, 256, 192),     # non-multiple N → padding path
        (128, 128, 128),    # exactly one block
        (130, 384, 250),    # ragged everything
    ],
)
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_shapes(m, k, n, xdtype):
    x = jnp.asarray(RNG.normal(0, 1, (m, k)), dtype=xdtype)
    base, delta = _mk_quant(k, n)
    bs, bz, ds, dz = 0.013, 117.0, 3.1e-4, 64.0
    want = ref.dequant_matmul_ref(x, jnp.asarray(base), bs, bz, jnp.asarray(delta), ds, dz)
    got = ops.dequant_matmul(x, jnp.asarray(base), bs, bz, jnp.asarray(delta), ds, dz)
    _assert_close(got, want)


@pytest.mark.parametrize("m,k,n", [(1, 128, 128), (16, 256, 256), (64, 384, 200)])
def test_dequant_matmul_int4(m, k, n):
    x = jnp.asarray(RNG.normal(0, 1, (m, k)), dtype=jnp.float32)
    base = RNG.integers(0, 256, (k, n)).astype(np.int8)
    d4 = RNG.integers(0, 16, (k, n)).astype(np.uint8)
    packed = ops.pack_int4(d4)
    bs, bz, ds, dz = 0.02, 128.0, 5e-4, 8.0
    want = ref.dequant_matmul_int4_ref(
        x, jnp.asarray(base), bs, bz, jnp.asarray(packed), ds, dz)
    got = ops.dequant_matmul_int4(
        x, jnp.asarray(base), bs, bz, jnp.asarray(packed), ds, dz)
    _assert_close(got, want)
    # And the unpack itself is exact.
    assert (np.asarray(ref.unpack_int4_ref(jnp.asarray(packed))) == d4).all()


def test_dequant_matmul_matches_materialized_weight():
    """Fused kernel == materialize-then-matmul (the non-fused paper path)."""
    m, k, n = 32, 256, 128
    x = jnp.asarray(RNG.normal(0, 1, (m, k)), dtype=jnp.float32)
    base, delta = _mk_quant(k, n)
    bs, bz, ds, dz = 0.01, 100.0, 1e-4, 50.0
    w = ref.dequantize_weight_ref(jnp.asarray(base), bs, bz, jnp.asarray(delta), ds, dz)
    want = x @ w
    got = ops.dequant_matmul(x, jnp.asarray(base), bs, bz, jnp.asarray(delta), ds, dz)
    _assert_close(got, want)


@pytest.mark.parametrize(
    "n,d",
    [(1, 128), (7, 300), (128, 512), (200, 1000), (130, 4096)],
)
def test_quantized_l2_shapes(n, d):
    q = RNG.normal(0, 1, d).astype(np.float32)
    codes = RNG.integers(0, 256, (n, d)).astype(np.uint8)
    scales = RNG.uniform(1e-3, 2e-2, n).astype(np.float32)
    if n > 3:
        scales[3] = 0.0  # constant-row path
    zps = RNG.integers(0, 256, n).astype(np.float32)
    mids = RNG.normal(0, 0.5, n).astype(np.float32)
    want = ref.quantized_l2_ref(
        jnp.asarray(q), jnp.asarray(codes), jnp.asarray(scales),
        jnp.asarray(zps), jnp.asarray(mids))
    got = ops.quantized_l2(q, codes, scales, zps, mids)
    _assert_close(got, want)


def test_quantized_l2_matches_host_hnsw_distance():
    """Kernel == the numpy hot loop actually used by the host HNSW."""
    from repro.core.hnsw import quantized_l2_batch

    n, d = 64, 777
    q = RNG.normal(0, 1, d)
    codes = RNG.integers(0, 256, (n, d)).astype(np.uint8)
    scales = RNG.uniform(1e-3, 2e-2, n)
    zps = RNG.integers(0, 256, n).astype(np.int64)
    mids = np.zeros(n)
    want = quantized_l2_batch(q, codes, scales, zps, mids)
    got = ops.quantized_l2(
        q.astype(np.float32), codes, scales.astype(np.float32),
        zps.astype(np.float32), mids.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3)


@pytest.mark.parametrize("block_k", [128, 256])
def test_dequant_matmul_block_sweep(block_k):
    m, k, n = 64, 512, 256
    x = jnp.asarray(RNG.normal(0, 1, (m, k)), dtype=jnp.float32)
    base, delta = _mk_quant(k, n)
    bs, bz, ds, dz = 0.01, 100.0, 1e-4, 50.0
    want = ref.dequant_matmul_ref(x, jnp.asarray(base), bs, bz, jnp.asarray(delta), ds, dz)
    got = ops.dequant_matmul(
        x, jnp.asarray(base), bs, bz, jnp.asarray(delta), ds, dz, block_k=block_k)
    _assert_close(got, want)


@pytest.mark.parametrize(
    "b,sq,sk,h,kv,dh,causal,window",
    [
        (2, 256, 256, 8, 4, 64, True, 0),
        (1, 256, 256, 4, 1, 128, True, 64),   # MQA + recurrentgemma window
        (2, 128, 128, 8, 8, 64, False, 0),    # bidirectional (hubert)
        (1, 200, 256, 8, 2, 64, True, 0),     # ragged Sq → padding path
        (1, 384, 384, 16, 16, 80, False, 0),  # hubert dims (dh=80)
    ],
)
def test_flash_attention_vs_ref(b, sq, sk, h, kv, dh, causal, window):
    q = jnp.asarray(RNG.normal(0, 1, (b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, sk, kv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, sk, kv, dh)), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    got = ops.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2e-5)


def test_flash_attention_matches_model_chunked_attention():
    """Kernel == the pure-JAX chunked attention used by the model stack."""
    from repro.models.layers import chunked_attention

    b, s, h, kv, dh = 2, 256, 8, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, dh)), jnp.float32)
    want = chunked_attention(q, k, v, causal=True, chunk=64)
    got = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize(
    "b,sq,sk,h,kv,dh",
    [
        (1, 37, 37, 4, 2, 64),     # non-aligned bidirectional (the old
        (2, 50, 100, 8, 4, 32),    # ValueError path: sk % block_k != 0)
        (1, 100, 50, 4, 4, 64),    # q longer than k
    ],
)
def test_flash_attention_non_causal_padded_keys(b, sq, sk, h, kv, dh):
    """Non-causal attention at non-block-multiple Sk: padded key positions
    must be masked out by the kernel's sk_true bias, not win the softmax
    (regression for the former ValueError/garbage at unaligned lengths)."""
    q = jnp.asarray(RNG.normal(0, 1, (b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, sk, kv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, sk, kv, dh)), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=False, window=0)
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2e-5)


def _scalar_reconstruct(base_i8, bs, bz, packed, ds, dz):
    """Element-wise host reconstruction of dq(base)+dq(delta) — the slow
    obviously-correct oracle for the fused paths (bin-centre delta)."""
    k, n = base_i8.shape
    w = np.empty((k, n), np.float64)
    for i in range(k):
        byte_row = packed[i // 2]
        for j in range(n):
            nib = (byte_row[j] >> 4) if i % 2 else (byte_row[j] & 0xF)
            w[i, j] = ((float(base_i8[i, j]) - bz) * bs
                       + (float(nib) - dz + 0.5) * ds)
    return w


@pytest.mark.parametrize("k,n,m", [(130, 70, 1), (2, 3, 1), (64, 130, 5)])
def test_dequant_matmul_auto_parity(k, n, m):
    """Interpret-mode kernel == decomposed numpy == in-graph reconstruct ==
    scalar host oracle, on odd shapes including K=2 and decode rows (M=1)."""
    from repro.launch.compressed_serve import dequantize_leaf_jnp, quantize_leaf

    arr = RNG.normal(0, 0.5, (k, n)).astype(np.float32)
    q = quantize_leaf(arr)
    x = RNG.normal(0, 1, (m, k)).astype(np.float32)

    w_scalar = _scalar_reconstruct(q["base"], float(q["bs"]), float(q["bz"]),
                                   q["packed"], float(q["ds"]), float(q["dz"]))
    w_jnp = np.asarray(
        dequantize_leaf_jnp(q, dtype=jnp.float32)).reshape(k, n)
    np.testing.assert_allclose(w_jnp, w_scalar, rtol=1e-5, atol=1e-5)

    want = x.astype(np.float64) @ w_scalar
    for force in ("kernel", "numpy"):
        got = ops.dequant_matmul_auto(
            x, q["base"].reshape(k, n), float(q["bs"]), float(q["bz"]),
            q["packed"], float(q["ds"]), float(q["dz"]),
            packed=True, force=force)
        scale = float(np.abs(want).max()) + 1e-6
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale,
                                   err_msg=f"force={force}")


def test_dequant_matmul_auto_int8_paths_agree():
    """force=kernel (interpret Pallas) and force=numpy (decomposed gemm)
    agree on the unpacked int8 delta layout, with and without scratch."""
    k, n, m = 96, 200, 3
    base = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    delta = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    x = RNG.normal(0, 1, (m, k)).astype(np.float32)
    args = (x, base, 0.013, -11.0, delta, 3.1e-4, -64.0)
    yk = ops.dequant_matmul_auto(*args, force="kernel")
    scratch: dict = {}
    yn = ops.dequant_matmul_auto(*args, force="numpy", scratch=scratch)
    yn2 = ops.dequant_matmul_auto(*args, force="numpy", scratch=scratch)
    assert "cpu" in scratch  # combined operand cached for the decode loop
    np.testing.assert_allclose(yk, yn, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(yn, yn2)


def test_dequant_matmul_auto_rejects_bad_force():
    with pytest.raises(ValueError):
        ops.dequant_matmul_auto(
            np.zeros((1, 2), np.float32), np.zeros((2, 2), np.int8),
            1.0, 0.0, np.zeros((2, 2), np.int8), 1.0, 0.0, force="tpu")
