"""Model lifecycle tests: transactional catalog, delete/replace, vertex GC,
HNSW compaction, and crash recovery (journal replay at every failpoint).

The parity bar everywhere: surviving models must ``materialize()``
**bit-identically** before vs. after any lifecycle operation, and a crash
between any two protocol steps must replay to a consistent catalog — no
orphan pages, no dangling ``vertex_refs``.
"""

import os
from collections import Counter

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core import catalog as catmod
from repro.core.catalog import STATUS_COMMITTED, InjectedCrash, ModelEntry
from repro.core.hnsw import HNSWIndex
from repro.core.hnsw_ref import quantized_l2_batch_dense
from repro.core.pages import read_page_header, read_record, remap_page_vertices

RNG = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _clear_failpoints():
    catmod.FAILPOINTS.clear()
    yield
    catmod.FAILPOINTS.clear()


def _tensors(scale=0.02, d=48, seed_shift=0.0):
    return {
        "layer0/w": (RNG.normal(0, scale, (d, d)) + seed_shift).astype(np.float32),
        "layer0/b": (RNG.normal(0, scale, (d,)) + seed_shift).astype(np.float32),
    }


def _distinct(d=48):
    """Tensors far from everything else — guaranteed new base vertices."""
    return {
        "layer0/w": RNG.normal(0, 5.0, (d, d)).astype(np.float32),
        "layer0/b": RNG.normal(0, 5.0, (d,)).astype(np.float32),
    }


def assert_consistent(eng: StorageEngine) -> None:
    """The catalog invariants recovery must restore after any crash."""
    # 1) No orphan pages: files on disk == pages of committed models.
    pages_dir = os.path.join(eng.root, "pages")
    on_disk = set(os.listdir(pages_dir))
    referenced = {eng.catalog.get(n).page for n in eng.list_models()}
    assert on_disk == referenced, f"orphan pages: {on_disk - referenced}"
    # 2) No dangling vertex_refs: the table equals the page-derived counts.
    derived: Counter = Counter()
    for name in eng.list_models():
        derived.update(eng._page_refs(eng.catalog.get(name).page))
    table = {
        tuple(map(int, k.split(":"))): v
        for k, v in eng.catalog.state.vertex_refs.items()
    }
    assert table == dict(derived)
    # 3) Every referenced vertex exists and is live in its index.
    for dim, vid in table:
        idx = eng.index_cache.get(dim)
        assert idx is not None and 0 <= vid < len(idx)
        assert not idx.is_deleted(vid)
    # 4) Every committed model fully materializes.
    for name in eng.list_models():
        eng.load_model(name).materialize()


# --------------------------------------------------------------- delete/replace
def test_delete_model_basics(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("keep", {}, _tensors())
    eng.save_model("gone", {}, _distinct())
    keep = eng.load_model("keep").materialize()
    before = eng.storage_bytes()

    eng.delete_model("gone")
    assert eng.list_models() == ["keep"]
    assert eng.storage_bytes()["pages"] < before["pages"]
    out = eng.load_model("keep").materialize()
    assert all(np.array_equal(out[k], keep[k]) for k in keep)
    assert_consistent(eng)
    with pytest.raises(KeyError):
        eng.delete_model("gone")
    with pytest.raises(KeyError):
        eng.load_model("gone")


def test_delete_shared_base_keeps_vertex_live(tmp_path):
    """Deleting a fine-tune must not tombstone bases other models share."""
    eng = StorageEngine(str(tmp_path))
    base = _tensors()
    eng.save_model("base", {}, base)
    ft = {k: v + RNG.normal(0, 3e-4, v.shape).astype(np.float32)
          for k, v in base.items()}
    r = eng.save_model("ft", {}, ft)
    assert r.n_new_bases == 0  # shares the base's vertices
    eng.delete_model("ft")
    rep = eng.vacuum()
    assert rep["vertices_dropped"] == 0  # still referenced by "base"
    assert_consistent(eng)


def test_replace_model(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {"v": 1}, _tensors())
    old_entry = eng.model_info("m")
    new = _distinct()
    eng.replace_model("m", {"v": 2}, new)
    entry = eng.model_info("m")
    assert entry.model_id != old_entry.model_id
    assert entry.architecture == {"v": 2}
    assert entry.status == STATUS_COMMITTED
    assert not os.path.exists(eng._page_file(old_entry.page))
    out = eng.load_model("m").materialize()
    assert all(np.abs(out[k] - new[k]).max() < 1e-5 for k in new)
    assert_consistent(eng)
    with pytest.raises(KeyError):
        eng.replace_model("nonexistent", {}, new)


def test_save_over_existing_name_is_replace(tmp_path):
    """Re-saving a name must not leak the old page or its vertex refs."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {}, _distinct())
    eng.save_model("m", {}, _distinct())
    assert eng.list_models() == ["m"]
    assert len(os.listdir(os.path.join(str(tmp_path), "pages"))) == 1
    assert_consistent(eng)


# --------------------------------------------------------------------- vacuum
def test_vacuum_reclaims_pages_and_index_bit_identical(tmp_path):
    """The acceptance bar: delete exclusive-base models, vacuum, and the
    total (pages AND index) shrinks while survivors are bit-identical."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("keep0", {}, _tensors())
    for i in range(3):
        eng.save_model(f"drop{i}", {}, _distinct())
    eng.save_model("keep1", {}, _distinct())
    before = eng.storage_bytes()
    survivors = {n: eng.load_model(n).materialize() for n in ("keep0", "keep1")}

    for i in range(3):
        eng.delete_model(f"drop{i}")
    mid = eng.storage_bytes()
    assert mid["pages"] < before["pages"]

    rep = eng.vacuum(min_dead_fraction=0.0)
    assert rep["vertices_dropped"] > 0
    after = eng.storage_bytes()
    assert after["index"] < mid["index"]
    assert after["total"] < before["total"]

    for name, snap in survivors.items():
        out = eng.load_model(name).materialize()
        for k in snap:
            assert np.array_equal(out[k], snap[k]), (name, k)
    assert_consistent(eng)

    # And across a restart (recovery is a no-op on a clean store).
    eng2 = StorageEngine(str(tmp_path))
    for name, snap in survivors.items():
        out = eng2.load_model(name).materialize()
        assert all(np.array_equal(out[k], snap[k]) for k in snap)


def test_vacuum_rewrites_pages_when_ids_shift(tmp_path):
    """Deleting the oldest model shifts survivor vertex ids down, so the
    survivor's page must be rewritten with the remap — and stay identical
    at the tensor level."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("old", {}, {"w": RNG.normal(0, 5.0, (64,)).astype(np.float32)})
    eng.save_model("young", {}, {"w": RNG.normal(0, 5.0, (64,)).astype(np.float32)})
    snap = eng.load_model("young").materialize()
    eng.delete_model("old")
    rep = eng.vacuum()
    assert rep["pages_rewritten"] == 1
    out = eng.load_model("young").materialize()
    assert all(np.array_equal(out[k], snap[k]) for k in snap)
    # The rewritten page's records now reference the compacted ids.
    page, _ = eng.open_page("young")
    for i in range(page.n_records):
        rec = read_record(page, i, with_payload=False)
        idx = eng.index_cache.get(rec.dim_key)
        assert 0 <= rec.vertex_id < len(idx)
    assert_consistent(eng)


def test_vacuum_threshold_skips_mostly_live_index(tmp_path):
    eng = StorageEngine(str(tmp_path))
    for i in range(4):
        eng.save_model(f"m{i}", {}, _distinct())
    eng.delete_model("m0")  # 1 of 4 bases dead per dim
    rep = eng.vacuum(min_dead_fraction=0.5)
    assert rep["vertices_dropped"] == 0  # 25% dead < 50% threshold
    rep = eng.vacuum(min_dead_fraction=0.2)
    assert rep["vertices_dropped"] > 0
    assert_consistent(eng)


def test_vacuum_all_models_deleted_empties_index(tmp_path):
    eng = StorageEngine(str(tmp_path))
    for i in range(2):
        eng.save_model(f"m{i}", {}, _distinct())
    for i in range(2):
        eng.delete_model(f"m{i}")
    eng.vacuum()
    s = eng.storage_bytes()
    assert s["pages"] == 0
    for dim in eng.index_cache.dims():
        assert len(eng.index_cache.get(dim)) == 0
    # An empty store still accepts new saves.
    eng.save_model("fresh", {}, _tensors())
    assert_consistent(eng)


# ----------------------------------------------------------- HNSW tombstones
def test_tombstoned_vertex_excluded_but_waypoint():
    dim = 32
    idx = HNSWIndex(dim, m=8, ef_construction=32, seed=0)
    pts = RNG.normal(0, 1, (30, dim))
    for p in pts:
        idx.insert(p)
    victim = 7
    hit = idx.search(pts[victim], k=1)
    assert hit[0][1] == victim
    idx.mark_deleted(victim)
    hit = idx.search(pts[victim], k=1)
    assert hit and hit[0][1] != victim  # excluded, but results still flow
    # Un-deleted queries are unaffected.
    assert idx.search(pts[3], k=1)[0][1] == 3
    # exclude_deleted=False still sees the tombstone (raw graph search).
    assert idx.search(pts[victim], k=1, exclude_deleted=False)[0][1] == victim


def test_all_deleted_search_returns_empty():
    idx = HNSWIndex(16, m=4, seed=0)
    for _ in range(5):
        idx.insert(RNG.normal(0, 1, 16))
    for v in range(5):
        idx.mark_deleted(v)
    assert idx.search(RNG.normal(0, 1, 16), k=3) == []
    assert idx.dead_count == 5 and idx.live_count == 0


def test_compact_parity_vs_dense_oracle():
    """After delete + compact, k=1 search must agree with the frozen dense
    oracle (`hnsw_ref.quantized_l2_batch_dense`) over the survivors, and
    surviving vertex payloads must dequantize bit-identically."""
    dim = 48
    idx = HNSWIndex(dim, m=8, ef_construction=48, seed=3)
    centers = RNG.normal(0, 1, (24, dim)) * 4.0  # well-separated
    for c in centers:
        idx.insert(c)
    doomed = set(range(0, 24, 3))
    before = {v: idx.dequantize_vertex(v) for v in range(24) if v not in doomed}
    for v in doomed:
        idx.mark_deleted(v)
    remap = idx.compact()
    assert set(remap) == set(before)
    assert len(idx) == 24 - len(doomed)
    # Bit-identical survivor payloads under the remapped ids.
    for old, new in remap.items():
        assert np.array_equal(idx.dequantize_vertex(new), before[old])
    # Graph search agrees with brute force over the compacted arrays.
    n = len(idx)
    for old in list(before)[:8]:
        q = centers[old]
        got = idx.search(q, k=1, ef=48)[0][1]
        dense = quantized_l2_batch_dense(
            np.asarray(q, dtype=np.float64),
            idx._codes[:n], idx._scales[:n], idx._zps[:n], idx._mids[:n],
        )
        assert got == int(np.argmin(dense))


def test_compact_serialization_roundtrip():
    idx = HNSWIndex(16, m=4, seed=1)
    for _ in range(12):
        idx.insert(RNG.normal(0, 1, 16))
    idx.mark_deleted(2)
    idx.mark_deleted(9)
    blob = idx.to_bytes()
    idx2 = HNSWIndex.from_bytes(blob)
    assert idx2.dead_count == 2 and idx2.is_deleted(2) and idx2.is_deleted(9)
    idx2.compact()
    assert idx2.dead_count == 0 and len(idx2) == 10
    q = RNG.normal(0, 1, 16)
    # Re-serializes cleanly after compaction.
    idx3 = HNSWIndex.from_bytes(idx2.to_bytes())
    assert idx2.search(q, k=3) == idx3.search(q, k=3)


def test_remap_page_vertices_patches_only_vid_field(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {}, _distinct())
    with open(eng._page_file(eng.model_info("m").page), "rb") as f:
        buf = f.read()
    page = read_page_header(buf)
    recs = [read_record(page, i) for i in range(page.n_records)]
    dims = {r.dim_key for r in recs}
    for dim in dims:
        shift = {r.vertex_id: r.vertex_id + 100 for r in recs if r.dim_key == dim}
        buf, changed = remap_page_vertices(buf, shift, dim)
        assert changed
    page2 = read_page_header(buf)
    for i, old in enumerate(recs):
        new = read_record(page2, i)
        assert new.vertex_id == old.vertex_id + 100
        assert new.name == old.name and new.shape == old.shape
        assert new.meta == old.meta
        assert np.array_equal(new.qdelta, old.qdelta)


def test_open_loader_survives_vacuum_remap(tmp_path):
    """A LoadedModel opened before vacuum must keep returning its own
    model's tensors after the index is compacted and ids renumbered."""
    eng = StorageEngine(str(tmp_path))
    mk = lambda: {"w": RNG.normal(0, 5.0, (64,)).astype(np.float32)}
    eng.save_model("a", {}, mk())
    eng.save_model("b", {}, mk())
    eng.save_model("c", {}, mk())
    expect = eng.load_model("b").materialize()
    lm = eng.load_model("b")  # held open across the vacuum
    eng.delete_model("a")     # b's and c's vertex ids shift down on compact
    rep = eng.vacuum()
    assert rep["vertices_dropped"] == 1
    out = lm.materialize()
    assert np.array_equal(out["w"], expect["w"])
    # compressed_params sees the remapped base too (lazy: index to build).
    cp = lm.compressed_params()
    for name in cp:
        assert cp[name]["shape"] == lm.tensor(name).shape


def test_loader_over_deleted_model_keeps_its_snapshot(tmp_path):
    """Snapshot isolation: a handle opened before delete+vacuum keeps
    materializing the deleted model's weights bit-identically from its
    pinned snapshot (old index object, old page bytes) — new loads fail.

    This replaces the pre-concurrency contract where vacuum poisoned the
    handle; see docs/concurrency.md.
    """
    eng = StorageEngine(str(tmp_path))
    w = RNG.normal(0, 5.0, (64,)).astype(np.float32)
    eng.save_model("gone", {}, {"w": w})
    expect = eng.load_model("gone").materialize()
    lm = eng.load_model("gone")
    eng.delete_model("gone")
    rep = eng.vacuum()
    assert rep["vertices_dropped"] == 1
    out = lm.materialize()
    assert np.array_equal(out["w"], expect["w"])
    cp = lm.compressed_params()  # the compressed view stays valid too
    assert cp["w"]["base_codes"].size == expect["w"].size
    with pytest.raises(KeyError):
        eng.load_model("gone")


def test_compact_bridges_dead_chains():
    """Live regions connected only through a chain of dead waypoints must
    stay connected: contraction collapses whole dead components."""
    idx = HNSWIndex(8, m=4, seed=0)
    pts = RNG.normal(0, 1, (4, 8))
    for p in pts:
        idx.insert(p)
    # Force the topology live(0) — dead(1) — dead(2) — live(3) on layer 0.
    idx._neighbors[0] = {
        0: np.array([1], dtype=np.int64),
        1: np.array([0, 2], dtype=np.int64),
        2: np.array([1, 3], dtype=np.int64),
        3: np.array([2], dtype=np.int64),
    }
    idx.mark_deleted(1)
    idx.mark_deleted(2)
    remap = idx.compact()
    assert remap == {0: 0, 3: 1}
    assert 1 in idx._neighbors[0][0].tolist()
    assert 0 in idx._neighbors[0][1].tolist()


# ------------------------------------------------------------- crash recovery
SAVE_POINTS = [
    "save.after_intent",
    "save.after_index_flush",
    "save.after_page_write",
    "save.after_snapshot",
]


@pytest.mark.parametrize("point", SAVE_POINTS)
def test_crash_during_save_replays_consistent(tmp_path, point):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("keep", {}, _tensors())
    keep = eng.load_model("keep").materialize()
    catmod.FAILPOINTS.add(point)
    with pytest.raises(InjectedCrash):
        eng.save_model("doomed", {}, _distinct())
    catmod.FAILPOINTS.clear()

    eng2 = StorageEngine(str(tmp_path))
    if point == "save.after_snapshot":
        # Crash after the atomic snapshot switch: the save committed.
        assert eng2.list_models() == ["keep", "doomed"]
    else:
        assert eng2.list_models() == ["keep"]
    assert_consistent(eng2)
    out = eng2.load_model("keep").materialize()
    assert all(np.array_equal(out[k], keep[k]) for k in keep)


@pytest.mark.parametrize(
    "point",
    ["delete.after_intent", "delete.after_snapshot", "delete.after_index_flush"],
)
def test_crash_during_delete_replays_consistent(tmp_path, point):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("keep", {}, _tensors())
    eng.save_model("gone", {}, _distinct())
    catmod.FAILPOINTS.add(point)
    with pytest.raises(InjectedCrash):
        eng.delete_model("gone")
    catmod.FAILPOINTS.clear()

    eng2 = StorageEngine(str(tmp_path))
    if point == "delete.after_intent":
        assert eng2.list_models() == ["keep", "gone"]  # rolled back whole
    else:
        assert eng2.list_models() == ["keep"]  # rolled forward
    assert_consistent(eng2)


VACUUM_POINTS = [
    "vacuum.after_intent",
    "vacuum.after_sidefiles",
    "vacuum.after_switch_log",
    "vacuum.mid_switch",
]


@pytest.mark.parametrize("point", VACUUM_POINTS)
def test_crash_mid_vacuum_replays_consistent(tmp_path, point):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("old", {}, _distinct())
    eng.save_model("young", {}, _distinct())
    snap = eng.load_model("young").materialize()
    eng.delete_model("old")
    catmod.FAILPOINTS.add(point)
    with pytest.raises(InjectedCrash):
        eng.vacuum()
    catmod.FAILPOINTS.clear()

    eng2 = StorageEngine(str(tmp_path))
    assert eng2.list_models() == ["young"]
    assert_consistent(eng2)
    out = eng2.load_model("young").materialize()
    assert all(np.array_equal(out[k], snap[k]) for k in snap)
    # A fresh vacuum on the recovered store completes and stays consistent.
    eng2.vacuum()
    assert_consistent(eng2)
    out = eng2.load_model("young").materialize()
    assert all(np.array_equal(out[k], snap[k]) for k in snap)


def test_failed_save_does_not_block_engine_in_process(tmp_path):
    """A save that dies mid-commit must release its in-flight refs: the
    same engine instance keeps saving and vacuuming, and the next open
    sweeps the orphan page."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("keep", {}, _distinct())
    catmod.FAILPOINTS.add("save.after_page_write")
    with pytest.raises(InjectedCrash):
        eng.save_model("doomed", {}, _distinct())
    catmod.FAILPOINTS.clear()
    assert not eng._inflight
    eng.save_model("more", {}, _distinct())
    rep = eng.vacuum()
    assert rep["skipped_dims"] == []
    eng2 = StorageEngine(str(tmp_path))
    assert sorted(eng2.list_models()) == ["keep", "more"]
    assert_consistent(eng2)


def test_vacuum_failure_in_process_quarantines_dim_and_survives_commits(tmp_path):
    """A vacuum that fails mid-switch without killing the process must (a)
    quarantine the half-switched dim so uses fail loudly, and (b) keep its
    journal records across later commits so reopen still replays it."""
    eng = StorageEngine(str(tmp_path))
    dim = 64
    eng.save_model("old", {}, {"w": RNG.normal(0, 5.0, (dim,)).astype(np.float32)})
    eng.save_model("young", {}, {"w": RNG.normal(0, 5.0, (dim,)).astype(np.float32)})
    snap = eng.load_model("young").materialize()
    eng.delete_model("old")
    catmod.FAILPOINTS.add("vacuum.mid_switch")
    with pytest.raises(InjectedCrash):
        eng.vacuum()
    catmod.FAILPOINTS.clear()

    # The dim is quarantined: saves and loads of it fail loudly.
    with pytest.raises(RuntimeError, match="half-applied vacuum"):
        eng.save_model("new", {}, {"w": RNG.normal(0, 5.0, (dim,)).astype(np.float32)})
    with pytest.raises(RuntimeError, match="half-applied vacuum"):
        eng.load_model("young").materialize()
    assert eng.vacuum()["skipped_dims"] == [dim]

    # A commit on an unrelated dim must NOT erase the vacuum's journal
    # records (selective truncation).
    eng.save_model("other", {}, {"w": RNG.normal(0, 5.0, (dim * 2,)).astype(np.float32)})

    eng2 = StorageEngine(str(tmp_path))  # replays the half-switched vacuum
    assert sorted(eng2.list_models()) == ["other", "young"]
    assert_consistent(eng2)
    out = eng2.load_model("young").materialize()
    assert np.array_equal(out["w"], snap["w"])


def test_replace_crash_rolls_back_new_version(tmp_path):
    eng = StorageEngine(str(tmp_path))
    old = _tensors()
    eng.save_model("m", {}, old)
    snap = eng.load_model("m").materialize()
    catmod.FAILPOINTS.add("save.after_page_write")
    with pytest.raises(InjectedCrash):
        eng.replace_model("m", {}, _distinct())
    catmod.FAILPOINTS.clear()
    eng2 = StorageEngine(str(tmp_path))
    assert eng2.list_models() == ["m"]
    out = eng2.load_model("m").materialize()
    assert all(np.array_equal(out[k], snap[k]) for k in snap)
    assert_consistent(eng2)


# ------------------------------------------------------------ catalog format
def test_catalog_loads_seed_format_meta(tmp_path):
    """Pre-catalog stores (no status fields, no journal) open unchanged."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {"a": 1}, _tensors())
    snap = eng.load_model("m").materialize()
    # Strip the new fields back to the seed's shape.
    import json

    meta_path = os.path.join(str(tmp_path), "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    for entry in meta["models"].values():
        entry.pop("status", None)
    meta.pop("integrity", None)  # seed snapshots carry no checksum stamp
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    os.unlink(os.path.join(str(tmp_path), "journal.jsonl"))

    eng2 = StorageEngine(str(tmp_path))
    entry = eng2.model_info("m")
    assert isinstance(entry, ModelEntry)
    assert entry.status == STATUS_COMMITTED
    out = eng2.load_model("m").materialize()
    assert all(np.array_equal(out[k], snap[k]) for k in snap)


# ------------------------------------------------------------------ satellites
def test_loader_double_materialize_regression(tmp_path):
    """Seed bug: the one-shot drain counter went negative on a second
    materialize() and re-dequantized shared bases on every access."""
    eng = StorageEngine(str(tmp_path), tau=10.0)
    t = RNG.normal(0, 0.02, (32, 32)).astype(np.float32)
    tensors = {"a": t, "b": t + 1e-5, "c": t - 1e-5}
    eng.save_model("m", {}, tensors)
    lm = eng.load_model("m")
    first = lm.materialize()
    assert not lm._deq_base  # drained → freed after the pass
    second = lm.materialize()
    for k in tensors:
        assert np.array_equal(first[k], second[k])
    assert not lm._deq_base
    assert all(v >= 0 for v in lm._remaining.values())
    # Repeated single-tensor access cycles the counter without going negative.
    for _ in range(7):
        lm.tensor("a")
    assert all(v >= 0 for v in lm._remaining.values())


def test_index_cache_trim_spills_sole_oversized_index(tmp_path):
    """Seed bug: one resident index larger than the whole budget was never
    evicted. trim() at commit boundaries spills it to disk."""
    eng = StorageEngine(str(tmp_path), cache_bytes=1)
    eng.save_model("m", {}, {"w": RNG.normal(0, 5.0, 256).astype(np.float32)})
    stats = eng.index_cache.stats()
    assert stats["resident"] == 0  # spilled at commit despite being the only one
    assert stats["evictions"] >= 1
    # The handle contract holds: the model loads from the on-disk index.
    out = eng.load_model("m").materialize()
    assert out["w"].shape == (256,)
