"""Library trainer + server integration: loss decreases, crash-restart
resumes exactly, the server generates from delta-compressed checkpoints."""

import numpy as np


from repro.launch.serve import ModelServer
from repro.launch.train import Trainer
from repro.models.config import ModelConfig

CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, attn_chunk=32,
    param_dtype="float32", compute_dtype="float32")


def test_trainer_loss_decreases(tmp_path):
    tr = Trainer(CFG, str(tmp_path), ckpt_every=10)
    rep = tr.fit(steps=20, batch=4, seq=32)
    assert not rep.resumed
    assert rep.final_loss < np.mean(rep.losses[:3])
    assert tr.storage_report()["n_checkpoints"] >= 2


def test_trainer_crash_restart_resumes(tmp_path):
    tr1 = Trainer(CFG, str(tmp_path), ckpt_every=10)
    tr1.fit(steps=10, batch=4, seq=32)
    # "Crash": new Trainer against the same store resumes from step 10.
    tr2 = Trainer(CFG, str(tmp_path), ckpt_every=10)
    rep = tr2.fit(steps=5, batch=4, seq=32)
    assert rep.resumed
    assert rep.start_step == 10
    assert rep.end_step == 15


def test_trainer_straggler_hook(tmp_path):
    import time as _time

    seen = []
    tr = Trainer(CFG, str(tmp_path), ckpt_every=100,
                 straggler_factor=1.5,
                 on_straggler=lambda s, dt, ewma: seen.append(s))
    orig = tr.step_fn
    calls = {"n": 0}

    def slow_step(*a):
        calls["n"] += 1
        if calls["n"] == 8:
            _time.sleep(1.0)  # synthetic straggler
        return orig(*a)

    tr.step_fn = slow_step
    rep = tr.fit(steps=10, batch=4, seq=32)
    assert rep.n_stragglers >= 1
    assert seen  # hook fired


def test_server_generates_from_checkpoints(tmp_path):
    tr = Trainer(CFG, str(tmp_path), ckpt_every=10)
    tr.fit(steps=10, batch=4, seq=32)
    srv = ModelServer(CFG, str(tmp_path), bits=8)
    step = srv.load()
    assert step == 10
    prompts = np.random.default_rng(0).integers(0, 512, (2, 4)).astype(np.int32)
    toks, stats = srv.generate(step, prompts, max_new_tokens=4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < 512).all()
    assert stats["tokens_per_s"] > 0
    # LRU: loading the same step again is a cache hit (no error, same id).
    assert srv.load(step) == step
