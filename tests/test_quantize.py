"""Property tests for the delta quantization core (paper Eq. 2/3)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.bitpack import (
    pack_bits,
    pack_bits_planar,
    unpack_bits,
    unpack_bits_planar,
)
from repro.core.quantize import (
    QuantMeta,
    delta_nbit,
    dequantize_delta,
    dequantize_linear,
    extract_msb,
    quantize_delta,
    quantize_linear,
)


@settings(max_examples=60, deadline=None)
@given(
    scale=st.floats(1e-6, 10.0),
    loc=st.floats(-5.0, 5.0),
    p_exp=st.integers(-24, -4),
    seed=st.integers(0, 2**31 - 1),
)
def test_delta_roundtrip_error_bounded(scale, loc, p_exp, seed):
    """|dq(q(d)) - d| <= p for any delta distribution — the paper's core claim."""
    p = 2.0 ** p_exp
    rng = np.random.default_rng(seed)
    d = rng.normal(loc, scale, 257)
    q, meta = quantize_delta(d, p)
    dq = dequantize_delta(q, meta)
    assert np.abs(dq - d).max() <= p * (1 + 1e-9)


@settings(max_examples=40, deadline=None)
@given(
    rng_width=st.floats(1e-7, 100.0),
    p_exp=st.integers(-24, -2),
)
def test_nbit_matches_eq2(rng_width, p_exp):
    p = 2.0 ** p_exp
    nbit = delta_nbit(0.0, rng_width, p)
    if rng_width <= 2 * p:
        assert nbit == 0
    else:
        import math
        assert nbit == min(max(1, math.ceil(math.log2(rng_width / (2 * p)))), 32)


def test_constant_delta_zero_bits():
    q, meta = quantize_delta(np.full(100, 0.123), p=1e-6)
    assert meta.nbit == 0
    assert np.allclose(dequantize_delta(q, meta), 0.123, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nbit=st.integers(2, 24), b=st.integers(1, 24))
def test_extract_msb_scale_adjust(seed, nbit, b):
    """Alg. 2 lines 6-8: truncation widens scale by 2^(nbit-b); error stays
    bounded by the widened bin (~scale') not the original."""
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 1 << nbit, 300)
    meta = QuantMeta(scale=1e-5, zero_point=int(1 << (nbit - 1)), nbit=nbit)
    qt, mt = extract_msb(q, meta, b)
    if nbit <= b:
        assert mt.nbit == nbit
        return
    assert mt.nbit == b
    assert mt.scale == pytest.approx(meta.scale * (1 << (nbit - b)))
    full = dequantize_delta(q, meta)
    trunc = dequantize_delta(qt, mt)
    assert np.abs(full - trunc).max() <= mt.scale * 1.5


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nbit=st.integers(1, 30), n=st.integers(1, 500))
def test_bitpack_roundtrip(seed, nbit, n):
    rng = np.random.default_rng(seed)
    v = rng.integers(0, 1 << nbit, n)
    assert (unpack_bits(pack_bits(v, nbit), nbit, n) == v).all()
    assert (unpack_bits_planar(pack_bits_planar(v, nbit), nbit, n) == v).all()


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nbit=st.integers(2, 24), b=st.integers(1, 24))
def test_planar_partial_read_equals_msb(seed, nbit, b):
    """Reading b bit-planes == extract_msb on fully-unpacked values."""
    rng = np.random.default_rng(seed)
    v = rng.integers(0, 1 << nbit, 257)
    data = pack_bits_planar(v, nbit)
    got = unpack_bits_planar(data, nbit, 257, b=min(b, nbit))
    want = v >> max(nbit - b, 0)
    assert (got == want).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nbit=st.sampled_from([4, 8]))
def test_linear_quant_roundtrip(seed, nbit):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, 1000)
    q, meta = quantize_linear(x, nbit=nbit)
    dq = dequantize_linear(q, meta)
    # Error bounded by half a bin.
    bin_w = (x.max() - x.min()) / (2**nbit - 1)
    assert np.abs(dq - x).max() <= bin_w / 2 + 1e-12
    assert q.min() >= 0 and q.max() <= 2**nbit - 1
