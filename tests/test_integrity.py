"""Storage-integrity tests: checksums, torn tails, degradation, fsck.

The contract under test (see ``docs/durability.md``):

- every on-disk artifact (tensor pages, HNSW index files, the JSONL
  journal, ``meta.json``) carries CRCs, verified at frame admission /
  replay / open — a damaged artifact raises a **typed** error, never
  yields silently wrong tensor bytes;
- damage is contained: a corrupt page or index quarantines only the
  models it backs (the engine keeps serving the rest), while journal-body
  or catalog corruption degrades the whole store to read-only on the
  last good snapshot;
- a torn journal *tail* (the only damage an append crash can cause) is
  tolerated and truncated at open — satellite S1;
- the maintenance daemon never dies silently — satellite S2;
- random single-bit flips / truncations anywhere in the store never
  escape detection — satellite S3 (hypothesis property + seeded fallback);
- ``tools/fsck.py`` finds all of the above offline and repairs what is
  safely repairable.
"""

import importlib.util
import json
import os
import random
import shutil
import tempfile
import time

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core.catalog import (
    STATUS_CORRUPT,
    Catalog,
    read_journal,
)
from repro.core.integrity import (
    CorruptIndexError,
    CorruptMetaError,
    CorruptPageError,
    IntegrityError,
    ReadOnlyStoreError,
    frame_index,
    journal_line,
    meta_payload,
    parse_journal_record,
    parse_meta,
    unframe_index,
)
from repro.core.maintenance import MaintenanceDaemon
from repro.core.pages import (
    TensorRecord,
    encode_payload,
    read_record,
    verify_page,
    write_page,
)
from repro.core.quantize import quantize_delta

RNG = np.random.default_rng(7)

_FSCK_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "tools", "fsck.py",
)
_spec = importlib.util.spec_from_file_location("neurstore_fsck", _FSCK_PATH)
fsck_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_spec and fsck_mod)
fsck = fsck_mod.fsck


def _tensors(n=2, d=16, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return {
        f"t{i}": rng.normal(0, scale, (d,)).astype(np.float32)
        for i in range(n)
    }


def _flip(path: str, byte: int, bit: int = 0) -> None:
    with open(path, "r+b") as f:
        f.seek(byte % os.path.getsize(path))
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ (1 << (bit % 8))]))


def _page_path(root: str, name: str) -> str:
    return os.path.join(root, "pages", Catalog(root).get(name).page)


# ------------------------------------------------------------ page framing
def _sample_records(k=3, d=16):
    recs = []
    for i in range(k):
        delta = RNG.normal(0, 0.01, d).astype(np.float32)
        qd, meta = quantize_delta(delta, 1e-3)
        rec = TensorRecord(
            name=f"r{i}", shape=(d,), dim_key=d, vertex_id=i,
            meta=meta, qdelta=qd,
        )
        rec.payload = encode_payload(rec)
        recs.append(rec)
    return recs


def test_page_v3_roundtrip():
    recs = _sample_records()
    buf = write_page(recs)
    page = verify_page(buf)
    assert page.n_records == len(recs)
    assert page.crcs is not None and all(c for c in page.crcs)
    for i in range(page.n_records):
        r = read_record(page, i)
        assert r.name == f"r{i}"


def test_page_without_checksums_still_parses():
    buf = write_page(_sample_records(), checksums=False)
    page = verify_page(buf)  # crc==0 sentinel: nothing to verify
    assert page.crcs is not None and not any(page.crcs)


def test_page_every_byte_flip_detected():
    """Any single bit flip anywhere in a v3 page raises CorruptPageError."""
    buf = bytes(write_page(_sample_records(k=2, d=8)))
    step = max(1, len(buf) // 64)  # sample ~64 positions across the file
    for off in range(0, len(buf), step):
        damaged = bytearray(buf)
        damaged[off] ^= 0x10
        with pytest.raises(CorruptPageError):
            verify_page(bytes(damaged))


def test_page_truncation_detected():
    buf = bytes(write_page(_sample_records()))
    for cut in (1, len(buf) // 3, len(buf) - 1):
        with pytest.raises(CorruptPageError):
            verify_page(buf[:cut])


# ------------------------------------------------------------ index framing
def test_index_frame_roundtrip_and_flip():
    payload = os.urandom(256)
    buf = frame_index(payload)
    assert unframe_index(buf) == payload
    for off in (0, 5, len(buf) // 2, len(buf) - 1):
        damaged = bytearray(buf)
        damaged[off] ^= 0x01
        with pytest.raises(CorruptIndexError):
            unframe_index(bytes(damaged))
    with pytest.raises(CorruptIndexError):
        unframe_index(buf[:-3])


# ---------------------------------------------------------- journal records
def test_journal_record_roundtrip_and_tamper():
    line = journal_line({"op": "intent", "tx": 3, "name": "m"})
    rec = parse_journal_record(line)
    assert rec["op"] == "intent" and rec["tx"] == 3
    with pytest.raises(ValueError):
        parse_journal_record(line.replace('"m"', '"x"'))
    # Legacy (no crc field) records still parse.
    assert parse_journal_record('{"op": "commit", "tx": 1}')["tx"] == 1


def test_read_journal_classifies_torn_vs_corrupt(tmp_path):
    jp = str(tmp_path / "journal.jsonl")
    good = [journal_line({"op": "intent", "tx": i}) for i in (1, 2)]
    # Damaged suffix (a torn half-written line) → torn, records intact.
    with open(jp, "w") as f:
        f.write("".join(good) + '{"op": "inte')
    records, max_tx, torn, corrupt = read_journal(jp)
    assert [r["tx"] for r in records] == [1, 2]
    assert torn is not None and corrupt is None and max_tx == 2
    # Multi-line garbage suffix is still just a torn tail.
    with open(jp, "w") as f:
        f.write("".join(good) + "garbage\nmore garbage")
    _, _, torn, corrupt = read_journal(jp)
    assert torn is not None and corrupt is None
    # Trailing blank line is clean.
    with open(jp, "w") as f:
        f.write("".join(good) + "\n")
    _, _, torn, corrupt = read_journal(jp)
    assert torn is None and corrupt is None
    # Damaged record BEFORE a valid one → body corruption.
    with open(jp, "w") as f:
        f.write("garbage\n" + good[1])
    _, _, _, corrupt = read_journal(jp)
    assert corrupt is not None


# ------------------------------------------------- S1: torn-tail tolerance
def test_reopen_truncates_torn_journal_tail(tmp_path):
    """Regression: a half-written trailing journal line must not prevent
    open — it is truncated and the committed state serves as usual."""
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("a", {}, _tensors(seed=1))
    eng.save_model("b", {}, _tensors(seed=2, scale=4.0))
    base = {n: eng.load_model(n).materialize() for n in ("a", "b")}
    eng.close()

    jp = os.path.join(root, "journal.jsonl")
    with open(jp, "ab") as f:
        f.write(b'{"op": "intent", "tx": 99, "na')  # torn mid-write

    eng = StorageEngine(root)
    assert not eng.read_only
    for n in ("a", "b"):
        got = eng.load_model(n).materialize()
        for k in base[n]:
            np.testing.assert_array_equal(got[k], base[n][k])
    # The torn bytes are gone from disk after open.
    _, _, torn, corrupt = read_journal(jp)
    assert torn is None and corrupt is None
    eng.save_model("c", {}, _tensors(seed=3))  # store is fully writable
    eng.close()


def test_journal_body_corruption_degrades_to_read_only(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("a", {}, _tensors(seed=1))
    base = eng.load_model("a").materialize()
    eng.close()

    jp = os.path.join(root, "journal.jsonl")
    with open(jp, "wb") as f:  # damaged record PRECEDES a valid one
        f.write(b"garbage\n" + journal_line({"op": "commit", "tx": 9}).encode())

    eng = StorageEngine(root)
    assert eng.read_only and "journal" in eng.degraded_reason
    got = eng.load_model("a").materialize()  # reads still served
    for k in base:
        np.testing.assert_array_equal(got[k], base[k])
    with pytest.raises(ReadOnlyStoreError):
        eng.save_model("x", {}, _tensors(seed=4))
    with pytest.raises(ReadOnlyStoreError):
        eng.delete_model("a")
    with pytest.raises(ReadOnlyStoreError):
        eng.vacuum()
    assert eng.stats()["integrity"]["read_only"] is True
    eng.close()


# ------------------------------------------------------- meta.json fallback
def test_meta_payload_roundtrip_and_flip():
    text = meta_payload({"models": {}, "next_id": 0})
    d = parse_meta(text)
    assert d["models"] == {} and "integrity" not in d
    with pytest.raises(CorruptMetaError):
        parse_meta(text.replace("0", "1", 1))
    # Legacy unstamped snapshots still parse.
    assert parse_meta(json.dumps({"models": {}}))["models"] == {}


def test_corrupt_meta_falls_back_to_prev_read_only(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("a", {}, _tensors(seed=1))
    base = eng.load_model("a").materialize()
    eng.save_model("b", {}, _tensors(seed=2, scale=4.0))  # writes .prev
    eng.close()

    meta = os.path.join(root, "meta.json")
    assert os.path.exists(meta + ".prev")
    _flip(meta, byte=len(open(meta).read()) // 2, bit=3)

    eng = StorageEngine(root)
    assert eng.read_only and "last good" in eng.degraded_reason
    # "a" was committed in the prev snapshot: it must serve bit-identically.
    got = eng.load_model("a").materialize()
    for k in base:
        np.testing.assert_array_equal(got[k], base[k])
    with pytest.raises(ReadOnlyStoreError):
        eng.save_model("x", {}, _tensors(seed=5))
    eng.close()


def test_meta_and_prev_both_corrupt_is_unopenable(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("a", {}, _tensors(seed=1))
    eng.save_model("b", {}, _tensors(seed=2))
    eng.close()
    meta = os.path.join(root, "meta.json")
    _flip(meta, byte=10)
    _flip(meta + ".prev", byte=10)
    with pytest.raises(CorruptMetaError):
        StorageEngine(root)


# --------------------------------------------------- quarantine containment
@pytest.fixture
def store_with_corrupt_page(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("good", {}, _tensors(seed=1))
    eng.save_model("bad", {}, _tensors(seed=2, scale=4.0))
    base = eng.load_model("good").materialize()
    eng.close()
    _flip(_page_path(root, "bad"), byte=-5 % os.path.getsize(
        _page_path(root, "bad")))
    return root, base


def test_corrupt_page_quarantines_only_that_model(store_with_corrupt_page):
    root, base = store_with_corrupt_page
    eng = StorageEngine(root)
    with pytest.raises(CorruptPageError):
        eng.load_model("bad").materialize()
    st = eng.stats()["integrity"]
    assert st["corrupt_models"] == ["bad"] and not st["read_only"]
    # Healthy model unaffected; store stays writable.
    got = eng.load_model("good").materialize()
    for k in base:
        np.testing.assert_array_equal(got[k], base[k])
    eng.save_model("new", {}, _tensors(seed=3))
    # Repeated loads report quarantine without re-reading the page.
    with pytest.raises(CorruptPageError, match="quarantined"):
        eng.load_model("bad")
    eng.close()

    # Quarantine is persisted: a fresh open still refuses the model.
    eng = StorageEngine(root)
    assert eng.catalog.get("bad").status == STATUS_CORRUPT
    with pytest.raises(CorruptPageError, match="quarantined"):
        eng.load_model("bad")
    # Vacuum refuses to renumber while quarantined models pin vertex ids.
    rep = eng.vacuum(min_dead_fraction=0.0)
    assert "quarantined" in rep.get("skipped_reason", "")
    # Deleting the quarantined model clears the quarantine and its refs.
    eng.delete_model("bad")
    assert eng.stats()["integrity"]["corrupt_models"] == []
    eng.vacuum(min_dead_fraction=0.0)  # now allowed
    eng.close()


def test_scrub_quarantines_latent_corruption(store_with_corrupt_page):
    root, _ = store_with_corrupt_page
    eng = StorageEngine(root)
    seen = 0
    for _ in range(8):  # round-robin over committed models
        seen += eng.scrub(max_models=1)["scanned"]
        if eng.stats()["integrity"]["corrupt_models"]:
            break
    assert eng.stats()["integrity"]["corrupt_models"] == ["bad"]
    assert seen >= 1
    reason = eng._corrupt_reasons["bad"]
    assert reason.startswith("scrub:")
    eng.close()


def test_verify_store_reports_and_quarantines(store_with_corrupt_page):
    root, _ = store_with_corrupt_page
    eng = StorageEngine(root)
    rep = eng.verify_store(quarantine=False)
    assert rep["pages"]["good"] == "ok"
    assert rep["pages"]["bad"].startswith("corrupt")
    assert not rep["quarantined"]
    rep = eng.verify_store(quarantine=True)
    assert rep["quarantined"] == ["bad"]
    eng.close()
    eng = StorageEngine(root)  # persisted
    assert eng.catalog.get("bad").status == STATUS_CORRUPT
    eng.close()


def test_corrupt_index_quarantines_dependent_models(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("m", {}, _tensors(seed=1))
    eng.close()
    idx_dir = os.path.join(root, "index")
    idx_file = os.path.join(idx_dir, os.listdir(idx_dir)[0])
    _flip(idx_file, byte=os.path.getsize(idx_file) - 2)
    eng = StorageEngine(root)
    with pytest.raises((CorruptIndexError, CorruptPageError)):
        eng.load_model("m").materialize()
    assert eng.stats()["integrity"]["corrupt_models"] == ["m"]
    eng.close()
    rep = fsck(root, repair=True, drop_corrupt=True)
    assert rep["clean"], rep
    assert any("index" in a for a in rep["actions"]), rep["actions"]


# ------------------------------------------------------------------- fsck
def test_fsck_clean_store(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("a", {}, _tensors(seed=1))
    eng.close()
    rep = fsck(root)
    assert rep["clean"] and not rep["errors"] and not rep["warnings"], rep


def test_fsck_detects_and_repairs(store_with_corrupt_page):
    root, base = store_with_corrupt_page
    rep = fsck(root)
    assert not rep["clean"] and any("bad" in e for e in rep["errors"])
    # Repair without dropping: quarantines, store clean-with-warnings.
    rep = fsck(root, repair=True)
    assert rep["clean"]
    assert any("quarantined" in w for w in rep["warnings"])
    # Drop: fully clean, healthy model intact.
    rep = fsck(root, repair=True, drop_corrupt=True)
    assert rep["clean"] and not rep["warnings"], rep
    eng = StorageEngine(root)
    got = eng.load_model("good").materialize()
    for k in base:
        np.testing.assert_array_equal(got[k], base[k])
    assert eng.list_models() == ["good"]
    eng.close()
    assert fsck(root)["clean"]


def test_fsck_promotes_prev_snapshot(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("a", {}, _tensors(seed=1))
    eng.save_model("b", {}, _tensors(seed=2))
    eng.close()
    meta = os.path.join(root, "meta.json")
    _flip(meta, byte=12)
    rep = fsck(root, repair=True, drop_corrupt=True)
    assert rep["clean"], rep
    assert any("promoted" in a for a in rep["actions"]), rep["actions"]
    assert os.path.exists(meta + ".corrupt")  # evidence kept
    eng = StorageEngine(root)
    assert not eng.read_only
    eng.load_model("a").materialize()
    eng.close()


def test_fsck_cli(tmp_path, capsys):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("a", {}, _tensors(seed=1))
    eng.close()
    assert fsck_mod.main([root, "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["clean"] is True
    _flip(_page_path(root, "a"), byte=-4 % os.path.getsize(
        _page_path(root, "a")))
    assert fsck_mod.main([root]) == 1
    assert fsck_mod.main([root, "--repair", "--drop-corrupt"]) == 0


# --------------------------------------------- S2: daemon failure containment
def test_daemon_backoff_math(tmp_path):
    eng = StorageEngine(str(tmp_path))
    d = MaintenanceDaemon(eng, interval_s=1.0, max_backoff_s=10.0)
    assert d._backoff_s() == 1.0
    d.consecutive_errors = 2
    assert d._backoff_s() == 4.0
    d.consecutive_errors = 8
    assert d._backoff_s() == 10.0  # capped
    eng.close()


def test_daemon_records_step_errors_and_recovers(tmp_path):
    eng = StorageEngine(str(tmp_path))
    d = MaintenanceDaemon(eng, interval_s=0.01)
    boom = {"on": True}
    real_step = d.step

    def step():
        if boom["on"]:
            raise RuntimeError("injected maintenance failure")
        return real_step()

    d.step = step
    d.start()
    deadline = time.time() + 10
    while d.errors < 3 and time.time() < deadline:
        time.sleep(0.01)
    assert d.errors >= 3, "daemon stopped counting failures"
    assert d.consecutive_errors >= 1
    assert "injected maintenance failure" in d.last_error
    assert d.running  # it did NOT die
    boom["on"] = False
    while d.consecutive_errors != 0 and time.time() < deadline:
        time.sleep(0.01)
    assert d.consecutive_errors == 0  # reset on first success
    d.stop()
    st = d.stats()
    for key in ("errors", "last_error", "restarts", "consecutive_errors",
                "backoff_s"):
        assert key in st
    eng.close()


def test_daemon_supervisor_restarts_escaped_loop(tmp_path):
    eng = StorageEngine(str(tmp_path))
    d = MaintenanceDaemon(eng, interval_s=0.01, max_backoff_s=0.05)

    def step():
        raise KeyboardInterrupt("escapes the Exception handler")

    d.step = step
    d.start()
    deadline = time.time() + 10
    while d.restarts < 2 and time.time() < deadline:
        time.sleep(0.01)
    assert d.restarts >= 2, "supervisor did not restart the loop"
    assert d.running
    d.stop()
    assert not d.running
    eng.close()


def test_engine_stats_surface_daemon_health(tmp_path):
    eng = StorageEngine(str(tmp_path), auto_maintenance=True)
    try:
        st = eng.stats()
        assert "maintenance" in st
        for key in ("errors", "last_error", "restarts"):
            assert key in st["maintenance"]
    finally:
        eng.close()


# --------------------------- S3: no single fault yields silently wrong bytes
class _Baseline:
    """A small store built once; trials mutate throwaway copies of it."""

    def __init__(self):
        self.root = tempfile.mkdtemp(prefix="nsint_")
        eng = StorageEngine(self.root)
        eng.save_model("a", {}, _tensors(seed=1))
        eng.save_model("b", {}, _tensors(seed=2, scale=4.0))
        eng.save_model("c", {}, _tensors(seed=3, scale=8.0))
        self.values = {
            n: eng.load_model(n).materialize() for n in ("a", "b", "c")
        }
        eng.close()
        self.files = []
        for dirpath, _dirs, files in os.walk(self.root):
            for f in files:
                p = os.path.join(dirpath, f)
                if os.path.getsize(p) > 0:
                    self.files.append(os.path.relpath(p, self.root))
        self.files.sort()


_BASELINE = None


def _baseline():
    global _BASELINE
    if _BASELINE is None:
        _BASELINE = _Baseline()
    return _BASELINE


def _check_one_fault(rel_idx: int, pos_frac: float, bit: int,
                     truncate: bool) -> None:
    """Apply one fault to a copy of the baseline store and assert the
    integrity contract: typed error, quarantine, degradation, or
    bit-identical data — never silently wrong bytes."""
    bl = _baseline()
    work = tempfile.mkdtemp(prefix="nsint_trial_")
    try:
        dst = os.path.join(work, "store")
        shutil.copytree(bl.root, dst)
        rel = bl.files[rel_idx % len(bl.files)]
        target = os.path.join(dst, rel)
        size = os.path.getsize(target)
        if truncate:
            with open(target, "r+b") as f:
                f.truncate(max(0, int(size * pos_frac)))
        else:
            _flip(target, byte=int((size - 1) * pos_frac), bit=bit)
        try:
            eng = StorageEngine(dst)
        except IntegrityError:
            return  # typed refusal at open is a pass
        try:
            for name, want in bl.values.items():
                try:
                    got = eng.load_model(name).materialize()
                except (IntegrityError, ValueError):
                    continue  # typed detection is a pass
                except KeyError:
                    # Degraded store serving an older snapshot, or a
                    # replay legitimately rolled the model back.
                    assert eng.read_only or name not in eng.list_models()
                    continue
                for k, v in want.items():
                    assert np.array_equal(got[k], v), (
                        f"SILENT CORRUPTION: {rel} fault gave wrong bytes "
                        f"for {name}/{k}"
                    )
        finally:
            eng.close()
    finally:
        shutil.rmtree(work, ignore_errors=True)


def test_single_fault_never_silently_corrupts_seeded():
    """Seeded sweep (runs everywhere, no hypothesis needed)."""
    rng = random.Random(1234)
    for _ in range(60):
        _check_one_fault(
            rel_idx=rng.randrange(1 << 16),
            pos_frac=rng.random(),
            bit=rng.randrange(8),
            truncate=rng.random() < 0.3,
        )


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        rel_idx=st.integers(min_value=0, max_value=1 << 16),
        pos_frac=st.floats(min_value=0.0, max_value=1.0),
        bit=st.integers(min_value=0, max_value=7),
        truncate=st.booleans(),
    )
    def test_single_fault_never_silently_corrupts_property(
        rel_idx, pos_frac, bit, truncate
    ):
        _check_one_fault(rel_idx, pos_frac, bit, truncate)

except ImportError:  # pragma: no cover - hypothesis optional locally
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_single_fault_never_silently_corrupts_property():
        """Placeholder so a missing-hypothesis env reports the skip."""
