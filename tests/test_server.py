"""End-to-end tests for the networked front door (``repro.server``).

The contract under test (``docs/serving.md``):

- a model saved through ``StoreClient`` streams back down byte-identical
  to what the embedded engine reconstructs for the same catalog entry;
- concurrent served readers + a writer see snapshot-consistent models
  and zero 5xx responses;
- tenant byte quotas reject the offending save atomically at commit
  time (nothing durable, catalog unchanged);
- the admission policy sheds writes with HTTP 429 + ``Retry-After``
  while a lagging snapshot pins old epochs, and admits again once the
  reader drains;
- storage corruption surfaces to the remote client as the *same typed
  exception* the embedded API raises, via the stable error-code
  registry (parametrized contract test);
- the streaming wire format fails typed on truncation and bit damage.
"""

import os
import threading

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core.catalog import Catalog
from repro.core.engine import STATS_SCHEMA_VERSION
from repro.core.integrity import (
    CorruptPageError,
    ReadOnlyStoreError,
)
from repro.core.loader import KernelNotReady
from repro.server import (
    AdmissionPolicy,
    ModelStoreServer,
    QuotaManager,
    StoreClient,
    WireError,
)
from repro.server import wire as wire_mod
from repro.store import SaveRequest
from repro.store.errors import (
    ERROR_CODES,
    AdmissionRejectedError,
    QuotaExceededError,
    RemoteStoreError,
    error_payload,
    raise_for_code,
)

RNG = np.random.default_rng(23)


def _tensors(n=3, d=48, seed=None, fill=None):
    if fill is not None:
        return {f"t{i}": np.full((d,), float(fill), dtype=np.float32)
                for i in range(n)}
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return {f"t{i}": rng.standard_normal((d,)).astype(np.float32)
            for i in range(n)}


@pytest.fixture
def served(tmp_path):
    """(engine, server) pair on an ephemeral port, torn down after."""
    engine = StorageEngine(str(tmp_path))
    server = ModelStoreServer(engine).start()
    yield engine, server
    server.stop()
    engine.close()


def _client(server, tenant="acme"):
    return StoreClient(server.host, server.port, tenant=tenant)


# ---------------------------------------------------------------- roundtrip
def test_save_then_load_byte_identical_across_clients(served):
    engine, server = served
    tensors = _tensors(seed=1)
    writer = _client(server)
    report = writer.save(SaveRequest("m", tensors, architecture={"v": 1}))
    assert report.n_tensors == len(tensors)
    assert report.name == "m"  # tenant prefix never leaks back out

    reader = _client(server)  # a SECOND client: nothing shared but the wire
    with reader.load("m") as handle:
        served_params = handle.materialize()
        assert handle.architecture == {"v": 1}

    embedded = engine.load_model("acme/m")
    try:
        for k in tensors:
            np.testing.assert_array_equal(
                served_params[k], embedded.tensor(k))
    finally:
        embedded.close()


def test_streamed_load_matches_eager_and_preserves_order(served):
    _, server = served
    c = _client(server)
    c.save(SaveRequest("m", _tensors(seed=2)))
    eager = c.load("m").materialize()
    lazy = c.load("m", stream=True)
    try:
        order = []
        for name, arr in lazy.tensors():
            order.append(name)
            np.testing.assert_array_equal(arr, eager[name])
    finally:
        lazy.close()
    assert order == ["t0", "t1", "t2"]  # architecture/page order


def test_flexible_loading_bits_over_the_wire(served):
    engine, server = served
    c = _client(server)
    c.save(SaveRequest("m", _tensors(seed=3)))
    coarse = c.load("m", bits=2).materialize()
    embedded = engine.load_model("acme/m", bits=2)
    try:
        for k, arr in coarse.items():
            np.testing.assert_array_equal(arr, embedded.tensor(k))
    finally:
        embedded.close()


def test_replace_delete_info_and_listing(served):
    _, server = served
    c = _client(server)
    with pytest.raises(KeyError):
        c.replace(SaveRequest("m", _tensors(seed=4)))
    c.save(SaveRequest("m", _tensors(seed=4)))
    rep = c.replace(SaveRequest("m", _tensors(seed=5)))
    assert rep.model_id >= 1
    info = c.model_info("m")
    assert info["name"] == "m" and info["page_bytes"] > 0
    assert c.models() == ["m"]
    c.delete("m")
    assert c.models() == []
    with pytest.raises(KeyError):
        c.load("m")


def test_tenant_namespaces_are_isolated(served):
    engine, server = served
    a, b = _client(server, "alice"), _client(server, "bob")
    a.save(SaveRequest("m", _tensors(seed=6)))
    b.save(SaveRequest("m", _tensors(seed=7)))
    assert a.models() == ["m"] and b.models() == ["m"]
    assert set(engine.list_models()) == {"alice/m", "bob/m"}
    # Different content despite the same visible name.
    ta, tb = a.load("m").materialize(), b.load("m").materialize()
    assert not np.array_equal(ta["t0"], tb["t0"])
    with pytest.raises(ValueError):
        _client(server, "../escape").models()  # invalid tenant id


# -------------------------------------------------------------- concurrency
def test_four_readers_one_writer_no_5xx_snapshot_consistent(served):
    """Served reads stay consistent and error-free under writer churn.

    The writer replaces the model with tensors all equal to the version
    number; any torn read (tensors from two different versions in one
    response) or 5xx fails the test.
    """
    engine, server = served
    writer = _client(server)
    writer.save(SaveRequest("m", _tensors(fill=0)))

    stop = threading.Event()
    failures: list[str] = []

    def write_loop():
        version = 0
        while not stop.is_set():
            version += 1
            try:
                writer.replace(SaveRequest("m", _tensors(fill=version)))
            except AdmissionRejectedError:
                continue  # shed writes are allowed; 5xx is not
            except Exception as exc:  # noqa: BLE001
                failures.append(f"writer: {exc!r}")
                return

    def read_loop(idx):
        c = _client(server)
        reads = 0
        while not stop.is_set() or reads == 0:
            try:
                got = c.load("m").materialize()
            except Exception as exc:  # noqa: BLE001
                failures.append(f"reader{idx}: {exc!r}")
                return
            versions = {int(round(float(arr[0]))) for arr in got.values()}
            if len(versions) != 1:
                failures.append(f"reader{idx}: torn read {versions}")
                return
            reads += 1

    threads = [threading.Thread(target=write_loop)] + [
        threading.Thread(target=read_loop, args=(i,)) for i in range(4)
    ]
    for t in threads:
        t.start()
    stop_timer = threading.Timer(2.0, stop.set)
    stop_timer.start()
    for t in threads:
        t.join(timeout=30)
    stop.set()
    stop_timer.cancel()

    assert failures == []
    assert server.server_stats()["errors_5xx"] == 0


# -------------------------------------------------------------------- quota
def test_quota_rejects_save_atomically(tmp_path):
    engine = StorageEngine(str(tmp_path))
    quotas = QuotaManager()
    server = ModelStoreServer(engine, quotas=quotas).start()
    try:
        c = _client(server)
        c.save(SaveRequest("m1", _tensors(seed=8)))
        used = quotas.usage(engine, "acme")
        assert used > 0 and c.quota()["used_bytes"] == used

        quotas.set_limit("acme", used + 16)  # room for nothing more
        epoch_before = engine.stats()["epoch"]
        with pytest.raises(QuotaExceededError):
            c.save(SaveRequest("m2", _tensors(seed=9)))
        # Rejected pre-durability: no catalog entry, no epoch bump.
        assert c.models() == ["m1"]
        assert engine.stats()["epoch"] == epoch_before

        # Replace charges only the DELTA, so it fits under the cap...
        c.replace(SaveRequest("m1", _tensors(seed=8)))
        # ...and another tenant is not constrained by acme's limit.
        _client(server, "other").save(SaveRequest("big", _tensors(seed=10)))
    finally:
        server.stop()
        engine.close()


# ------------------------------------------------------------- backpressure
def test_backpressure_sheds_writes_until_reader_drains(tmp_path):
    engine = StorageEngine(str(tmp_path))
    server = ModelStoreServer(
        engine, admission=AdmissionPolicy(max_epoch_lag=0)).start()
    try:
        c = _client(server)
        c.save(SaveRequest("m", _tensors(seed=11)))  # epoch 0 → 1, no lag
        lagging = engine.load_model("acme/m")  # pins epoch 1
        c.save(SaveRequest("m2", _tensors(seed=12)))  # lag 0: admitted → epoch 2
        with pytest.raises(AdmissionRejectedError):
            c.save(SaveRequest("m3", _tensors(seed=13)))  # lag 1 > 0: shed
        assert server.admission.stats()["rejected"] == 1
        assert "m3" not in c.models()
        lagging.close()  # reader drains → lag back to 0
        c.save(SaveRequest("m3", _tensors(seed=13)))  # admitted again
        assert sorted(c.models()) == ["m", "m2", "m3"]
        # Reads were never gated, even while writes shed.
        assert c.load("m").materialize()
    finally:
        server.stop()
        engine.close()


# ----------------------------------------------------------- error contract
_REPRESENTATIVE = {
    "not_found": KeyError("m"),
    "corrupt": CorruptPageError("crc mismatch"),
    "read_only": ReadOnlyStoreError("degraded"),
    "quota_exceeded": QuotaExceededError("over"),
    "backpressure": AdmissionRejectedError("shed"),
    "kernel_not_ready": KernelNotReady("pallas kernel unavailable"),
    "invalid_request": ValueError("bad body"),
    "internal": RemoteStoreError("boom"),
}


@pytest.mark.parametrize("code", sorted(ERROR_CODES))
def test_error_contract(code):
    """code ↔ status ↔ exception is one bidirectional registry."""
    exc = _REPRESENTATIVE[code]
    status, payload = error_payload(exc)
    assert status == ERROR_CODES[code]
    assert payload["error"]["code"] == code
    assert payload["error"]["message"]  # never empty
    # The client turns the code back into the SAME exception type
    # (or a superclass-compatible one) the embedded API raises.
    with pytest.raises(type(exc)):
        raise_for_code(code, payload["error"]["message"])


def test_unknown_error_code_degrades_typed():
    with pytest.raises(RemoteStoreError, match=r"\[sharding_conflict\]"):
        raise_for_code("sharding_conflict", "from a newer server")


def test_served_error_statuses_match_registry(served):
    _, server = served
    c = _client(server)
    with pytest.raises(KeyError):  # 404 over the wire
        c.load("never-saved")
    with pytest.raises(KeyError):
        c.delete("never-saved")
    with pytest.raises(ValueError):  # 400: malformed upload body
        c._json("POST", c._model_path("m"), body=b"not a stream")


def test_corrupt_model_surfaces_same_typed_error_remotely(tmp_path):
    """Bit damage on disk → CorruptPageError through the socket (S4)."""
    root = str(tmp_path)
    engine = StorageEngine(root)
    server = ModelStoreServer(engine).start()
    c = _client(server)
    c.save(SaveRequest("good", _tensors(seed=14)))
    c.save(SaveRequest("bad", _tensors(seed=15)))
    server.stop()
    engine.close()

    page = os.path.join(root, "pages", Catalog(root).get("acme/bad").page)
    size = os.path.getsize(page)
    with open(page, "r+b") as f:  # flip one bit mid-payload
        f.seek(size // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0x10]))

    engine = StorageEngine(root)
    server = ModelStoreServer(engine).start()
    try:
        c = _client(server)
        with pytest.raises(CorruptPageError):
            c.load("bad")
        # Containment holds over the wire too: the healthy model still
        # serves and the store stays writable.
        assert c.load("good").materialize()
        c.save(SaveRequest("new", _tensors(seed=16)))
        assert c.stats().corrupt_models == 1
    finally:
        server.stop()
        engine.close()


# -------------------------------------------------------------- wire format
class _Buf:
    def __init__(self, data: bytes):
        self._data = data
        self._pos = 0

    def read(self, n: int) -> bytes:
        out = self._data[self._pos:self._pos + n]
        self._pos += len(out)
        return out


def _encode(tensors) -> bytes:
    return b"".join(wire_mod.encode_model_stream(
        {"name": "m"}, iter(tensors.items())))


def test_wire_roundtrip_and_trailer_validation():
    tensors = _tensors(seed=17)
    blob = _encode(tensors)
    header, records = wire_mod.decode_model_stream(_Buf(blob))
    assert header["name"] == "m"
    assert header["stream_version"] == wire_mod.STREAM_VERSION
    got = dict(records)  # exhausting validates the trailer
    for k in tensors:
        np.testing.assert_array_equal(got[k], tensors[k])


def test_wire_truncation_is_typed_never_partial():
    blob = _encode(_tensors(seed=18))
    for cut in (3, len(blob) // 2, len(blob) - 1):
        with pytest.raises(WireError):  # at decode (header) or iteration
            _, records = wire_mod.decode_model_stream(_Buf(blob[:cut]))
            list(records)


def test_wire_bit_damage_fails_crc():
    blob = bytearray(_encode(_tensors(seed=19)))
    blob[len(blob) // 2] ^= 0x01  # mid-stream → lands in a tensor payload
    _, records = wire_mod.decode_model_stream(_Buf(bytes(blob)))
    with pytest.raises(WireError):
        list(records)


def test_wire_rejects_unknown_stream_version():
    blob = _encode(_tensors(seed=20))
    bad = blob.replace(b'"stream_version": 1', b'"stream_version": 9', 1)
    with pytest.raises(WireError, match="stream_version"):
        wire_mod.decode_model_stream(_Buf(bad))


# -------------------------------------------------------------------- stats
def test_stats_endpoint_is_versioned_and_documented(served):
    _, server = served
    c = _client(server)
    c.save(SaveRequest("m", _tensors(seed=21)))
    st = c.stats()
    assert st.schema_version == STATS_SCHEMA_VERSION
    assert st.models == 1 and st.epoch >= 1
    assert st.pool_budget_bytes > 0 and not st.read_only
    # The admission signals are derivable from documented fields alone.
    assert st.pool_utilization >= 0.0 and st.epoch_lag == 0
    # Server-side telemetry rides along in the raw dump.
    assert st.raw["server"]["requests"] >= 2
    assert st.raw["server"]["errors_5xx"] == 0


def test_healthz_and_vacuum_admin(served):
    _, server = served
    c = _client(server)
    assert c.healthz()
    c.save(SaveRequest("m", _tensors(seed=22)))
    c.delete("m")
    report = c.vacuum()
    assert "vertices_dropped" in report


# ----------------------------------------------------------- response cache
def test_response_cache_admission_knob(tmp_path):
    """Oversized downloads bypass the cache instead of wiping it, and the
    policy is visible in stats (admissions/bypasses/evictions)."""
    from repro.server.app import _ResponseCache

    cache = _ResponseCache(budget_bytes=1000)  # default max_entry = 500
    assert cache.max_entry_bytes == 500
    cache.put(("big", None), b"x" * 501)  # refused, counted
    assert cache.get(("big", None)) is None
    cache.put(("a", None), b"x" * 400)
    cache.put(("b", None), b"y" * 400)
    assert cache.get(("a", None)) is not None
    cache.put(("c", None), b"z" * 400)  # budget forces an eviction
    st = cache.stats()
    assert st["bypasses"] == 1
    assert st["admissions"] == 3
    assert st["evictions"] >= 1
    assert st["max_entry_bytes"] == 500
    assert st["bytes"] <= st["budget_bytes"]


def test_response_cache_max_entry_passthrough(tmp_path):
    engine = StorageEngine(str(tmp_path))
    server = ModelStoreServer(
        engine, response_cache_bytes=1 << 20,
        response_cache_max_entry_bytes=64,  # every real model bypasses
    ).start()
    try:
        c = _client(server)
        c.save(SaveRequest("m", _tensors(seed=30)))
        for _ in range(2):
            c.load("m").close()
        st = server.response_cache.stats()
        assert st["max_entry_bytes"] == 64
        assert st["bypasses"] >= 1 and st["admissions"] == 0
        assert st["hits"] == 0  # nothing was ever admitted
        c.close()
    finally:
        server.stop()
        engine.close()


def test_healthz_shape_is_enriched(served):
    """/v1/healthz is a contract: schema version, uptime, degraded flag,
    maintenance health — not just a liveness bit."""
    import json as _json
    import urllib.request

    _, server = served
    url = f"http://{server.host}:{server.port}/v1/healthz"
    with urllib.request.urlopen(url) as resp:
        body = _json.loads(resp.read())
    assert set(body) == {
        "ok", "stats_schema_version", "uptime_s", "read_only", "maintenance",
        "slow_op_threshold_s"}
    assert set(body["maintenance"]) == {
        "running", "consecutive_errors", "last_error_age_s"}
    assert body["stats_schema_version"] == STATS_SCHEMA_VERSION
    assert body["slow_op_threshold_s"] > 0


def test_metrics_route_serves_prometheus_text(served):
    import urllib.request

    from repro.obs.metrics import parse_prometheus_text

    _, server = served
    c = _client(server)
    c.save(SaveRequest("m", _tensors(seed=31)))
    c.load("m").close()
    url = f"http://{server.host}:{server.port}/v1/metrics"
    with urllib.request.urlopen(url) as resp:
        assert resp.status == 200
        assert resp.headers["Content-Type"].startswith("text/plain")
        fams = parse_prometheus_text(resp.read().decode("utf-8"))
    # One family from every instrumented subsystem answers the scrape.
    for name in ("neurstore_engine_ops_total", "neurstore_pool_hits_total",
                 "neurstore_hnsw_searches_total",
                 "neurstore_maintenance_steps_total",
                 "neurstore_server_requests_total"):
        assert name in fams, name
    c.close()


def test_unknown_route_counts_as_4xx_not_5xx(served):
    from repro.obs.metrics import default_registry

    _, server = served

    def val():
        return default_registry().sample_value(
            "neurstore_server_requests_total",
            {"route": "unknown", "method": "GET", "status": "4xx"}) or 0.0

    before = val()
    c = _client(server)
    with pytest.raises(Exception):
        c._json("GET", "/v1/nope")
    assert val() == before + 1
    assert server.server_stats()["errors_5xx"] == 0
    c.close()
