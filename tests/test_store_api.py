"""The typed embedded facade: ``repro.store.NeurStore`` + shared dataclasses.

Covers satellite S1 (facade + canonical knob set) and the pieces of the
typed surface the server tests then exercise over a socket:

- facade save/load roundtrips match raw-engine access bit for bit;
- ``SaveRequest`` survives its own wire-header encoding;
- ``LoadHandle`` gives the same tensors through all three access
  patterns and releases its snapshot on close;
- ``StoreStats`` projects the engine dump onto the documented schema and
  derives the two admission signals correctly;
- legacy import paths (``repro.core.StorageEngine``/``SaveReport``) stay
  importable and identical to the facade's re-exports.
"""

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core.engine import STATS_SCHEMA_VERSION
from repro.core.engine import SaveReport as EngineSaveReport
from repro.store import (
    DEFAULT_TAU,
    DEFAULT_TOLERANCE,
    NeurStore,
    SaveReport,
    SaveRequest,
    StoreStats,
)

RNG = np.random.default_rng(11)


def _tensors(n=3, d=32, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return {f"t{i}": rng.standard_normal((d,)).astype(np.float32)
            for i in range(n)}


@pytest.fixture
def store(tmp_path):
    with NeurStore.open(str(tmp_path)) as s:
        yield s


# ------------------------------------------------------------------ facade
def test_facade_roundtrip_matches_engine(store):
    tensors = _tensors(seed=1)
    report = store.save(SaveRequest("m", tensors, architecture={"k": 1}))
    assert isinstance(report, SaveReport)
    with store.load("m") as handle:
        got = handle.materialize()
    raw = store.engine.load_model("m")
    try:
        for k in tensors:
            np.testing.assert_array_equal(got[k], raw.tensor(k))
    finally:
        raw.close()


def test_facade_replace_delete_models(store):
    store.save(SaveRequest("a", _tensors(seed=2)))
    with pytest.raises(KeyError):
        store.replace(SaveRequest("missing", _tensors(seed=3)))
    store.replace(SaveRequest("a", _tensors(seed=4)))
    assert store.models() == ["a"]
    store.delete("a")
    assert store.models() == []


def test_save_many_one_epoch_and_knob_guard(store):
    reqs = [SaveRequest(f"m{i}", _tensors(seed=10 + i)) for i in range(3)]
    reports = store.save_many(reqs)
    assert [r.name for r in reports] == ["m0", "m1", "m2"]
    # Batch commit bumps the epoch once, not once per model.
    assert store.stats().epoch == 1
    with pytest.raises(ValueError, match="per-save knob"):
        store.save_many([SaveRequest("x", _tensors(), tolerance=1e-2)])


def test_load_many_consistent_snapshot(store):
    store.save_many([SaveRequest(f"m{i}", _tensors(seed=i)) for i in range(2)])
    handles = store.load_many(["m0", "m1"])
    try:
        assert {h.name for h in handles} == {"m0", "m1"}
        for h in handles:
            assert set(h.tensor_names()) == {"t0", "t1", "t2"}
    finally:
        for h in handles:
            h.close()


def test_flexible_loading_bits_knob(store):
    tensors = _tensors(seed=5)
    store.save(SaveRequest("m", tensors))
    with store.load("m", bits=2) as coarse, store.load("m") as full:
        err_coarse = np.abs(coarse.tensor("t0") - tensors["t0"]).max()
        err_full = np.abs(full.tensor("t0") - tensors["t0"]).max()
    assert coarse.bits == 2 and full.bits is None
    assert err_full <= DEFAULT_TOLERANCE
    assert err_coarse >= err_full  # fewer planes can't be more precise


# -------------------------------------------------------------- LoadHandle
def test_load_handle_access_patterns_agree(store):
    tensors = _tensors(seed=6)
    store.save(SaveRequest("m", tensors))
    with store.load("m") as h:
        streamed = dict(h.tensors())
        assert set(streamed) == set(tensors)
        mat = h.materialize()
        for k in tensors:
            np.testing.assert_array_equal(streamed[k], mat[k])
            np.testing.assert_array_equal(h.tensor(k), mat[k])


def test_load_handle_close_releases_snapshot(store):
    store.save(SaveRequest("m", _tensors(seed=7)))
    h = store.load("m")
    h.materialize()
    assert store.stats().snapshots_live >= 1
    h.close()
    assert store.stats().snapshots_live == 0


# ------------------------------------------------------------- SaveRequest
def test_save_request_wire_header_roundtrip():
    tensors = _tensors(seed=8)
    req = SaveRequest("m", tensors, architecture={"family": "demo"},
                      tolerance=1e-2, tau=0.5)
    header = req.wire_header()
    assert header["n_tensors"] == len(tensors)
    back = SaveRequest.from_wire(header, tensors)
    assert (back.name, back.architecture, back.tolerance, back.tau) == \
        ("m", {"family": "demo"}, 1e-2, 0.5)
    assert req.total_bytes() == sum(t.nbytes for t in tensors.values())


def test_save_report_dict_roundtrip(store):
    report = store.save(SaveRequest("m", _tensors(seed=9)))
    d = report.to_dict()
    back = SaveReport.from_dict(d)
    assert back == report
    # Unknown keys from a newer server are ignored, not fatal.
    d["future_field"] = 42
    assert SaveReport.from_dict(d) == report


# -------------------------------------------------------------- StoreStats
def test_store_stats_projection_and_derived_signals(store):
    store.save(SaveRequest("m", _tensors(seed=12)))
    st = store.stats()
    assert st.schema_version == STATS_SCHEMA_VERSION
    assert st.models == 1 and st.epoch == 1
    assert st.raw["buffer_pool"]["budget_bytes"] == st.pool_budget_bytes

    synthetic = StoreStats(
        schema_version=1, epoch=10, models=1, snapshots_live=2,
        oldest_epoch=4, pool_resident_bytes=75, pool_budget_bytes=100,
        pool_pinned_bytes=0, read_only=False, corrupt_models=0)
    assert synthetic.pool_utilization == 0.75
    assert synthetic.epoch_lag == 6
    no_readers = StoreStats.from_dict(
        {**synthetic.to_dict(), "oldest_epoch": None,
         "pool_budget_bytes": 0})
    assert no_readers.epoch_lag == 0
    assert no_readers.pool_utilization == 0.0


# ------------------------------------------------------- legacy import path
def test_legacy_imports_are_the_same_objects():
    from repro.core import StorageEngine as LegacyEngine

    assert LegacyEngine is StorageEngine
    assert SaveReport is EngineSaveReport  # facade re-export, not a copy
    assert DEFAULT_TOLERANCE > 0 and 0 < DEFAULT_TAU
