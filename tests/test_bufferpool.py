"""Buffer-pool invariants: pin safety, byte budget, torn-read freedom.

The pool's contract (``repro.core.bufferpool``):

* pinned frames are NEVER evicted;
* after every operation ``resident_bytes() <= max(budget, pinned_bytes())``
  — the pool only exceeds its budget when pins alone force it to, and then
  holds nothing unpinned;
* frame bytes are immutable: a reader holding a (pinned or merely
  referenced) frame can never observe stale or torn page bytes, no matter
  how much eviction pressure and invalidation churn runs concurrently.

The hypothesis test drives random op sequences against the invariants;
the thread-stress test hammers pin/read/unpin from several threads while
the key space thrashes the budget.
"""

import threading

import pytest

from repro.core.bufferpool import BufferPool


def _payload(key: str, size: int) -> bytes:
    # Deterministic per-key content so any cross-key mixup is detectable.
    seed = key.encode()
    reps = size // len(seed) + 1
    return (seed * reps)[:size]


def _check_invariants(pool: BufferPool) -> None:
    stats = pool.stats()
    assert stats["resident_bytes"] <= max(stats["budget_bytes"],
                                          stats["pinned_bytes"]), stats
    with pool._lock:
        for frame in pool._frames.values():
            assert not frame.detached
        for frame in pool._detached:
            assert frame.pins > 0  # detached frames die with their last pin


def test_get_returns_pinned_frame_and_shares_bytes():
    pool = BufferPool(budget_bytes=1 << 20)
    f1 = pool.get("a", lambda: _payload("a", 100))
    f2 = pool.get("a", lambda: (_ for _ in ()).throw(AssertionError("reload")))
    assert f1 is f2 and f1.pins == 2
    assert f1.data == _payload("a", 100)
    assert pool.stats()["hits"] == 1 and pool.stats()["misses"] == 1
    pool.unpin(f1)
    pool.unpin(f2)
    _check_invariants(pool)


def test_pinned_frames_survive_any_pressure():
    pool = BufferPool(budget_bytes=300)
    pinned = pool.get("keep", lambda: _payload("keep", 200))
    for i in range(20):  # each new frame forces eviction pressure
        f = pool.get(f"churn{i}", lambda i=i: _payload(f"churn{i}", 150))
        pool.unpin(f)
        _check_invariants(pool)
    assert pool.get("keep", lambda: b"WRONG").data == _payload("keep", 200)
    assert pinned.data == _payload("keep", 200)
    pool.unpin(pinned)
    pool.unpin(pinned)
    _check_invariants(pool)


def test_unpin_of_overbudget_frame_evicts_it():
    pool = BufferPool(budget_bytes=10)
    f = pool.get("big", lambda: _payload("big", 100))
    assert pool.resident_bytes() == 100  # pinned overage is allowed
    pool.unpin(f)
    assert pool.resident_bytes() == 0  # reclaimed the moment pins drain
    assert f.data == _payload("big", 100)  # holder's bytes stay valid
    _check_invariants(pool)


def test_invalidate_detaches_pinned_frame():
    pool = BufferPool(budget_bytes=1 << 20)
    f = pool.get("page", lambda: _payload("v1", 64))
    pool.invalidate("page")
    # New readers load fresh bytes; the old holder keeps the old version.
    f2 = pool.get("page", lambda: _payload("v2", 64))
    assert f.data == _payload("v1", 64)
    assert f2.data == _payload("v2", 64)
    assert pool.stats()["detached"] == 1
    assert pool.stats()["pinned_bytes"] == 128
    pool.unpin(f)
    assert pool.stats()["detached"] == 0
    pool.unpin(f2)
    _check_invariants(pool)


def test_loader_error_does_not_leak_a_frame():
    pool = BufferPool(budget_bytes=1 << 20)
    with pytest.raises(FileNotFoundError):
        pool.get("missing", lambda: (_ for _ in ()).throw(FileNotFoundError()))
    assert pool.stats()["resident"] == 0
    f = pool.get("missing", lambda: _payload("missing", 32))  # retry works
    assert f.data == _payload("missing", 32)
    pool.unpin(f)
    _check_invariants(pool)


def test_invalidate_racing_failed_load_leaves_no_detached_frame():
    """A writer invalidating a page whose load then fails (the unlink won
    the race) must not strand the loading frame in the detached set."""
    pool = BufferPool(budget_bytes=1 << 20)

    def loader():
        pool.invalidate("page")  # the concurrent unlink, mid-load
        raise FileNotFoundError("page")

    with pytest.raises(FileNotFoundError):
        pool.get("page", loader)
    stats = pool.stats()
    assert stats["detached"] == 0 and stats["resident"] == 0
    assert stats["pinned_bytes"] == 0
    _check_invariants(pool)


def test_trim_reclaims_to_target():
    pool = BufferPool(budget_bytes=1000)
    frames = [pool.get(f"k{i}", lambda i=i: _payload(f"k{i}", 200))
              for i in range(4)]
    for f in frames[1:]:
        pool.unpin(f)
    reclaimed = pool.trim(200)
    assert reclaimed == 600  # three unpinned frames go; the pinned one stays
    assert pool.resident_bytes() == 200
    pool.unpin(frames[0])
    _check_invariants(pool)


def test_concurrent_pin_read_unpin_never_tears(tmp_path):
    """Thread stress: random keys under heavy eviction pressure; every read
    must observe exactly the key's own deterministic payload."""
    pool = BufferPool(budget_bytes=2048)  # ~4 frames resident at a time
    keys = [f"page{i}" for i in range(16)]
    errors: list[str] = []
    barrier = threading.Barrier(4)

    def worker(seed: int):
        barrier.wait()
        for step in range(400):
            key = keys[(seed * 7919 + step * 31) % len(keys)]
            frame = pool.get(key, lambda key=key: _payload(key, 512))
            data = frame.data
            if data != _payload(key, 512):
                errors.append(f"torn read on {key}")
                pool.unpin(frame)
                return
            if step % 37 == 0:
                pool.invalidate(key)
            pool.unpin(frame)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not errors, errors
    assert not any(t.is_alive() for t in threads), "stress worker deadlocked"
    _check_invariants(pool)
    stats = pool.stats()
    assert stats["evictions"] > 0  # the budget actually exerted pressure


# ------------------------------------------------------------ property test
# Guarded import (not importorskip) so only this section skips without
# hypothesis — the unit tests above must run everywhere.
try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.stateful import RuleBasedStateMachine, invariant, rule

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional local dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    class PoolMachine(RuleBasedStateMachine):
        """Random op sequences against the pool's documented invariants."""

        def __init__(self):
            super().__init__()
            self.pool = BufferPool(budget_bytes=1024)
            self.pinned: list = []  # frames this machine still holds a pin on

        @rule(key=st.integers(0, 9), size=st.integers(1, 700))
        def get(self, key, size):
            name = f"k{key}"
            frame = self.pool.get(name, lambda: _payload(name, size))
            assert frame.data == _payload(name, len(frame.data))
            self.pinned.append(frame)

        @rule()
        def unpin_one(self):
            if self.pinned:
                self.pool.unpin(self.pinned.pop())

        @rule(key=st.integers(0, 9))
        def invalidate(self, key):
            self.pool.invalidate(f"k{key}")

        @rule(target_frac=st.floats(0.0, 1.2))
        def trim(self, target_frac):
            self.pool.trim(int(self.pool.budget * target_frac))

        @rule(extra=st.integers(1, 300))
        def note_extra(self, extra):
            if self.pinned:
                self.pool.note_extra(self.pinned[-1], extra)

        @invariant()
        def budget_respected(self):
            stats = self.pool.stats()
            assert stats["resident_bytes"] <= max(stats["budget_bytes"],
                                                  stats["pinned_bytes"]), stats

        @invariant()
        def pinned_never_evicted(self):
            for frame in self.pinned:
                assert frame.data is not None and frame.pins > 0

        @invariant()
        def accounting_matches(self):
            with self.pool._lock:
                actual = sum(f.nbytes for f in self.pool._frames.values())
                assert actual == self.pool._resident

        def teardown(self):
            while self.pinned:
                self.pool.unpin(self.pinned.pop())
            stats = self.pool.stats()
            assert stats["pinned_bytes"] == 0
            assert stats["resident_bytes"] <= stats["budget_bytes"]
            super().teardown()

    PoolMachine.TestCase.settings = settings(
        max_examples=60, stateful_step_count=50, deadline=None
    )
    TestPoolProperties = PoolMachine.TestCase

    @given(
        sizes=st.lists(st.integers(1, 500), min_size=1, max_size=30),
        budget=st.integers(1, 2000),
    )
    @settings(max_examples=100, deadline=None)
    def test_transient_gets_always_converge_under_budget(sizes, budget):
        """Get+unpin sequences (no held pins) land resident <= budget."""
        pool = BufferPool(budget_bytes=budget)
        for i, size in enumerate(sizes):
            name = f"s{i % 7}"
            frame = pool.get(
                name, lambda name=name, size=size: _payload(name, size)
            )
            assert frame.data is not None
            pool.unpin(frame)
            assert pool.resident_bytes() <= budget
else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pool_property_suite_needs_hypothesis():
        """Placeholder so a missing-hypothesis env reports the skip."""
