"""Dry-run integration: one real cell through the 512-device path.

Runs in a subprocess because the dry-run must own the
``xla_force_host_platform_device_count`` flag before jax initializes
(the test process itself keeps 1 device)."""

import glob
import importlib.util
import json
import subprocess
import sys

import pytest

# The dry-run subprocess runs with a stripped env (it must own XLA_FLAGS),
# so a parent-process JAX_PLATFORMS=cpu override does not reach it. When a
# TPU runtime stub (libtpu) is importable but no TPU chips are attached,
# jax's backend init in that subprocess hangs instead of failing — skip
# rather than burn the 540 s timeout.
_LIBTPU_STUB_WOULD_HANG = (
    importlib.util.find_spec("libtpu") is not None
    and not (glob.glob("/dev/accel*") or glob.glob("/dev/vfio/*"))
)


@pytest.mark.skipif(
    _LIBTPU_STUB_WOULD_HANG,
    reason="libtpu installed but no TPU devices: jax TPU init hangs in the "
    "stripped-env dry-run subprocess",
)
def test_dryrun_single_cell(tmp_path):
    out = tmp_path / "cell.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "internlm2-1.8b", "--shape", "decode_32k",
         "--out", str(out)],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec = json.load(open(out))[0]
    assert rec["n_devices"] == 256
    assert rec["per_device"]["hlo_flops"] > 0
    assert rec["per_device"]["collective_bytes"] > 0
    assert set(rec["roofline_s"]) == {"compute", "memory", "collective"}
    assert rec["bottleneck"] in ("compute", "memory", "collective")


def test_dryrun_skip_rules():
    from repro.configs import get_config

    assert not get_config("deepseek-67b").supports_shape("long_500k")
    assert not get_config("hubert-xlarge").supports_shape("decode_32k")
    assert get_config("rwkv6-7b").supports_shape("long_500k")
    assert get_config("recurrentgemma-9b").supports_shape("long_500k")
