"""Distribution-layer tests on the host devices (mesh 1×1 here; the
512-device configuration is exercised by launch/dryrun.py, which must own
the XLA device-count flag)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as sh
from repro.launch import shardings as shd
from repro.launch.hlo_stats import collective_stats
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import init_cache, init_params
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_specs_cover_every_leaf():
    """Every arch's every param leaf gets a valid spec (no fallthroughs that
    shard a mismatched rank)."""
    mesh = _mesh()
    for arch in ("qwen3-8b", "rwkv6-7b", "recurrentgemma-9b", "arctic-480b"):
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, KEY)
        with sh.use_mesh(mesh) as ctx:
            specs = shd.param_specs_tree(params, ctx)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(params),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(tuple(spec)) <= leaf.ndim, (path, spec, leaf.shape)


def test_sharded_train_step_runs():
    """jit with in_shardings on a real (1×1) mesh — the full production
    plumbing (param/opt/batch shardings, microbatching, donation)."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    mesh = _mesh()
    with sh.use_mesh(mesh) as ctx:
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        p_spec = shd.named(shd.param_specs_tree(params, ctx), mesh)
        o_spec = shd.named(shd.opt_specs_tree(
            opt, shd.param_specs_tree(params, ctx)), mesh)
        batch = {
            "tokens": jnp.zeros((4, 64), jnp.int32),
            "labels": jnp.zeros((4, 64), jnp.int32),
        }
        b_spec = shd.named(shd.batch_specs_tree(batch, ctx), mesh)
        step = jax.jit(make_train_step(cfg, 2),
                       in_shardings=(p_spec, o_spec, b_spec),
                       out_shardings=(p_spec, o_spec, None),
                       donate_argnums=(0, 1))
        params2, opt2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(opt2["step"]) == 1


def test_sharded_serve_step_runs():
    cfg = get_config("glm4-9b", smoke=True)
    mesh = _mesh()
    with sh.use_mesh(mesh, seq_shard=False, serve=True) as ctx:
        params = init_params(cfg, KEY)
        cache = init_cache(cfg, 2, 64)
        p_spec = shd.named(shd.param_specs_tree(params, ctx), mesh)
        c_spec = shd.named(shd.cache_specs_tree(cache, ctx, cfg.n_kv_heads), mesh)
        step = jax.jit(make_serve_step(cfg),
                       in_shardings=(p_spec, c_spec, None, None),
                       out_shardings=(None, c_spec), donate_argnums=(1,))
        tok, cache = step(params, cache, {"tokens": jnp.zeros((2, 1), jnp.int32)},
                          jnp.int32(0))
        assert tok.shape == (2,)


def test_fit_spec_divisibility():
    """fit_spec drops/replaces axes whose size doesn't divide the dim."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # These mesh axes are size 1 → everything divides; test the logic
    # directly with a fake 16×16 shape table instead.
    from repro.launch.shardings import _fits

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    assert _fits(P("data", "model"), (32, 32), FakeMesh)
    assert not _fits(P("data", "model"), (32, 8), FakeMesh)
    assert not _fits(P(("data", "model"),), (64,), FakeMesh)
    assert _fits(P(("data", "model"),), (256,), FakeMesh)


def test_collective_stats_parser():
    hlo = """
  %ag = bf16[16,256]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = (f32[128]{0}, f32[64]{0}) all-reduce(%a, %b), replica_groups=[2,8]<=[16], to_apply=%sum
  %rs = f32[4,32]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %done = bf16[16,256]{1,0} all-gather-done(%ag)
"""
    stats = collective_stats(hlo, 16)
    assert stats["count"] == 4
    ag = 16 * 256 * 2 * 3 / 4
    ar = 2 * (128 * 4 + 64 * 4) * 7 / 8
    rs = 4 * 32 * 4 * 1
    cp = 8 * 8 * 2
    np.testing.assert_allclose(stats["all-gather"], ag)
    np.testing.assert_allclose(stats["all-reduce"], ar)
    np.testing.assert_allclose(stats["reduce-scatter"], rs)
    np.testing.assert_allclose(stats["collective-permute"], cp)


def test_data_pipeline_determinism_and_sharding():
    from repro.data import SyntheticLM

    data = SyntheticLM(1024, seed=3)
    b1 = data.batch(7, 16, 32)
    b2 = data.batch(7, 16, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # Shards partition the work deterministically.
    s0 = data.batch(7, 16, 32, shard=0, n_shards=4)
    assert s0["tokens"].shape == (4, 32)
    # Labels are next-token aligned.
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
