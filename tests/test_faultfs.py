"""FaultFS unit tests + the randomized fault-injection campaign.

The campaign is the PR's acceptance test: a fixed lifecycle workload
(save, replace, delete, vacuum, load) runs under hundreds of
deterministic fault schedules — EIO, short writes, silent bit flips, and
crashes at any individual I/O call — and after every schedule the store
must *reopen* to a consistent catalog (possibly with models quarantined
or the store degraded to read-only), never serve silently wrong tensor
bytes, and come back fully clean after ``tools/fsck.py --repair
--drop-corrupt``.

``FAULT_CAMPAIGN_SCHEDULES`` (default 200; CI sets it explicitly) bounds
how many (call, kind) schedules the sweep samples.
"""

import importlib.util
import os
import random
import shutil
import tempfile

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core.faultfs import (
    FAULT_KINDS,
    FaultCrash,
    FaultFS,
    FaultInjected,
    FaultPlan,
)
from repro.core.integrity import IntegrityError

_FSCK_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "tools", "fsck.py",
)
_spec = importlib.util.spec_from_file_location("neurstore_fsck_c", _FSCK_PATH)
fsck_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(fsck_mod)
fsck = fsck_mod.fsck


# ------------------------------------------------------------- unit tests
def test_plan_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultPlan(at_call=1, kind="meteor")


def test_eio_write_leaves_file_untouched(tmp_path):
    p = str(tmp_path / "f")
    FaultFS().write_durable(p, b"before")
    fs = FaultFS(FaultPlan(at_call=1, kind="eio"))
    with pytest.raises(FaultInjected) as ei:
        fs.write_durable(p, b"after", site="page.write")
    assert ei.value.errno == 5 and ei.value.site == "page.write"
    assert open(p, "rb").read() == b"before"
    assert fs.injected == ("eio", "write", "page.write")


def test_crash_before_write_vs_short_write_vs_crash_fsync(tmp_path):
    data = b"0123456789abcdef"
    p = str(tmp_path / "f")
    fs = FaultFS(FaultPlan(at_call=1, kind="crash"))
    with pytest.raises(FaultCrash):
        fs.write_durable(p, data)
    assert not os.path.exists(p)  # crash lands before any byte

    fs = FaultFS(FaultPlan(at_call=1, kind="short_write"))
    with pytest.raises(FaultCrash):
        fs.write_durable(p, data)
    assert open(p, "rb").read() == data[: len(data) // 2]  # torn prefix

    fs = FaultFS(FaultPlan(at_call=1, kind="crash_fsync"))
    with pytest.raises(FaultCrash):
        fs.write_durable(p, data)
    assert open(p, "rb").read() == data  # all bytes landed, fsync didn't


def test_bitflip_write_is_silent_single_bit(tmp_path):
    data = bytes(range(32))
    p = str(tmp_path / "f")
    fs = FaultFS(FaultPlan(at_call=1, kind="bitflip", bit=77))
    fs.write_durable(p, data)  # no exception: the flip is silent
    got = open(p, "rb").read()
    assert len(got) == len(data)
    diff = [(a ^ b) for a, b in zip(got, data) if a != b]
    assert len(diff) == 1 and bin(diff[0]).count("1") == 1


def test_bitflip_read_is_transient(tmp_path):
    data = bytes(range(32))
    p = str(tmp_path / "f")
    FaultFS().write_durable(p, data)
    fs = FaultFS(FaultPlan(at_call=1, kind="bitflip", bit=5))
    assert fs.read_bytes(p) != data  # damaged in memory...
    assert open(p, "rb").read() == data  # ...but not on disk
    assert fs.read_bytes(p) == data  # one-shot: next read is clean


def test_replace_crash_before_vs_after_rename(tmp_path):
    src, dst = str(tmp_path / "src"), str(tmp_path / "dst")
    for kind, expect_dst in (("crash", False), ("crash_fsync", True)):
        FaultFS().write_durable(src, b"new")
        FaultFS().write_durable(dst, b"old")
        fs = FaultFS(FaultPlan(at_call=1, kind=kind))
        with pytest.raises(FaultCrash):
            fs.replace(src, dst)
        got = open(dst, "rb").read()
        assert got == (b"new" if expect_dst else b"old"), kind


def test_site_filter_counts_only_matching_calls(tmp_path):
    p = str(tmp_path / "f")
    fs = FaultFS(FaultPlan(at_call=1, kind="eio", site="journal"))
    fs.write_durable(p, b"x", site="page.write")  # not counted
    fs.write_durable(p, b"x", site="meta.tmp")  # not counted
    assert fs.calls == 0
    with pytest.raises(FaultInjected):
        fs.append_durable(p, "y", site="journal.append")
    assert fs.calls == 1


def test_record_mode_logs_every_call(tmp_path):
    p = str(tmp_path / "f")
    fs = FaultFS(record=True)
    fs.write_durable(p, b"x", site="page.write")
    fs.read_bytes(p, site="page.read")
    fs.unlink(p, site="unlink")
    assert fs.log == [
        ("write", "page.write"), ("read", "page.read"), ("unlink", "unlink"),
    ]
    assert fs.calls == 3


def test_truncate_durable(tmp_path):
    p = str(tmp_path / "f")
    FaultFS().write_durable(p, b"0123456789")
    FaultFS().truncate(p, 4)
    assert open(p, "rb").read() == b"0123"
    fs = FaultFS(FaultPlan(at_call=1, kind="eio"))
    with pytest.raises(FaultInjected):
        fs.truncate(p, 2)
    assert open(p, "rb").read() == b"0123"


# ------------------------------------------------------------- the campaign
def _mk(seed, scale=1.0, n=2, d=16):
    rng = np.random.default_rng(seed)
    return {
        f"t{i}": rng.normal(0, scale, (d,)).astype(np.float32)
        for i in range(n)
    }


_STEPS = (
    ("save", "wa", 10, 1.0),
    ("save", "wb", 11, 4.0),
    ("save", "wa", 12, 1.0),  # replace wa
    ("delete", "wb", None, None),
    ("save", "wc", 13, 8.0),
    ("vacuum", None, None, None),
    ("loads", None, None, None),
)


def _run_workload(eng, acceptable=None):
    """Run the lifecycle workload; when ``acceptable`` is given (the
    fault-free reference run) record every materialization each model
    ever legitimately had."""

    def snap():
        if acceptable is None:
            return
        for name in eng.list_models():
            vals = eng.load_model(name).materialize()
            versions = acceptable.setdefault(name, [])
            if not any(_same(vals, v) for v in versions):
                versions.append(vals)

    for op, name, seed, scale in _STEPS:
        if op == "save":
            eng.save_model(name, {}, _mk(seed, scale))
        elif op == "delete":
            eng.delete_model(name)
        elif op == "vacuum":
            eng.vacuum(min_dead_fraction=0.0)
        elif op == "loads":
            for n in eng.list_models():
                eng.load_model(n).materialize()
        snap()


def _same(a: dict, b: dict) -> bool:
    return set(a) == set(b) and all(np.array_equal(a[k], b[k]) for k in a)


class _Campaign:
    """Template store + fault-free reference, built once per test run."""

    def __init__(self):
        self.template = tempfile.mkdtemp(prefix="nsfault_tpl_")
        eng = StorageEngine(self.template)
        # Two snapshots so meta.json.prev exists before any fault lands —
        # a single fault must never be able to destroy the only snapshot.
        eng.save_model("seed0", {}, _mk(1))
        eng.save_model("seed1", {}, _mk(2, 4.0))
        eng.close()

        # Reference run: acceptable materializations per model name.
        ref = tempfile.mkdtemp(prefix="nsfault_ref_")
        shutil.copytree(self.template, ref, dirs_exist_ok=True)
        self.acceptable: dict[str, list[dict]] = {}
        eng = StorageEngine(ref)
        for name in eng.list_models():
            self.acceptable[name] = [eng.load_model(name).materialize()]
        _run_workload(eng, self.acceptable)
        eng.close()
        shutil.rmtree(ref, ignore_errors=True)

        # Counting run: how many faultable I/O calls the workload makes
        # (including the engine open itself).
        cnt = tempfile.mkdtemp(prefix="nsfault_cnt_")
        shutil.copytree(self.template, cnt, dirs_exist_ok=True)
        fs = FaultFS(record=True)
        eng = StorageEngine(cnt, fs=fs)
        _run_workload(eng)
        eng.close()
        self.n_calls = fs.calls
        shutil.rmtree(cnt, ignore_errors=True)


_CAMPAIGN = None


def _campaign():
    global _CAMPAIGN
    if _CAMPAIGN is None:
        _CAMPAIGN = _Campaign()
    return _CAMPAIGN


def _run_trial(at_call: int, kind: str, bit: int) -> None:
    camp = _campaign()
    work = tempfile.mkdtemp(prefix="nsfault_trial_")
    try:
        root = os.path.join(work, "store")
        shutil.copytree(camp.template, root)
        fs = FaultFS(FaultPlan(at_call=at_call, kind=kind, bit=bit))
        try:
            eng = StorageEngine(root, fs=fs)
            _run_workload(eng)
            eng.close()
        except Exception:
            # The workload died mid-flight (simulated crash, EIO, or a
            # typed integrity refusal). If no fault actually fired, this
            # is a real bug — surface it.
            if fs.injected is None:
                raise
        # "Reboot": a clean open must always succeed — degraded at worst.
        eng = StorageEngine(root)
        try:
            for name in eng.list_models():
                try:
                    got = eng.load_model(name).materialize()
                except (IntegrityError, ValueError):
                    continue  # typed detection / quarantine is a pass
                versions = camp.acceptable.get(name)
                assert versions is not None, f"unexpected model {name!r}"
                assert any(_same(got, v) for v in versions), (
                    f"SILENT CORRUPTION at call {at_call} kind {kind}: "
                    f"model {name!r} served bytes matching no legitimate "
                    f"version"
                )
        finally:
            eng.close()
        # fsck must repair the store to fully clean.
        rep = fsck(root, repair=True, drop_corrupt=True)
        assert rep["clean"], (
            f"fsck not clean after repair (call {at_call}, {kind}): "
            f"{rep['errors']}"
        )
        assert fsck(root)["clean"]
        # And the repaired store serves every surviving model.
        eng = StorageEngine(root)
        try:
            assert not eng.read_only
            for name in eng.list_models():
                eng.load_model(name).materialize()
        finally:
            eng.close()
    finally:
        shutil.rmtree(work, ignore_errors=True)


def _schedules():
    camp = _campaign()
    n = camp.n_calls
    budget = int(os.environ.get("FAULT_CAMPAIGN_SCHEDULES", "200"))
    pairs = [(c, k) for c in range(1, n + 1) for k in FAULT_KINDS]
    rng = random.Random(0xFA171)
    rng.shuffle(pairs)
    if len(pairs) > budget:
        # Keep full call-coverage with one kind each, then fill the rest
        # of the budget with the shuffled remainder.
        per_call = {}
        for c, k in pairs:
            per_call.setdefault(c, (c, k))
        chosen = list(per_call.values())[:budget]
        extra = [p for p in pairs if p not in set(chosen)]
        chosen += extra[: budget - len(chosen)]
        pairs = chosen
    return [(c, k, rng.randrange(4096)) for c, k in pairs]


def test_fault_campaign():
    sched = _schedules()
    assert sched, "workload made no faultable I/O calls?"
    for at_call, kind, bit in sched:
        _run_trial(at_call, kind, bit)
