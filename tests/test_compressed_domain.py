"""Compressed-domain serving: decode straight off the store, zero materialize.

The acceptance suite for the compute-on-compressed path: a decoder saved
through ``save_model`` and loaded with ``load_model(bits=8)`` must serve
greedy decode through the ``dequant_matmul_auto`` seam with *zero*
``materialize()`` calls on kernel-served tensors (counting-hook tests),
matching the materialize-then-serve forward pass within quantization
error; plus the lazy ``compressed_params`` / ``KernelNotReady`` contract,
int4 packing traffic, pinned-frame session semantics, and the one-epoch
``load_models`` batch capture.
"""

import tempfile

import numpy as np
import pytest

from repro.core import CompressedModel, KernelNotReady, StorageEngine
from repro.core.loader import LoadedModel
from repro.launch.compressed_serve import (
    DecoderSpec,
    MaterializedProvider,
    greedy_decode,
    save_decoder,
)

SPEC = DecoderSpec(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                   n_layers=2, vocab_size=96)
PROMPT = np.array([[1, 5, 9]])


@pytest.fixture
def decoder_engine(tmp_path):
    eng = StorageEngine(tmp_path)
    save_decoder(eng, "dec", SPEC, seed=3)
    yield eng
    eng.close()


def test_compressed_decode_matches_materialized_zero_materialize(
        decoder_engine, monkeypatch):
    """The tentpole acceptance: greedy decode off compressed operands equals
    the materialized forward, and materialize()/tensor() are never called
    for kernel-served tensors (norm vectors may reconstruct)."""
    eng = decoder_engine
    lm_base = eng.load_model("dec", bits=8)
    want_tokens, want_logits = greedy_decode(
        MaterializedProvider(lm_base), SPEC, PROMPT, 6, return_logits=True)
    lm_base.close()

    lm = eng.load_model("dec", bits=8)
    calls = {"materialize": 0}
    tensor_calls: list[str] = []
    orig_tensor = LoadedModel.tensor

    def no_materialize(self):
        calls["materialize"] += 1
        raise AssertionError("materialize() during compressed serving")

    def spy_tensor(self, name):
        tensor_calls.append(name)
        return orig_tensor(self, name)

    monkeypatch.setattr(LoadedModel, "materialize", no_materialize)
    monkeypatch.setattr(LoadedModel, "tensor", spy_tensor)
    provider = CompressedModel(lm)
    tokens, logits = greedy_decode(provider, SPEC, PROMPT, 6,
                                   return_logits=True)
    assert calls["materialize"] == 0
    # Every projection + lm_head + embedding went through the kernel seam;
    # tensor() reconstructed norm gains only — never a kernel-served weight.
    assert provider.kernel_served >= {
        "lm_head.weight", "model.embed_tokens.weight",
        "model.layers.0.self_attn.q_proj.weight",
        "model.layers.1.mlp.down_proj.weight"}
    assert not (set(tensor_calls) & provider.kernel_served)
    assert all("norm" in name for name in tensor_calls)
    np.testing.assert_array_equal(tokens, want_tokens)
    np.testing.assert_allclose(logits, want_logits, rtol=1e-4, atol=1e-4)
    assert provider.counters["matmul_calls"] > 0
    lm.close()


def test_compressed_session_pins_frames_until_close(decoder_engine):
    eng = decoder_engine
    assert eng.page_pool.pinned_bytes() == 0
    lm = eng.load_model("dec", bits=8)
    provider = CompressedModel(lm)
    greedy_decode(provider, SPEC, PROMPT, 2)
    assert eng.page_pool.pinned_bytes() > 0  # snapshot holds the page frame
    provider.close()
    eng._drain_released()
    assert eng.page_pool.pinned_bytes() == 0


def test_full_precision_handle_raises_kernel_not_ready(decoder_engine):
    lm = decoder_engine.load_model("dec")  # no bits= → ~17-bit deltas
    provider = CompressedModel(lm)
    with pytest.raises(KernelNotReady, match="bits"):
        provider.matmul(np.zeros((1, SPEC.d_model), np.float32),
                        "lm_head.weight")
    # vector() still works: norm gains don't go through the kernels.
    assert provider.vector("model.norm.weight").shape == (SPEC.d_model,)
    lm.close()


def test_int4_packing_traffic_and_parity(decoder_engine):
    """bits=4 flexible loading → nibble-packed deltas: 1.5 bytes/weight vs
    2.0 at bits=8, and compressed decode still matches the materialized
    decode of the *same* 4-bit view."""
    eng = decoder_engine
    lm8 = eng.load_model("dec", bits=8)
    lm4 = eng.load_model("dec", bits=4)
    p8, p4 = CompressedModel(lm8), CompressedModel(lm4)
    assert p8.bytes_per_weight("lm_head.weight") == 2.0
    assert not p8.weight("lm_head.weight").packed
    assert p4.bytes_per_weight("lm_head.weight") == 1.5
    assert p4.weight("lm_head.weight").packed
    lm4b = eng.load_model("dec", bits=4)
    want = greedy_decode(MaterializedProvider(lm4b), SPEC, PROMPT, 4)
    got = greedy_decode(p4, SPEC, PROMPT, 4)
    np.testing.assert_array_equal(got, want)
    for handle in (lm8, lm4, lm4b):
        handle.close()


def test_lazy_compressed_params_and_kernel_operands(decoder_engine):
    lm = decoder_engine.load_model("dec", bits=8)
    cp = lm.compressed_params()
    assert len(cp) == len(lm.tensor_names())
    assert "lm_head.weight" in cp
    assert not cp._entries  # nothing decoded until indexed
    entry = cp.kernel_operands("lm_head.weight")
    assert entry["qdelta_i8"].dtype == np.int8
    assert entry["base_codes"].dtype == np.int8
    assert list(cp._entries) == ["lm_head.weight"]  # only what was touched
    assert cp["lm_head.weight"] is entry  # cached
    lm.close()


@pytest.mark.parametrize("k,n,m", [(2, 5, 1), (33, 17, 4), (64, 64, 2)])
def test_compressed_matmul_error_bounds(k, n, m):
    """Property: for stored weight W, CompressedModel.matmul(x) equals
    x @ materialized(W) to fp precision, and x @ W within the delta-quant
    bin width (|err| <= 0.5*delta_scale per element, bin-centre dequant)."""
    rng = np.random.default_rng(k * 1000 + n * 10 + m)
    w = rng.normal(0, 0.7, (k, n)).astype(np.float32)
    x = rng.normal(0, 1, (m, k)).astype(np.float32)
    with tempfile.TemporaryDirectory() as root:
        eng = StorageEngine(root)
        eng.save_model("m", {"kind": "t"}, {"w": w})
        lm = eng.load_model("m", bits=8)
        provider = CompressedModel(lm, force="numpy")
        got = provider.matmul(x, "w")
        reference = x @ lm.tensor("w")
        np.testing.assert_allclose(got, reference, rtol=1e-4, atol=1e-4)
        half_bin = 0.5 * float(provider.params["w"]["delta_scale"])
        bound = (np.abs(x).sum(axis=1, keepdims=True) * half_bin
                 + 1e-3 * np.abs(x @ w) + 1e-4)
        assert (np.abs(got - x @ w) <= bound).all()
        # The interpret-mode kernel path agrees with the numpy path.
        kernel = CompressedModel(lm, force="kernel")
        np.testing.assert_allclose(kernel.matmul(x, "w"), got,
                                   rtol=1e-4, atol=1e-4)
        lm.close()
        eng.close()


def test_load_models_single_epoch_under_concurrent_replace(tmp_path,
                                                           monkeypatch):
    """A writer committing mid-batch must not hand load_models a mixed-epoch
    view: the batch retries and every handle shares one epoch, seeing the
    post-commit state consistently (regression for the per-name loop)."""
    eng = StorageEngine(tmp_path)
    t_a = {"w": np.full((8, 8), 1.0, np.float32)}
    t_b_old = {"w": np.full((8, 8), 2.0, np.float32)}
    t_b_new = {"w": np.full((8, 8), 5.0, np.float32)}
    eng.save_model("a", {}, t_a)
    eng.save_model("b", {}, t_b_old)

    orig_read = eng._read_page_bytes
    fired = []

    def racing_read(page_name):
        data = orig_read(page_name)
        if not fired:
            fired.append(page_name)
            eng.replace_model("b", {}, t_b_new)  # writer wins mid-batch
        return data

    monkeypatch.setattr(eng, "_read_page_bytes", racing_read)
    handles = eng.load_models(["a", "b"])
    assert fired, "the racing replace never ran"
    epochs = {h.snapshot.epoch for h in handles}
    assert len(epochs) == 1, f"mixed-epoch batch: {epochs}"
    out_a, out_b = (h.materialize() for h in handles)
    np.testing.assert_array_equal(out_a["w"], t_a["w"])
    np.testing.assert_array_equal(out_b["w"], t_b_new["w"])
    for h in handles:
        h.close()
    eng.close()