"""Fault tolerance: delta-compressed checkpointing, restart, elasticity,
gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.models import init_params
from repro.optim import adamw_init

KEY = jax.random.PRNGKey(0)


def _small_state():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = init_params(cfg, KEY)
    opt = adamw_init(params)
    return cfg, params, opt


def test_checkpoint_roundtrip(tmp_path):
    cfg, params, opt = _small_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, params, opt)
    step, state = mgr.restore()
    assert step == 10
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2 ** -23, rtol=0)
    assert int(state["opt"]["step"]) == int(opt["step"])


def test_checkpoint_delta_compression_across_steps(tmp_path):
    """Consecutive checkpoints delta-encode against each other: step 2+
    pages must be much smaller than step 1 (the paper's mechanism applied
    to training)."""
    cfg, params, opt = _small_state()
    mgr = CheckpointManager(str(tmp_path), tolerance=1e-6)
    mgr.save(0, params)
    first = mgr.engine._meta["models"]["ckpt-0"]
    # Simulate a few optimizer steps: small drift.
    for step in (1, 2):
        params = jax.tree.map(
            lambda p: p + 1e-4 * jax.random.normal(
                jax.random.PRNGKey(step), p.shape, p.dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        mgr.save(step, params)
    rep = mgr.storage_report()
    m0 = mgr._manifest["meta_0"]
    m2 = mgr._manifest["meta_2"]
    assert m2["new_bases"] == 0, "drifted ckpt must reuse previous bases"
    assert m2["page_bytes"] < 0.6 * m0["original_bytes"]
    assert rep["compression_ratio"] > 1.5


def test_restart_after_simulated_crash(tmp_path):
    cfg, params, opt = _small_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, params, opt)
    # Crash mid-save of step 6: write garbage page without manifest commit.
    with open(mgr.engine._page_path(999), "wb") as f:
        f.write(b"partial garbage")
    del mgr
    mgr2 = CheckpointManager(str(tmp_path))
    assert mgr2.latest_step() == 5
    step, state = mgr2.restore()
    assert step == 5 and state["params"] is not None


def test_async_save(tmp_path):
    cfg, params, opt = _small_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, params, opt, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_elastic_restore_different_mesh(tmp_path):
    """Save unsharded → restore and shard onto a different device layout."""
    cfg, params, opt = _small_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, params)
    _, state = mgr.restore()
    # Re-shard onto this host's devices (1 device ↔ N devices both fine).
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.distributed import sharding as sh
    from repro.launch import shardings as shd

    with sh.use_mesh(mesh) as ctx:
        specs = shd.param_specs_tree(state["params"], ctx)
        sharded = jax.tree.map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)),
            state["params"], specs,
            is_leaf=lambda x: isinstance(x, np.ndarray))
    flat = jax.tree.leaves(sharded)
    assert all(hasattr(x, "sharding") for x in flat)


def test_flexible_bit_restore(tmp_path):
    """bits=8 restore: approximate params, bounded deviation (fast eval
    replica spin-up per paper §4.3.1)."""
    cfg, params, opt = _small_state()
    mgr = CheckpointManager(str(tmp_path), tolerance=2 ** -24)
    mgr.save(0, params)
    _, exact = mgr.restore()
    _, approx = mgr.restore(bits=8)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(exact["params"]),
            jax.tree_util.tree_leaves_with_path(approx["params"])):
        diff = np.abs(np.asarray(a, np.float64) - np.asarray(b, np.float64))
        assert diff.mean() < 1e-3


def test_gradient_compression_error_feedback():
    """Quantize→feedback loop: time-averaged gradient is preserved."""
    from repro.distributed.compression import quantize_grad

    rng = np.random.default_rng(0)
    true_g = rng.normal(0, 1e-3, (64, 64)).astype(np.float32)
    err = jnp.zeros_like(jnp.asarray(true_g))
    acc = np.zeros_like(true_g)
    n = 50
    for _ in range(n):
        codes, scale, err = quantize_grad(jnp.asarray(true_g), err, nbit=4)
        acc += np.asarray(codes, np.float32) * float(scale)
    # With error feedback the mean transmitted gradient converges to true.
    np.testing.assert_allclose(acc / n, true_g, atol=2e-5)


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="cross_pod_sync needs the top-level jax.shard_map API (jax>=0.6); "
    "this environment's jax predates it",
)
def test_cross_pod_sync():
    from repro.distributed.compression import cross_pod_sync, init_error_state

    if len(jax.devices()) < 2:
        mesh = jax.make_mesh((1,), ("pod",))
    else:
        mesh = jax.make_mesh((2,), ("pod",))
    p = mesh.devices.size
    rng = np.random.default_rng(1)
    per_pod = jnp.asarray(rng.normal(0, 1e-3, (p, 32, 16)).astype(np.float32))
    grads = {"w": per_pod}
    errs = init_error_state(grads)
    synced, new_errs = cross_pod_sync(grads, errs, mesh)
    want = np.broadcast_to(np.asarray(per_pod).mean(0), (p, 32, 16))
    # One-shot int8 error ≤ scale/2 ≈ amax/254 (error feedback amortises
    # the rest across steps — see test_gradient_compression_error_feedback).
    amax = float(np.abs(np.asarray(per_pod)).max())
    np.testing.assert_allclose(np.asarray(synced["w"]), want,
                               atol=amax / 254 + 1e-7)
