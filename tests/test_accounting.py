"""Tests for the storage-introspection layer (``docs/observability.md``).

The contract under test:

- **Conservation**: for every committed model, ``delta_bytes +
  metadata_bytes == page_bytes == os.path.getsize(page)``, and the
  store totals re-sum from the per-model rows; amortized shared-base
  bytes re-sum to the store base bytes (± integer rounding).
- **No drift**: the incremental :class:`SpaceAccountant` — maintained
  at save/replace/delete/vacuum commit points — matches a full page
  rescan after every mutation, across a reopen, and after a mid-save
  crash + replay (the fsck ``--accounting`` invariant).
- **EXPLAIN**: every save report carries per-tensor dedup attribution
  whose delta bytes sum to the accountant's physical delta bytes; the
  rows persist via write-behind sidecars and survive a reopen.
- **Round-trip**: ``/v1/accounting`` and ``…/models/{name}/explain``
  serve the same numbers through ``StoreClient``.
- The ``SaveRequest.total_bytes`` quota footprint is post-cast f32 and
  the slow-op threshold is configurable via env var / server knob.
"""

import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core.faultfs import FaultCrash, FaultFS, FaultPlan
from repro.obs.trace import (
    DEFAULT_SLOW_OP_THRESHOLD_S,
    get_slow_op_threshold,
    set_slow_op_threshold,
)
from repro.server import ModelStoreServer, StoreClient
from repro.store import SaveRequest

# ``repro.obs`` re-exports the ``trace`` *function* under the same name
# as the module, so resolve the module itself explicitly.
trace_mod = importlib.import_module("repro.obs.trace")

_FSCK_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "tools", "fsck.py",
)
_spec = importlib.util.spec_from_file_location("neurstore_fsck_a", _FSCK_PATH)
fsck_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(fsck_mod)
fsck = fsck_mod.fsck

EXPLAIN_KEYS = {
    "tensor", "dim", "vertex_id", "outcome", "probe_distance",
    "delta_range", "tau", "nbit", "delta_bytes", "error_bound",
}
OUTCOMES = {"new_base", "delta", "intra_save_dedup"}


def _mk(seed, n=3, d=32, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        f"t{i}": rng.normal(0, scale, (d,)).astype(np.float32)
        for i in range(n)
    }


def _finetune(tensors, seed=99, eps=1e-3):
    rng = np.random.default_rng(seed)
    return {
        k: (v + eps * rng.standard_normal(v.shape)).astype(np.float32)
        for k, v in tensors.items()
    }


def _assert_conserved(eng):
    """The accounting taxonomy must re-sum exactly to the bytes on disk."""
    rep = eng.accounting_report()
    store, per_model = rep["store"], rep["per_model"]
    n_tensors = 0
    for name, m in per_model.items():
        disk = os.path.getsize(os.path.join(eng.root, "pages", m["page"]))
        assert m["delta_bytes"] + m["metadata_bytes"] == m["page_bytes"], name
        assert m["page_bytes"] == disk, name
        assert m["physical_bytes"] == (
            m["page_bytes"] + m["shared_base_bytes"]), name
        n_tensors += m["n_tensors"]
    for key in ("page_bytes", "delta_bytes", "logical_bytes"):
        assert store[key] == sum(m[key] for m in per_model.values()), key
    assert store["models"] == len(per_model)
    assert store["physical_bytes"] == store["page_bytes"] + store["base_bytes"]
    # Shared-base amortization (numel / refcount per sharer) must re-sum
    # to the store base bytes up to one byte of rounding per tensor.
    shared = sum(m["shared_base_bytes"] for m in per_model.values())
    assert abs(shared - store["base_bytes"]) <= max(n_tensors, 1)
    # The per-dim breakdown partitions the same totals.
    per_dim = rep["per_dim"]
    assert sum(d["logical_bytes"] for d in per_dim.values()) == \
        store["logical_bytes"]
    assert sum(d["delta_bytes"] for d in per_dim.values()) == \
        store["delta_bytes"]
    assert sum(d["base_bytes"] for d in per_dim.values()) == \
        store["base_bytes"]
    return rep


# -------------------------------------------------------------- satellites
def test_total_bytes_is_post_cast_f32_footprint():
    # The store casts to f32 before quantizing: an f16 upload is not
    # half price and an f64 upload is not double.
    t16 = {"a": np.ones(10, dtype=np.float16)}
    t64 = {"b": np.ones(10, dtype=np.float64)}
    assert SaveRequest("m", t16).total_bytes() == 40
    assert SaveRequest("m", t64).total_bytes() == 40
    both = SaveRequest("m", {**t16, **t64})
    assert both.total_bytes() == 80


def test_slow_op_threshold_env_parsing(monkeypatch):
    monkeypatch.delenv("NEURSTORE_SLOW_OP_THRESHOLD_S", raising=False)
    assert trace_mod._threshold_from_env() == DEFAULT_SLOW_OP_THRESHOLD_S
    monkeypatch.setenv("NEURSTORE_SLOW_OP_THRESHOLD_S", "2.5")
    assert trace_mod._threshold_from_env() == 2.5
    for bad in ("not-a-number", "", "0", "-3", "nan"):
        monkeypatch.setenv("NEURSTORE_SLOW_OP_THRESHOLD_S", bad)
        assert trace_mod._threshold_from_env() == \
            DEFAULT_SLOW_OP_THRESHOLD_S, bad


def test_set_slow_op_threshold_returns_previous():
    prev = set_slow_op_threshold(0.5)
    try:
        assert get_slow_op_threshold() == 0.5
        assert set_slow_op_threshold(1.5) == 0.5
    finally:
        set_slow_op_threshold(prev)


def test_server_knob_sets_threshold_and_healthz_reports_it(tmp_path):
    before = get_slow_op_threshold()
    engine = StorageEngine(str(tmp_path))
    server = ModelStoreServer(engine, slow_op_threshold_s=0.25).start()
    try:
        assert get_slow_op_threshold() == 0.25
        c = StoreClient(server.host, server.port, tenant="acme")
        body = c._json("GET", "/v1/healthz")
        assert body["slow_op_threshold_s"] == 0.25
    finally:
        server.stop()
        engine.close()
        set_slow_op_threshold(before)


# ------------------------------------------------------------ conservation
def test_conservation_and_amortization_across_dim_groups(tmp_path):
    eng = StorageEngine(str(tmp_path))
    try:
        base = _mk(1, n=3, d=32)
        eng.save_model("base", {}, base)
        eng.save_model("ft", {}, _finetune(base))  # shares base vertices
        eng.save_model("other", {}, _mk(2, n=2, d=48, scale=4.0))
        rep = _assert_conserved(eng)
        assert rep["store"]["logical_bytes"] == (3 * 32 + 3 * 32 + 2 * 48) * 4
        assert set(rep["per_dim"]) == {"32", "48"} | set()
        # Deleting "ft" reclaims its page but none of the shared bases.
        assert rep["per_model"]["ft"]["reclaimable_bytes"] >= \
            rep["per_model"]["ft"]["page_bytes"]
        assert eng.accounting_drift() == []
    finally:
        eng.close()


def test_accounting_tracks_lifecycle_without_drift(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    try:
        base = _mk(3, n=2, d=24)
        eng.save_model("a", {}, base)
        eng.save_model("b", {}, _finetune(base))
        for step in (
            lambda: eng.save_model("a", {}, _mk(4, n=2, d=24, scale=2.0)),
            lambda: eng.delete_model("b"),
            lambda: eng.vacuum(),
            lambda: eng.save_model("c", {}, _mk(5, n=2, d=24)),
        ):
            step()
            assert eng.accounting_drift() == []
            _assert_conserved(eng)
    finally:
        eng.close()

    eng = StorageEngine(root)  # reopen reseeds the ledger from a rescan
    try:
        assert eng.accounting_drift() == []
        _assert_conserved(eng)
    finally:
        eng.close()


def test_accounting_disabled_still_reports_via_rescan(tmp_path):
    eng = StorageEngine(str(tmp_path), accounting=False)
    try:
        eng.save_model("m", {}, _mk(6))
        rep = _assert_conserved(eng)  # computed from a one-off rescan
        assert rep["store"]["models"] == 1
        assert eng.accounting_drift() == []  # vacuously clean
    finally:
        eng.close()


@pytest.mark.parametrize("at_call", [3, 9, 18])
def test_accounting_survives_crash_replay(tmp_path, at_call):
    """One crash schedule (the test_faultfs campaign covers the full
    space): kill the process at an arbitrary I/O call mid-workload, then
    the reopened engine's replayed ledger must match a full rescan."""
    root = str(tmp_path)
    fs = FaultFS(FaultPlan(at_call=at_call, kind="crash"))
    crashed = False
    try:
        eng = StorageEngine(root, fs=fs)
        base = _mk(7, n=2, d=16)
        eng.save_model("wa", {}, base)
        eng.save_model("wb", {}, _finetune(base))
        eng.save_model("wa", {}, _mk(8, n=2, d=16, scale=2.0))
        eng.delete_model("wb")
    except FaultCrash:
        crashed = True
    else:
        eng.close()
    assert crashed, "schedule never reached the fault"

    eng = StorageEngine(root)  # crash recovery replays the journal
    try:
        assert eng.accounting_drift() == []
        _assert_conserved(eng)
        eng.save_model("post", {}, _mk(9, n=2, d=16))
        assert eng.accounting_drift() == []
        _assert_conserved(eng)
    finally:
        eng.close()


# ----------------------------------------------------------------- EXPLAIN
def test_save_report_explain_attributes_every_tensor(tmp_path):
    eng = StorageEngine(str(tmp_path))
    try:
        base = _mk(10, n=4, d=64)
        rep1 = eng.save_model("base", {}, base)
        rep2 = eng.save_model("ft", {}, _finetune(base))
        for rep, tensors in ((rep1, base), (rep2, base)):
            assert [ex["tensor"] for ex in rep.explain] == list(tensors)
            for ex in rep.explain:
                assert EXPLAIN_KEYS <= set(ex)
                assert ex["outcome"] in OUTCOMES
                assert ex["dim"] == 64 and ex["delta_bytes"] >= 0
        # A fresh store has no vertices: the first save mints new bases.
        assert rep1.explain[0]["outcome"] == "new_base"
        assert rep1.explain[0]["probe_distance"] is None
        # The fine-tune lands within tau of the existing bases.
        assert all(ex["outcome"] != "new_base" for ex in rep2.explain)
        # Acceptance: per-tensor delta bytes sum to the accountant's
        # physical delta bytes for the model.
        pm = eng.accounting_report()["per_model"]
        for rep in (rep1, rep2):
            assert sum(ex["delta_bytes"] for ex in rep.explain) == \
                pm[rep.name]["delta_bytes"]
    finally:
        eng.close()


def test_explain_sidecars_are_write_behind_and_survive_reopen(tmp_path):
    root = str(tmp_path)
    explain_dir = os.path.join(root, "explain")
    eng = StorageEngine(root)
    rep = eng.save_model("m", {}, _mk(11, n=3, d=32))
    # Write-behind: nothing hits disk on the save path itself.
    assert os.listdir(explain_dir) == []
    before = eng.model_explain("m")  # served from memory meanwhile
    assert before["explain"] == rep.explain and not before["truncated"]
    eng.close()  # close() flushes the queue
    files = os.listdir(explain_dir)
    assert files == [f"model_{rep.model_id}.json"]

    eng = StorageEngine(root)
    try:
        after = eng.model_explain("m")
        assert not after["truncated"]
        assert len(after["explain"]) == len(rep.explain)
        for got, want in zip(after["explain"], rep.explain):
            for k in ("tensor", "dim", "vertex_id", "outcome", "nbit",
                      "delta_bytes"):
                assert got[k] == want[k], k
            # Sidecar floats are trimmed to 6 significant digits.
            assert got["error_bound"] == pytest.approx(
                want["error_bound"], rel=1e-4)
        assert after["accounting"]["page_bytes"] > 0
    finally:
        eng.close()


def test_explain_sidecar_lifecycle_delete_vacuum_orphans(tmp_path):
    root = str(tmp_path)
    explain_dir = os.path.join(root, "explain")
    eng = StorageEngine(root)
    ra = eng.save_model("a", {}, _mk(12, n=2, d=16))
    rb = eng.save_model("b", {}, _mk(13, n=2, d=16, scale=4.0))
    eng.delete_model("b")  # dequeues + unlinks b's (never-written) sidecar
    eng.vacuum()  # vacuum flushes the queue
    assert os.listdir(explain_dir) == [f"model_{ra.model_id}.json"]
    assert eng.accounting_drift() == []
    eng.close()

    # An orphan sidecar (crash between delete commit and cleanup) is
    # swept at open, like orphan pages.
    stray = os.path.join(explain_dir, "model_999.json")
    with open(stray, "w") as f:
        json.dump([], f)
    eng = StorageEngine(root)
    try:
        assert not os.path.exists(stray)
        assert os.path.exists(
            os.path.join(explain_dir, f"model_{ra.model_id}.json"))
        assert eng.model_explain("a")["explain"], "survivor lost its rows"
        with pytest.raises(KeyError):
            eng.model_explain("b")
    finally:
        eng.close()
    del rb


# -------------------------------------------------------------------- fsck
def test_fsck_accounting_clean_and_forced_drift(tmp_path):
    root = str(tmp_path)
    eng = StorageEngine(root)
    eng.save_model("m", {}, _mk(14))
    eng.close()
    rep = fsck(root, accounting=True)
    assert rep["clean"], rep["errors"]

    # Forced drift: corrupt the live ledger, then the cross-check must
    # report it as an error (drift = failure, not warning).
    eng = StorageEngine(root)
    try:
        eng._accountant.record_delete("m")
        lines = eng.accounting_drift()
        assert lines and any("m" in ln for ln in lines)
        rep = {"root": root, "errors": [], "warnings": [], "actions": []}
        fsck_mod._check_accounting(root, rep, engine=eng)
        assert rep["errors"] == lines
    finally:
        eng.close()


# -------------------------------------------------------------- round-trip
def test_http_accounting_and_explain_roundtrip(tmp_path):
    engine = StorageEngine(str(tmp_path))
    server = ModelStoreServer(engine).start()
    try:
        c = StoreClient(server.host, server.port, tenant="acme")
        base = _mk(15, n=4, d=64)
        c.save(SaveRequest("base", base))
        rep = c.save(SaveRequest("ft", _finetune(base)))
        assert rep.explain and len(rep.explain) == len(base)
        for ex in rep.explain:
            assert EXPLAIN_KEYS <= set(ex)
            assert ex["outcome"] in OUTCOMES

        acct = c.accounting()
        pm = acct["per_model"]["acme/ft"]
        # Acceptance: the wire report's per-tensor delta bytes sum to the
        # accountant's physical delta bytes for the same model.
        assert sum(ex["delta_bytes"] for ex in rep.explain) == \
            pm["delta_bytes"]
        tenants = acct["per_tenant"]
        assert tenants["acme"]["models"] == 2
        assert tenants["acme"]["physical_bytes"] == sum(
            m["physical_bytes"] for m in acct["per_model"].values())

        body = c.explain("ft")
        assert body["n_tensors"] == len(base) and not body["truncated"]
        assert [ex["tensor"] for ex in body["explain"]] == list(base)
        assert body["accounting"]["page_bytes"] == pm["page_bytes"]

        # The typed stats surface quotes the same store-wide accounting.
        s = c.stats()
        assert s.logical_bytes == acct["store"]["logical_bytes"]
        assert s.physical_bytes == acct["store"]["physical_bytes"]
        assert s.compression_ratio == pytest.approx(
            acct["store"]["compression_ratio"])
        assert engine.accounting_drift() == []
    finally:
        server.stop()
        engine.close()
