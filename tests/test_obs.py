"""Observability contract tests (``repro.obs`` + instrumentation).

What's under test (``docs/observability.md``):

- the metric **name contract**: every documented family exists in the
  process registry with the documented type and label schema after a
  representative workload — renaming a metric is a breaking change and
  must fail here;
- counters are monotonic and move when the instrumented hot paths run
  (save/load/vacuum/delete, pool hits/misses, HNSW search);
- Prometheus text round-trips through the strict parser, and the parser
  actually rejects malformed exposition;
- spans nest into trees, propagate W3C ``traceparent`` from
  ``StoreClient`` through the server into engine spans, and slow roots
  hit the slow-op log with their full tree;
- disabling observability stops recording but never breaks timing
  (``SaveReport.seconds`` still real);
- ``/v1/metrics`` stays valid under concurrent read/write load with
  zero 5xx.

The registry is process-global, so every assertion is on *deltas*
around the workload, never absolutes.
"""

import json
import logging
import threading
import urllib.request

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.obs.metrics import (
    MetricsRegistry,
    default_registry,
    parse_prometheus_text,
    set_enabled,
)
from repro.obs.trace import (
    parse_traceparent,
    recent_traces,
    set_slow_op_threshold,
    trace,
)
from repro.server import ModelStoreServer, StoreClient
from repro.store import NeurStore, SaveRequest

RNG = np.random.default_rng(7)


def _tensors(n=3, d=48, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return {f"t{i}": rng.standard_normal((d,)).astype(np.float32)
            for i in range(n)}


@pytest.fixture(autouse=True)
def _obs_state_guard():
    """Tests may flip global obs switches; always restore them."""
    prev_thresh = set_slow_op_threshold(1.0)
    set_enabled(True)
    yield
    set_enabled(True)
    set_slow_op_threshold(prev_thresh)


def _value(name, labels=None):
    return default_registry().sample_value(name, labels or {}) or 0.0


# ------------------------------------------------------------ name contract
# The documented metric families (docs/observability.md). A rename or
# type/label change here is a breaking change to the scrape contract.
CONTRACT = [
    ("neurstore_engine_ops_total", "counter", ("op",)),
    ("neurstore_engine_op_seconds", "histogram", ("op",)),
    ("neurstore_engine_page_reads_total", "counter", ()),
    ("neurstore_engine_page_read_bytes_total", "counter", ()),
    ("neurstore_engine_quarantines_total", "counter", ()),
    ("neurstore_engine_models", "gauge", ()),
    ("neurstore_engine_epoch", "gauge", ()),
    ("neurstore_engine_snapshots_live", "gauge", ()),
    ("neurstore_pool_hits_total", "counter", ()),
    ("neurstore_pool_misses_total", "counter", ()),
    ("neurstore_pool_evictions_total", "counter", ()),
    ("neurstore_pool_decoded_hits_total", "counter", ()),
    ("neurstore_pool_decoded_misses_total", "counter", ()),
    ("neurstore_pool_resident_bytes", "gauge", ()),
    ("neurstore_pool_pinned_bytes", "gauge", ()),
    ("neurstore_pool_budget_bytes", "gauge", ()),
    ("neurstore_hnsw_distance_evals_total", "counter", ()),
    ("neurstore_hnsw_visited_total", "counter", ()),
    ("neurstore_hnsw_searches_total", "counter", ()),
    ("neurstore_hnsw_inserts_total", "counter", ()),
    ("neurstore_maintenance_steps_total", "counter", ()),
    ("neurstore_maintenance_errors_total", "counter", ()),
    ("neurstore_maintenance_restarts_total", "counter", ()),
    ("neurstore_maintenance_consecutive_errors", "gauge", ()),
    ("neurstore_maintenance_last_error_age_seconds", "gauge", ()),
    ("neurstore_server_requests_total", "counter",
     ("route", "method", "status")),
    ("neurstore_server_request_seconds", "histogram", ("route",)),
    ("neurstore_server_inflight_requests", "gauge", ()),
    ("neurstore_server_response_cache_hits_total", "counter", ()),
    ("neurstore_server_response_cache_misses_total", "counter", ()),
    ("neurstore_server_response_cache_admissions_total", "counter", ()),
    ("neurstore_server_response_cache_bypasses_total", "counter", ()),
    ("neurstore_server_response_cache_evictions_total", "counter", ()),
    ("neurstore_server_admission_rejects_total", "counter", ("reason",)),
    ("neurstore_slow_ops_total", "counter", ("op",)),
    ("neurstore_dedup_outcomes_total", "counter", ("outcome",)),
    ("neurstore_delta_bits", "histogram", ()),
    ("neurstore_logical_bytes", "gauge", ()),
    ("neurstore_physical_bytes", "gauge", ()),
]


def test_metric_name_contract():
    # Importing the instrumented modules registered every family; the
    # registry's own idempotent constructors verify type + label schema
    # (they raise on mismatch).
    import repro.server.admission  # noqa: F401 — registers its family
    reg = default_registry()
    for name, mtype, labels in CONTRACT:
        fam = {"counter": reg.counter, "gauge": reg.gauge,
               "histogram": reg.histogram}[mtype]
        fam(name, "help ignored on re-get", labels)  # raises on drift


def test_counters_move_and_are_monotonic(tmp_path):
    before = {
        "saves": _value("neurstore_engine_ops_total", {"op": "save"}),
        "loads": _value("neurstore_engine_ops_total", {"op": "load"}),
        "pool": (_value("neurstore_pool_hits_total")
                 + _value("neurstore_pool_misses_total")),
        "reads": _value("neurstore_engine_page_reads_total"),
        "inserts": _value("neurstore_hnsw_inserts_total"),
    }
    eng = StorageEngine(str(tmp_path))
    eng.save_model("a", {"f": 1}, _tensors(seed=1))
    eng.save_model("b", {"f": 1}, _tensors(seed=2))
    for _ in range(3):
        eng.load_model("a").close()
    eng.vacuum()
    eng.delete_model("b")
    eng.close()

    assert _value("neurstore_engine_ops_total", {"op": "save"}) \
        == before["saves"] + 2
    assert _value("neurstore_engine_ops_total", {"op": "load"}) \
        == before["loads"] + 3
    assert (_value("neurstore_pool_hits_total")
            + _value("neurstore_pool_misses_total")) >= before["pool"] + 3
    assert _value("neurstore_engine_page_reads_total") > before["reads"]
    assert _value("neurstore_hnsw_inserts_total") > before["inserts"]
    # Histogram count mirrors the op counter.
    fams = parse_prometheus_text(default_registry().render())
    count = [s["value"] for s in fams["neurstore_engine_op_seconds"]["samples"]
             if s["name"].endswith("_count") and s["labels"] == {"op": "save"}]
    assert count and count[0] >= before["saves"] + 2


def test_gauges_track_engine_state(tmp_path):
    base_models = _value("neurstore_engine_models")
    eng = StorageEngine(str(tmp_path))
    eng.save_model("a", {"f": 1}, _tensors(seed=3))
    assert _value("neurstore_engine_models") == base_models + 1
    lm = eng.load_model("a")
    assert _value("neurstore_engine_snapshots_live") >= 1
    assert _value("neurstore_pool_resident_bytes") > 0
    lm.close()
    eng.delete_model("a")
    assert _value("neurstore_engine_models") == base_models
    eng.close()
    # A collected engine drops out of the gauge sum (weakref semantics).
    del eng
    assert _value("neurstore_engine_models") == base_models


# --------------------------------------------------------------- exposition
def test_prometheus_round_trip():
    reg = MetricsRegistry()
    c = reg.counter("rt_ops_total", "ops", ("kind",))
    c.labels("read").inc(3)
    c.labels('we"ird\\la{bel}').inc()  # escaping must survive the trip
    g = reg.gauge("rt_depth", "depth")
    g.set(-2.5)
    h = reg.histogram("rt_seconds", "latency")
    for v in (1e-6, 0.003, 0.5, 99.0):
        h.observe(v)
    fams = parse_prometheus_text(reg.render())
    assert fams["rt_ops_total"]["type"] == "counter"
    by_kind = {s["labels"]["kind"]: s["value"]
               for s in fams["rt_ops_total"]["samples"]}
    assert by_kind["read"] == 3
    assert by_kind['we"ird\\la{bel}'] == 1
    assert fams["rt_depth"]["samples"][0]["value"] == -2.5
    hist = fams["rt_seconds"]["samples"]
    count = [s for s in hist if s["name"] == "rt_seconds_count"][0]
    assert count["value"] == 4
    inf = [s for s in hist if s["labels"].get("le") == "+Inf"]
    assert inf and inf[0]["value"] == 4  # cumulative buckets end at +Inf


@pytest.mark.parametrize("bad", [
    "no_type_announcement 1",
    "# TYPE x counter\nx one",
    "# TYPE x notatype\nx 1",
    '# TYPE x counter\nx{a="unterminated 1',
])
def test_parser_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_prometheus_text(bad)


def test_registry_rejects_schema_drift():
    reg = MetricsRegistry()
    reg.counter("drift_total", "x", ("a",))
    with pytest.raises(ValueError):
        reg.gauge("drift_total", "x")  # type change
    with pytest.raises(ValueError):
        reg.counter("drift_total", "x", ("b",))  # label change


# ------------------------------------------------------------------- traces
def test_span_tree_and_ring():
    with trace("outer", who="t") as outer:
        with trace("inner"):
            with trace("leaf"):
                pass
    assert [s.name for s in outer.walk()] == ["outer", "inner", "leaf"]
    assert outer.find("leaf") is not None
    assert recent_traces()[-1] is outer
    # traceparent emitted by a span parses back to its own ids.
    assert parse_traceparent(outer.traceparent()) == \
        (outer.trace_id, outer.span_id)


def test_save_report_seconds_comes_from_span(tmp_path):
    eng = StorageEngine(str(tmp_path))
    report = eng.save_model("m", {"f": 1}, _tensors(seed=4))
    root = [s for s in recent_traces() if s.name == "engine.save"][-1]
    # report.seconds is read off the same span just before it closes, so
    # it can only trail the closed span by bookkeeping microseconds.
    assert 0 < report.seconds <= root.elapsed()
    assert root.elapsed() - report.seconds < 5e-3
    children = {c.name for c in root.children}
    assert {"probe", "quantize", "commit"} <= children
    eng.close()


def test_slow_op_log_fires(tmp_path, caplog):
    before = _value("neurstore_slow_ops_total", {"op": "engine.save"})
    set_slow_op_threshold(0.0)  # everything is slow now
    eng = StorageEngine(str(tmp_path))
    with caplog.at_level(logging.WARNING, logger="repro.obs.slow"):
        eng.save_model("m", {"f": 1}, _tensors(seed=5))
    eng.close()
    msgs = [r.getMessage() for r in caplog.records
            if "engine.save" in r.getMessage()]
    assert msgs, "slow-op log never fired"
    # The log carries the indented span tree, not just the root.
    assert "- probe" in msgs[0] and "- commit" in msgs[0]
    assert _value("neurstore_slow_ops_total", {"op": "engine.save"}) \
        > before


def test_disabled_mode_records_nothing_but_still_times(tmp_path):
    before = _value("neurstore_engine_ops_total", {"op": "save"})
    ring_before = len(recent_traces())
    set_enabled(False)
    eng = StorageEngine(str(tmp_path))
    report = eng.save_model("m", {"f": 1}, _tensors(seed=6))
    eng.close()
    assert report.seconds > 0  # timing survives disablement
    assert _value("neurstore_engine_ops_total", {"op": "save"}) == before
    assert len(recent_traces()) == ring_before
    set_enabled(True)


# ------------------------------------------- propagation through the server
@pytest.fixture
def served(tmp_path):
    engine = StorageEngine(str(tmp_path))
    server = ModelStoreServer(engine).start()
    yield engine, server
    server.stop()
    engine.close()


def test_traceparent_client_to_engine(served):
    engine, server = served
    client = StoreClient(server.host, server.port, tenant="acme")
    client.save(SaveRequest("m", _tensors(seed=8), architecture={"v": 1}))
    with trace("app.load") as root:
        client.load("m").close()
    # The server handled the download on another thread, as a SEPARATE
    # local root — joined to our trace only by the propagated trace id.
    server_roots = [
        s for s in recent_traces()
        if s.name == "http.request" and s.trace_id == root.trace_id
        and s.attrs.get("method") == "GET"
    ]
    assert server_roots, "server span tree did not adopt the client trace id"
    tree = server_roots[-1]
    load = tree.find("engine.load")
    assert load is not None
    # Latency attribution: the documented child phases are all present.
    assert {"probe", "pool", "snapshot"} <= {c.name for c in load.children}
    assert tree.find("page.io") is not None or \
        tree.find("decode") is not None
    client.close()


def test_metrics_endpoint_under_concurrent_load(served):
    engine, server = served
    writer = StoreClient(server.host, server.port, tenant="acme")
    writer.save(SaveRequest("hot", _tensors(seed=9), architecture={"v": 1}))
    url = f"http://{server.host}:{server.port}"
    stop = threading.Event()
    failures: list[str] = []

    def reader():
        c = StoreClient(server.host, server.port, tenant="acme")
        while not stop.is_set():
            try:
                c.load("hot").close()
            except Exception as exc:  # noqa: BLE001
                failures.append(f"read: {exc!r}")
        c.close()

    def scraper():
        while not stop.is_set():
            try:
                with urllib.request.urlopen(f"{url}/v1/metrics") as resp:
                    assert resp.status == 200
                    parse_prometheus_text(resp.read().decode("utf-8"))
            except Exception as exc:  # noqa: BLE001
                failures.append(f"scrape: {exc!r}")

    threads = [threading.Thread(target=reader) for _ in range(3)] + \
              [threading.Thread(target=scraper) for _ in range(2)]
    for t in threads:
        t.start()
    for i in range(5):
        writer.save(SaveRequest(f"w{i}", _tensors(seed=10 + i),
                                architecture={"v": 1}))
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not failures, failures[:5]
    assert server.server_stats()["errors_5xx"] == 0
    # Per-route request accounting saw the scrapes and downloads as 2xx.
    assert _value("neurstore_server_requests_total",
                  {"route": "metrics", "method": "GET", "status": "2xx"}) > 0
    assert _value("neurstore_server_requests_total",
                  {"route": "model.download", "method": "GET",
                   "status": "2xx"}) > 0
    writer.close()


def test_healthz_reports_maintenance_and_uptime(served):
    engine, server = served
    daemon = engine.start_maintenance()
    try:
        url = f"http://{server.host}:{server.port}/v1/healthz"
        with urllib.request.urlopen(url) as resp:
            body = json.loads(resp.read())
        assert body["ok"] is True
        assert body["stats_schema_version"] >= 1
        assert body["uptime_s"] > 0
        assert body["read_only"] is False
        assert body["maintenance"]["running"] is True
        assert body["maintenance"]["consecutive_errors"] == 0
    finally:
        daemon.stop()


def test_facade_metrics_snapshot(tmp_path):
    with NeurStore.open(str(tmp_path)) as store:
        store.save(SaveRequest("m", _tensors(seed=11),
                               architecture={"v": 1}))
        snap = store.metrics()
        text = store.metrics_text()
    assert snap.keys() == parse_prometheus_text(text).keys()
    assert "neurstore_engine_ops_total" in snap
