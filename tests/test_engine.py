"""Storage engine behaviour tests (Alg. 1 / Alg. 2, index cache, pages)."""

import numpy as np

from repro.core import (
    DEFAULT_TOLERANCE,
    StorageEngine,
)
from repro.core.hnsw import HNSWIndex

RNG = np.random.default_rng(7)


def _mlp_tensors(scale=0.02, d=48):
    return {
        "layer0/w": RNG.normal(0, scale, (d, d)).astype(np.float32),
        "layer0/b": RNG.normal(0, scale, (d,)).astype(np.float32),
        "layer1/w": RNG.normal(0, scale, (d, 2 * d)).astype(np.float32),
    }


def test_save_load_roundtrip_bounded(tmp_path):
    eng = StorageEngine(str(tmp_path))
    tensors = _mlp_tensors()
    eng.save_model("m0", {"kind": "mlp"}, tensors)
    loaded = eng.load_model("m0").materialize()
    for k, v in tensors.items():
        assert loaded[k].shape == v.shape
        assert np.abs(loaded[k] - v).max() <= DEFAULT_TOLERANCE * 1.001 + 1e-9


def test_finetuned_variants_dedup(tmp_path):
    """Fine-tunes within tau of the base must NOT create new vertices and
    must compress far better than the base (paper's central mechanism)."""
    eng = StorageEngine(str(tmp_path))
    base = _mlp_tensors()
    r0 = eng.save_model("base", {}, base)
    assert r0.n_new_bases == len(base)
    ratios = []
    for i in range(4):
        ft = {k: v + RNG.normal(0, 5e-4, v.shape).astype(np.float32)
              for k, v in base.items()}
        r = eng.save_model(f"ft{i}", {}, ft)
        assert r.n_new_bases == 0, "fine-tune should match existing bases"
        ratios.append(r.original_bytes / r.page_bytes)
    assert min(ratios) > 1.5  # deltas need far fewer bits than f32


def test_dissimilar_model_new_bases(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("a", {}, _mlp_tensors())
    other = {k: RNG.normal(0, 5.0, v.shape).astype(np.float32)
             for k, v in _mlp_tensors().items()}
    r = eng.save_model("b", {}, other)
    assert r.n_new_bases == len(other), "distant tensors must become new bases"


def test_tau_controls_vertex_creation(tmp_path):
    base = _mlp_tensors()
    perturbed = {k: v + RNG.normal(0, 0.05, v.shape).astype(np.float32)
                 for k, v in base.items()}
    # Large tau: perturbation accepted as delta.
    eng_hi = StorageEngine(str(tmp_path / "hi"), tau=10.0)
    eng_hi.save_model("base", {}, base)
    r_hi = eng_hi.save_model("p", {}, perturbed)
    assert r_hi.n_new_bases == 0
    # Tiny tau: forced to create new vertices.
    eng_lo = StorageEngine(str(tmp_path / "lo"), tau=1e-6)
    eng_lo.save_model("base", {}, base)
    r_lo = eng_lo.save_model("p", {}, perturbed)
    assert r_lo.n_new_bases == len(base)


def test_flexible_loading_bits(tmp_path):
    """bits=8 load: bounded extra error, smaller payload read (Fig. 11)."""
    eng = StorageEngine(str(tmp_path))
    tensors = _mlp_tensors()
    eng.save_model("m", {}, tensors)
    full = eng.load_model("m").materialize()
    flex = eng.load_model("m", bits=8).materialize()
    for k in tensors:
        diff = np.abs(full[k] - flex[k]).mean()
        assert diff < 1e-3  # paper: ~1e-4 average
        # flexible is not exact (unless nbit <= 8)
    # flexible record carries truncated nbit
    lm = eng.load_model("m", bits=8)
    assert all(lm.record(k).meta.nbit <= 8 for k in lm.tensor_names())


def test_share_counted_base_dequant(tmp_path):
    """Tensors sharing one base dequantize it once (paper §4.3.2)."""
    eng = StorageEngine(str(tmp_path), tau=10.0)
    t = RNG.normal(0, 0.02, (32, 32)).astype(np.float32)
    tensors = {"a": t, "b": t + 1e-5, "c": t - 1e-5}
    eng.save_model("m", {}, tensors)
    lm = eng.load_model("m")
    recs = [lm.record(n) for n in lm.tensor_names()]
    assert len({(r.dim_key, r.vertex_id) for r in recs}) == 1
    out = lm.materialize()
    for k, v in tensors.items():
        assert np.abs(out[k] - v).max() <= DEFAULT_TOLERANCE * 1.001 + 1e-9
    assert not lm._deq_base  # drained to zero → freed


def test_pipeline_loader(tmp_path):
    eng = StorageEngine(str(tmp_path))
    tensors = _mlp_tensors()
    eng.save_model("m", {}, tensors)
    lm = eng.load_model("m")
    from repro.core import PipelineLoader

    seen = {}
    stats = PipelineLoader(lm).run(lambda name, t: seen.__setitem__(name, t))
    assert set(seen) == set(tensors)
    assert stats["wall"] > 0


def test_persistence_across_engine_restart(tmp_path):
    eng = StorageEngine(str(tmp_path))
    tensors = _mlp_tensors()
    eng.save_model("m", {"arch": "x"}, tensors)
    del eng
    eng2 = StorageEngine(str(tmp_path))
    assert "m" in eng2.list_models()
    loaded = eng2.load_model("m").materialize()
    for k, v in tensors.items():
        assert np.abs(loaded[k] - v).max() <= DEFAULT_TOLERANCE * 1.001 + 1e-9


def test_index_cache_eviction(tmp_path):
    eng = StorageEngine(str(tmp_path), cache_bytes=1)  # force eviction
    for i, d in enumerate([100, 200, 300]):
        t = {"w": RNG.normal(0, 0.02, d).astype(np.float32)}
        eng.save_model(f"m{i}", {}, t)
    # All models still loadable after their indexes were evicted to disk.
    for i in range(3):
        eng.load_model(f"m{i}").materialize()
    assert eng.index_cache.misses >= 1


def test_hnsw_recall_on_clusters():
    """HNSW must find the right cluster representative (dedup correctness)."""
    dim = 64
    idx = HNSWIndex(dim, m=8, ef_construction=32, seed=0)
    centers = RNG.normal(0, 1, (20, dim))
    for c in centers:
        idx.insert(c)
    hits = 0
    for i, c in enumerate(centers):
        q = c + RNG.normal(0, 0.01, dim)
        got = idx.search(q, k=1)[0][1]
        hits += got == i
    assert hits >= 18  # >=90% recall on well-separated clusters


def test_hnsw_serialization_roundtrip():
    idx = HNSWIndex(32, m=8, seed=1)
    for _ in range(30):
        idx.insert(RNG.normal(0, 1, 32))
    blob = idx.to_bytes()
    idx2 = HNSWIndex.from_bytes(blob)
    q = RNG.normal(0, 1, 32)
    assert idx.search(q, k=3) == idx2.search(q, k=3)


def test_storage_accounting(tmp_path):
    eng = StorageEngine(str(tmp_path))
    base = _mlp_tensors()
    eng.save_model("base", {}, base)
    for i in range(3):
        ft = {k: v + RNG.normal(0, 3e-4, v.shape).astype(np.float32)
              for k, v in base.items()}
        eng.save_model(f"ft{i}", {}, ft)
    s = eng.storage_bytes()
    assert s["total"] == s["pages"] + s["index"]
    # Per-model amortized bytes < raw f32 bytes for fine-tunes.
    raw = sum(v.nbytes for v in base.values())
    assert eng.per_model_bytes("ft0") < raw
